"""Quickstart: the plan-and-execute FFT API in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft as F
from repro.core import plan
from repro.core.conv import fft_conv

# ---- 1. plan inspection: the paper's kernel-call schedule -----------------
for n in (1024, 65536, 2**20):
    print(plan.describe(n))

# ---- 2. plan-and-execute: resolve a spec once, run it many times ----------
x = (np.random.randn(4, 4096) + 1j * np.random.randn(4, 4096)).astype(np.complex64)
spec = F.FFTSpec(n=4096, kind="fft", batch_hint=4)
planned = F.plan(spec)                  # cached: F.plan(spec) is F.plan(spec)
print(f"planned: {planned.describe()}  tiles={dict(planned.batch_tiles)}")
y = planned(jnp.asarray(x))
print("max err vs numpy:", float(np.abs(np.asarray(y) - np.fft.fft(x)).max()))

# ---- 3. the backend registry: every registered backend runs the same plan --
for backend in F.available_backends():   # pallas runs interpret on CPU
    y = F.plan(spec, backend=backend)(jnp.asarray(x))
    err = np.abs(np.asarray(y) - np.fft.fft(x)).max()
    print(f"backend={backend:9s} max err vs numpy: {err:.2e}")

# ---- 4. scoped backend selection (the deprecated global setter's successor) -
with F.use_backend("stockham"):
    y = F.fft(jnp.asarray(x))            # wrappers are plan-cached too
    print("use_backend('stockham') err:",
          float(np.abs(np.asarray(y) - np.fft.fft(x)).max()))

# ---- 5. axis-aware transforms (no manual swapaxes) -------------------------
xa = (np.random.randn(8, 1024, 3) + 1j * np.random.randn(8, 1024, 3)).astype(np.complex64)
ya = F.fft(jnp.asarray(xa), axis=1)
print("axis=1 err:", float(np.abs(np.asarray(ya) - np.fft.fft(xa, axis=1)).max()))

# ---- 6. real FFT (half the work for real signals) --------------------------
sig = np.random.randn(2, 8192).astype(np.float32)
Xr, Xi = F.rfft(jnp.asarray(sig))
print("rfft bins:", Xr.shape, " roundtrip err:",
      float(jnp.abs(F.irfft((Xr, Xi), 8192) - sig).max()))

# ---- 7. FFT long convolution (the LM-layer integration) --------------------
u = np.random.randn(1, 16, 2048).astype(np.float32)   # (B, D, L)
h = np.random.randn(16, 2048).astype(np.float32)      # per-channel filters
y = fft_conv(jnp.asarray(u), jnp.asarray(h))
print("fft_conv out:", y.shape)

# ---- 8. under jit, composed with autodiff ----------------------------------
g = jax.grad(lambda v: jnp.sum(jnp.abs(F.fft(v)) ** 2))(jnp.asarray(x))
print("grad of spectral energy == 2N·conj(x):",
      bool(jnp.allclose(g, 2 * 4096 * jnp.conj(jnp.asarray(x)), rtol=1e-3)))

# ---- 9. 2-D images: one joint rows+columns pass program --------------------
img = (np.random.randn(128, 1024) + 1j * np.random.randn(128, 1024)).astype(
    np.complex64
)
p2 = F.plan(F.FFTSpec(n=1024, kind="fft2", n2=128))   # ONE compiled program
print("fft2 plan:", p2.describe())
err2 = np.abs(np.asarray(p2(jnp.asarray(img))) - np.fft.fft2(img)).max()
print("fft2 err vs numpy:", float(err2))
real_img = np.random.randn(128, 1024).astype(np.float32)
Br, Bi = F.rfft2(jnp.asarray(real_img))               # real-packing 2-D
print("rfft2 bins:", Br.shape, " roundtrip err:",
      float(jnp.abs(F.irfft2((Br, Bi), 1024, 128) - real_img).max()))

# ---- 10. overlap-save streaming convolution --------------------------------
# Long signals never plan past the fused regime: the signal is blocked into
# overlapping segments batched through ONE cached small plan pair, and
# StreamingConv carries the Lh-1 tail so chunked calls compose exactly.
from repro.core.overlap import StreamingConv, fft_conv_os

sig = np.random.randn(2, 1 << 16).astype(np.float32)
filt = np.random.randn(1025).astype(np.float32)
y_os = fft_conv_os(jnp.asarray(sig), jnp.asarray(filt))
print("fft_conv_os out:", y_os.shape)
sc = StreamingConv(jnp.asarray(filt))                 # block picked from Lh
state = sc.init_state((2,))
chunks = []
for start in range(0, sig.shape[-1], 1 << 14):
    yc, state = sc(jnp.asarray(sig[:, start : start + (1 << 14)]), state)
    chunks.append(yc)
print("streaming == one-shot:",
      bool(jnp.allclose(jnp.concatenate(chunks, -1), y_os, atol=1e-3)))

# ---- 11. autotuning: measured plan tuning with a persistent cache ----------
# Every fixed performance heuristic (overlap-save block, per-pass chunk,
# leaf tile, fused-vs-split crossover) is a searched decision: the roofline
# model prunes the candidates, tune="measure" times the survivors ONCE and
# persists the winner — warm runs (and future processes) hit the cache and
# measure nothing.
from repro.core import tuning

y_tuned = fft_conv_os(jnp.asarray(sig), jnp.asarray(filt), tune="measure")
print("tuned block == one-shot result:",
      bool(jnp.allclose(y_tuned, y_os, atol=1e-3)))
pt = F.plan(F.FFTSpec(n=2**17, kind="fft"), backend="pallas", tune="measure")
print("tuned plan:", pt.describe())                 # tuned choices per pass
print("tuning cache:", tuning.cache_path())         # REPRO_TUNING_CACHE overrides
print("measurements this process:", len(tuning.measure_log()))
pt2 = F.plan(F.FFTSpec(n=2**17, kind="fft"), backend="pallas", tune="measure")
print("second plan is the same handle (zero re-measurement):", pt2 is pt)

# ---- 12. streaming spectral serving: prefill / insert / generate -----------
# The LM engine serves tokens through three compiled phases.  prefill runs
# the prompt once and converts caches to decode layout; insert splices the
# request into a slot of a RUNNING batch (the spectral mixer's stream state
# is re-phased to the batch's chunk clock, so a late joiner decodes exactly
# as it would solo); generate advances every slot in ONE lax.scan — the
# spectral layer's once-per-chunk FFT flush reuses the plan cached at trace
# time, so a warm loop creates zero new plans.
import dataclasses

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.engine import Engine, ServeConfig
from repro.serving.spectral_serve import ServeSession

cfg = ModelConfig(
    family="dense", num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
    d_ff=64, vocab_size=128, block_pattern=("spectral", "attn"),
    spectral_filter_len=8, compute_dtype="float32",
)
params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
eng = Engine(cfg, params, ServeConfig(max_new=6))
prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4, cfg.vocab_size)

sess = ServeSession(eng, slots=2, max_len=16)
s0 = sess.submit(prompts[0])       # prefill + insert into slot 0
sess.run(2)                        # slot 0 decodes alone for 2 steps
s1 = sess.submit(prompts[1])       # joins the RUNNING batch mid-stream
sess.run(5)                        # both slots advance in one scan
print("slot0 tokens:", sess.output(s0)[:6])
print("slot1 tokens:", sess.output(s1)[:6])
solo = eng.generate(prompts)       # whole-batch convenience wrapper
print("mid-stream join == solo decode:",
      sess.output(s1)[:6] == solo[1].tolist())
F.clear_plan_log()
sess.run(3)                        # warm loop: every flush hits the plan cache
print("new FFT plans during warm generate:", len(F.plan_log()))
print("phase seconds:", {k: round(v, 4) for k, v in sess.phase_s.items()})

# ---- 13. distributed pencil FFT: tuned, packed, overlapped -----------------
# Across a mesh the slow tier is the all-to-all transpose, and the schedule
# is a tuned decision exactly like the single-chip pass programs: factor
# balance, split-complex packing (ONE stacked collective per transpose) and
# the chunk count K the inner transposes are double-buffered at.  The pick
# is modeled-only (tune="model") — cache-free and measurement-free, so
# every host of an SPMD mesh derives the identical schedule.
from repro.core import distributed as D

mesh1 = jax.make_mesh((1,), ("x",))      # single-host demo mesh; on a pod
xr = jax.random.normal(jax.random.PRNGKey(2), (2, 4096))
yr, yi = D.pfft_sharded(xr, jnp.zeros_like(xr), mesh1, "x", tune="model")
print("pfft matches jnp.fft:",
      bool(jnp.allclose(yr + 1j * yi, jnp.fft.fft(xr), atol=1e-2)))
# The plan handle prints the pencil schedule like single-device plans do —
# factors, collective count, modeled comm MB per transpose step.  With one
# shard it collapses to the local plan (zero collectives, jaxpr-asserted
# in tests/test_pencil_plan.py); at d=8 the same call emits 3 packed
# all-to-alls where the per-plane path paid 6 (see bench_pfft).
print("d=1:", D.plan_pencil(4096, 1).describe().splitlines()[0])
print("d=8:", D.plan_pencil(1 << 18, 8).describe().splitlines()[0])

# ---- 14. the GPU backend: shared-memory-budgeted leaves, per-leaf fallback -
# `pallas_gpu` runs the SAME linearized pass programs through Pallas-on-
# Triton kernels, leaf by leaf.  Tiles are sized by the device's shared-
# memory budget (`limits.memory_budget`: 164 KiB on A100, 228 KiB on H100,
# 48 KiB for unknown GPUs — the paper's Fermi floor) instead of TPU VMEM;
# passes the Triton leaf can't run natively (strided columns) fall back to
# xla INSIDE the same plan — `pass_claims` names the executor per leaf, and
# describe() adds the GPU account: modeled global-memory round trips and
# peak shared-memory per block against the budget.  On this CPU host the
# kernels run in Pallas interpret mode; a real GPU wins negotiation and
# picks them up with zero code changes (tune="model"/"measure" decides the
# pallas↔xla crossover per device, seeded by repro/data/tuning_seed.json).
from repro.core import limits

with F.use_backend("pallas_gpu"):
    pg = F.plan(F.FFTSpec(n=131072))
print("per-leaf claims:", pg.pass_claims)          # ('xla', 'pallas_gpu')
print(pg.describe())                               # "...; gpu: N global round trips, ..."
xg = jax.random.normal(jax.random.PRNGKey(3), (2, 131072))
yg = pg(xg)                                        # real in → complex out
print("pallas_gpu matches jnp.fft:",
      bool(jnp.allclose(yg, jnp.fft.fft(xg), atol=1e-2)))
print("smem budget here:", limits.memory_budget() // 1024, "KiB;",
      "A100:", limits.memory_budget("NVIDIA A100-SXM4-40GB") // 1024, "KiB")

# ---- 15. arbitrary lengths: the Bluestein chirp-conv leaf ------------------
# FFTSpec takes ANY n ≥ 1 — primes, 3·2^k, whatever the pulse dictates.
# Non-pow2 lengths compile to Bluestein leaves: chirp pre-multiply, one
# cached pow2 convolution of length next_pow2(2n-1), chirp post-multiply —
# all fused into the same pass-program machinery (2 passes in the fused
# regime), with the chirp spectrum interned on the plan like twiddle LUTs.
pb = F.plan(F.FFTSpec(n=2029))                     # prime length
print(pb.describe())                               # "...; bluestein: pad 4096 (2.02x), ..."
xb = jax.random.normal(jax.random.PRNGKey(4), (2, 2029))
print("prime-n matches jnp.fft:",
      bool(jnp.allclose(pb(xb), jnp.fft.fft(xb), atol=1e-2)))
# rfft/irfft handle odd lengths too, and the roofline's bluestein_report
# costs the pad against a hypothetical mixed-radix transform.
from repro.analysis import roofline as rl

rep = rl.bluestein_report(2029)
print("bluestein tax: pad %d (%.2fx), %.1fx flops vs mixed-radix"
      % (rep["pad"], rep["pad_ratio"], rep["flops_overhead"]))

# ---- 16. fault tolerance: injection, per-leaf degradation, quarantine ------
# Every claimed pallas leaf executes under a retry→quarantine→fallback
# guard (`faults.run_leaf`): a leaf that fails twice is demoted to the
# traced-XLA execution of the SAME pass, the (backend, pass-kind) pair is
# quarantined for the process (warm re-plans skip the kernel entirely),
# and the plan advertises the demotion.  Inject a deterministic kernel
# fault — `inject_fault` in code, `REPRO_FAULTS=kernel.launch:64` from the
# environment — and watch the transform survive it:
from repro.core import faults

with F.use_backend("pallas"):
    pf = F.plan(F.FFTSpec(n=4096, batch_hint=2))
xf = jax.random.normal(jax.random.PRNGKey(5), (2, 4096))
with faults.inject_fault("kernel.launch", times=64):   # every attempt fails...
    yf = pf(xf)                                        # ...the call still succeeds
print("degraded leaf matches jnp.fft:",
      bool(jnp.allclose(yf, jnp.fft.fft(xf), atol=1e-2)))
print(pf.describe())                  # "...; DEGRADED: pass 0 fused4 (pallas→xla)"
print("quarantined:", faults.quarantined())
print("ledger:", faults.degradation_log())
# Opt-in numerics guards ride on execution: check="nan" scans the output,
# check="parseval" verifies energy conservation (NumericsError on drift).
pf2 = F.plan(F.FFTSpec(n=4096, batch_hint=2))
pf2(xf.astype(jnp.complex64), check="parseval")
# Demo only: lift the quarantine so later cells keep using the kernels.
faults.clear_quarantine()
faults.clear_degradations()
