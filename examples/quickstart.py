"""Quickstart: the memory-optimized FFT public API in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft as F
from repro.core import plan
from repro.core.conv import fft_conv

# ---- 1. plan inspection: the paper's kernel-call schedule -----------------
for n in (1024, 65536, 2**20):
    print(plan.describe(n))

# ---- 2. complex FFT, three backends ---------------------------------------
x = (np.random.randn(4, 4096) + 1j * np.random.randn(4, 4096)).astype(np.complex64)
for backend in ("stockham", "xla", "pallas"):  # pallas runs interpret on CPU
    y = F.fft(jnp.asarray(x), backend=backend)
    err = np.abs(np.asarray(y) - np.fft.fft(x)).max()
    print(f"backend={backend:9s} max err vs numpy: {err:.2e}")

# ---- 3. real FFT (half the work for real signals) --------------------------
sig = np.random.randn(2, 8192).astype(np.float32)
Xr, Xi = F.rfft(jnp.asarray(sig))
print("rfft bins:", Xr.shape, " roundtrip err:",
      float(jnp.abs(F.irfft((Xr, Xi), 8192) - sig).max()))

# ---- 4. FFT long convolution (the LM-layer integration) --------------------
u = np.random.randn(1, 16, 2048).astype(np.float32)   # (B, D, L)
h = np.random.randn(16, 2048).astype(np.float32)      # per-channel filters
y = fft_conv(jnp.asarray(u), jnp.asarray(h))
print("fft_conv out:", y.shape)

# ---- 5. under jit, composed with autodiff ----------------------------------
g = jax.grad(lambda v: jnp.sum(jnp.abs(F.fft(v)) ** 2))(jnp.asarray(x))
print("grad of spectral energy == 2N·conj(x):",
      bool(jnp.allclose(g, 2 * 4096 * jnp.conj(jnp.asarray(x)), rtol=1e-3)))
