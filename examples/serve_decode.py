"""Batched serving example: prefill once, decode with KV caches + sampling.

Also demonstrates the int8 quantized KV cache (the feature that makes the
72B-class decode cells fit 16 GB/chip — see EXPERIMENTS.md §Perf).

  PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.configs.reduce import make_reduced
from repro.models import model as M
from repro.serving.engine import Engine, ServeConfig

cfg = make_reduced(get_config("h2o-danube-1.8b"))
params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)

prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 4, cfg.vocab_size)

for kv_dtype in ("bf16", "int8"):
    c = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    eng = Engine(c, params, ServeConfig(max_new=24, temperature=0.8, top_k=40))
    t0 = time.time()
    out = eng.generate(prompts)
    out.block_until_ready()
    print(f"kv_cache={kv_dtype}: generated {out.shape} in {time.time()-t0:.1f}s; "
          f"first row: {out[0, :10].tolist()}")
