"""SAR image formation on the planned 2-D FFT API (paper §3 motivation).

Two scenes, each running through single plan handles end to end:

1. **Stripmap range–Doppler**: real raw returns are range-compressed with an
   LFM matched filter via ``fft_conv2d`` — one cached rfft2/irfft2 plan pair
   (the joint rows+columns program with the Hermitian epilogue) — then
   azimuth-compressed with a planned ``axis=-2`` FFT, the in-place column
   pass: no transposes anywhere in the pipeline.
2. **Spotlight (dechirped) phase history**: after dechirp-on-receive the
   image *is* the 2-D FFT of the phase history, so image formation is ONE
   planned ``fft2`` handle — the paper's headline scenario as a single
   compiled multi-axis pass program.

Each scene prints its plan schedule: pass count and modeled HBM GB.

  PYTHONPATH=src python examples/sar_imaging.py
"""

import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline as rl
from repro.core import fft as F
from repro.core.conv import fft_conv2d, next_pow2

rng = np.random.default_rng(0)


def report_scene(name: str, n_az: int, n_rg: int, note: str = "") -> None:
    rep = rl.fft_pass_report(n_rg, batch=1, n2=n_az)
    print(
        f"[{name}] {note or 'scene'} {n_az}x{n_rg}: "
        f"{rep['hbm_round_trips']} passes, "
        f"modeled HBM {rep['modeled_hbm_bytes'] / 1e9:.4f} GB"
    )


# ===========================================================================
# Scene 1 — stripmap: matched-filter range compression + azimuth FFT
# ===========================================================================
n_az, n_rg = 256, 2048          # azimuth pulses x range samples
chirp_len = 256

t = np.arange(chirp_len, dtype=np.float64)
chirp = np.cos(0.002 * t**2).astype(np.float32)        # real LFM pulse
matched = chirp[::-1].copy()                           # time-reversed filter

# Each target: a range-delayed chirp echo, cosine azimuth modulation.
targets = [(0.10, 500), (0.25, 1200), (0.40, 300)]     # (azimuth freq, range)
raw = np.zeros((n_az, n_rg), np.float32)
for fa, rg0 in targets:
    az_mod = np.cos(2 * np.pi * fa * np.arange(n_az)).astype(np.float32)
    raw[:, rg0 : rg0 + chirp_len] += az_mod[:, None] * chirp[None, :]
raw += rng.standard_normal(raw.shape).astype(np.float32) * 0.05

# Range compression: per-row matched filter as a (1, Lh) 2-D convolution —
# one rfft2/irfft2 plan pair, the joint program end to end.
rc = fft_conv2d(jnp.asarray(raw), jnp.asarray(matched)[None, :], mode="same")

# Azimuth compression: planned FFT down the pulse axis — the in-place
# strided-column pass (axis=-2), no swapaxes glue.
az_plan = F.plan(F.FFTSpec(n=n_az, kind="fft", axis=-2))
ar, ai = az_plan.apply_planes(rc, jnp.zeros_like(rc))
image1 = np.hypot(np.asarray(ar), np.asarray(ai))      # (az_freq, range)

# Report the transforms that actually ran: fft_conv2d's rfft2/irfft2 pair
# operates on the zero-padded linear-convolution image (each direction is
# one joint rows+cols program), and azimuth compression adds one more pass.
pad_az = next_pow2(n_az + 1 - 1)
pad_rg = next_pow2(n_rg + chirp_len - 1)
report_scene(
    "stripmap", pad_az, pad_rg,
    note="per transform of the matched-filter rfft2/irfft2 pair, padded",
)
print(f"[stripmap] + 1 azimuth pass (planned axis=-2 FFT, n={n_az})")
print("stripmap image:", image1.shape, "dynamic range: %.1f dB"
      % (20 * np.log10(image1.max() / (np.median(image1) + 1e-6))))
for fa, rg0 in targets:
    expect_rg = rg0 + chirp_len - 1                    # matched-filter peak
    lo, hi = expect_rg - 64, expect_rg + 64
    rg_peak = int(np.argmax(image1.max(axis=0)[lo:hi])) + lo
    az_col = image1[:, rg_peak]
    az_peak = int(np.argmax(az_col[1 : n_az // 2])) + 1  # skip DC, one side
    expect_az = int(round(fa * n_az))
    ok = abs(rg_peak - expect_rg) <= 8 and abs(az_peak - expect_az) <= 2
    print(f"  target (fa={fa:.2f}, rg={rg0:4d}): peak at "
          f"(az {az_peak:3d}/{expect_az:3d}, rg {rg_peak:4d}/{expect_rg:4d}) "
          f"{'OK' if ok else 'MISS'}")

# ===========================================================================
# Scene 2 — spotlight: dechirped phase history → ONE planned fft2
# ===========================================================================
n_az2, n_rg2 = 512, 4096
# After dechirp-on-receive each point target is a 2-D complex sinusoid whose
# frequency encodes its (azimuth, range) position.
targets2 = [(64, 700), (200, 2048), (400, 3500)]       # (az bin, rg bin)
a = np.arange(n_az2)[:, None]
r = np.arange(n_rg2)[None, :]
ph = np.zeros((n_az2, n_rg2), np.complex64)
for az0, rg0 in targets2:
    ph += np.exp(2j * np.pi * (az0 * a / n_az2 + rg0 * r / n_rg2)).astype(
        np.complex64
    )
ph += 0.05 * (
    rng.standard_normal(ph.shape) + 1j * rng.standard_normal(ph.shape)
).astype(np.complex64)

# One plan handle: the unified rows+columns pass program.
fft2_plan = F.plan(F.FFTSpec(n=n_rg2, kind="fft2", n2=n_az2))
print("\nspotlight plan:", fft2_plan.describe())
report_scene("spotlight", n_az2, n_rg2)
image2 = np.abs(np.asarray(fft2_plan(jnp.asarray(ph)))) / (n_az2 * n_rg2)

for az0, rg0 in targets2:
    az_pk, rg_pk = np.unravel_index(
        np.argmax(image2[az0 - 4 : az0 + 5, rg0 - 4 : rg0 + 5]), (9, 9)
    )
    ok = (az_pk, rg_pk) == (4, 4) and image2[az0, rg0] > 0.5
    print(f"  target (az={az0:3d}, rg={rg0:4d}): "
          f"|X|={image2[az0, rg0]:.2f} {'OK' if ok else 'MISS'}")

# ===========================================================================
# Scene 3 — prime range line: pulse-sized FFTs via the Bluestein leaf
# ===========================================================================
# Real radars size range lines to the pulse, not to 2^k (arXiv:1505.08067).
# A prime-length line used to be rejected by FFTSpec; it now plans as a
# Bluestein chirp-conv leaf, and fft_conv(pad='exact') keeps the spectrum
# bin-aligned to the true linear-convolution length.
n_rg3, chirp3 = 2029, 64                               # prime range samples
t3 = np.arange(chirp3, dtype=np.float64)
pulse3 = np.cos(0.01 * t3**2).astype(np.float32)
line = np.zeros((4, n_rg3), np.float32)
for row, rg0 in enumerate((173, 611, 1301, 1949)):
    line[row, rg0 : rg0 + chirp3] += pulse3[: max(0, min(chirp3, n_rg3 - rg0))]
line += rng.standard_normal(line.shape).astype(np.float32) * 0.02

from repro.core.conv import fft_conv

rc3 = fft_conv(jnp.asarray(line), jnp.asarray(pulse3[::-1].copy()),
               pad="exact")                            # n = 2092, non-pow2
rg3_plan = F.plan(F.FFTSpec(n=n_rg3, kind="fft"))
print("\nprime range-line plan:", rg3_plan.describe())
for row, rg0 in enumerate((173, 611, 1301, 1949)):
    pk = int(np.argmax(np.abs(np.asarray(rc3)[row])))
    expect = rg0 + chirp3 - 1
    ok = abs(pk - expect) <= 4
    print(f"  range line {row}: peak {pk:4d}/{expect:4d} "
          f"{'OK' if ok else 'MISS'}")
