"""SAR range–Doppler image formation with the repo FFT (paper §3 motivation).

Simulates raw returns of point scatterers, then: range compression (matched
filter via fft_conv) → azimuth FFT → image peak check.  Everything flows
through repro.core's memory-optimized transforms.

  PYTHONPATH=src python examples/sar_imaging.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import fft as F
from repro.core.fft_xla import cmul

# ---- simulate raw data ------------------------------------------------------
n_az, n_rg = 256, 2048           # azimuth pulses x range samples
chirp_len = 256
rng = np.random.default_rng(0)

t = np.arange(chirp_len, dtype=np.float32)
chirp = np.exp(1j * 0.002 * t**2).astype(np.complex64)  # LFM pulse

targets = [(64, 500), (128, 1200), (200, 300)]  # (azimuth, range) bins
raw = np.zeros((n_az, n_rg), np.complex64)
for az0, rg0 in targets:
    az_phase = np.exp(1j * 0.01 * (np.arange(n_az) - az0) ** 2)
    for a in range(n_az):
        seg = slice(rg0, rg0 + chirp_len)
        raw[a, seg] += az_phase[a] * chirp
raw += (rng.standard_normal(raw.shape) + 1j * rng.standard_normal(raw.shape)).astype(
    np.complex64
) * 0.05

# ---- range compression: matched filter in the frequency domain -------------
# Plan both transforms once (FFTW/cuFFT-style handles): one length-n_rg plan
# over range samples, one length-n_az plan over the azimuth (non-last) axis.
rg_plan = F.plan(F.FFTSpec(n=n_rg, kind="fft", batch_hint=n_az))
rg_iplan = F.plan(F.FFTSpec(n=n_rg, kind="ifft", batch_hint=n_az))
az_plan = F.plan(F.FFTSpec(n=n_az, kind="fft", axis=0))

xr, xi = jnp.asarray(raw.real), jnp.asarray(raw.imag)
# pad filter spectrum to range length by transforming the padded kernel
hpad = np.zeros(n_rg, np.complex64)
hpad[:chirp_len] = np.conj(chirp[::-1])
Hr, Hi = rg_plan((jnp.asarray(hpad.real), jnp.asarray(hpad.imag)))
Xr, Xi = rg_plan((xr, xi))
Yr, Yi = cmul(Xr, Xi, Hr[None, :], Hi[None, :])
rc_r, rc_i = rg_iplan((Yr, Yi))

# ---- azimuth compression: FFT across pulses + quadratic dechirp -------------
az = np.exp(-1j * 0.01 * (np.arange(n_az) - n_az / 2) ** 2).astype(np.complex64)
dr, di = cmul(rc_r, rc_i, jnp.asarray(az.real)[:, None], jnp.asarray(az.imag)[:, None])
ir, ii = az_plan((dr, di))  # axis-aware: transforms axis 0, no swapaxes
image = np.hypot(np.asarray(ir), np.asarray(ii))  # (az_freq, range)

# ---- verify: bright peaks near the injected targets' range bins -------------
print("image:", image.shape, "dynamic range: %.1f dB"
      % (20 * np.log10(image.max() / (np.median(image) + 1e-6))))
for az0, rg0 in targets:
    rg_peak = int(np.argmax(image.max(axis=0)[rg0 - 32 : rg0 + chirp_len + 32])) + rg0 - 32
    print(f"target at range bin {rg0:5d}: peak found at {rg_peak:5d} "
          f"({'OK' if abs(rg_peak - (rg0 + chirp_len - 1)) <= 8 else 'MISS'})")
