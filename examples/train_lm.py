"""End-to-end training driver: train a ~100M-class LM for a few hundred steps.

On this CPU container the default invocation trains the reduced xlstm-125m
config; pass --full (on a real accelerator) for the actual 125M model.
Demonstrates: synthetic data pipeline, AdamW + cosine schedule, microbatch
gradient accumulation, async checkpointing, crash-safe resume.

  PYTHONPATH=src python examples/train_lm.py               # ~2 min on CPU
  PYTHONPATH=src python examples/train_lm.py --steps 300 --arch h2o-danube-1.8b
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true", help="full-size config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--microbatches", "2",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--log-every", "20",
    ]
    if not args.full:
        argv.append("--reduced")
    losses = train_main(argv)
    print(f"trained {args.steps} steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
