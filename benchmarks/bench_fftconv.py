"""FFT long-convolution layer vs direct convolution — the LM integration.

Shows the O(L log L) crossover that justifies the spectral-mixer layers in
the SSM/hybrid configs, and benchmarks the spectral block forward itself.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.conv import fft_conv
from repro.models.layers import spectral
from repro.utils.params import unzip

LENGTHS = [256, 1024, 4096, 16384]


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _direct_conv(x, h):
    # causal direct conv via correlation with flipped kernel
    L = x.shape[-1]
    pad = h.shape[-1] - 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, 0)))
    return jax.lax.conv_general_dilated(
        xp[:, :, None, :], h[:, None, None, ::-1],
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=x.shape[1],
    )[:, :, 0, :L]


def main(emit=print):
    emit("fftconv.name,seq_len,filter_len,direct_ms,fft_ms,speedup")
    D = 8
    for L in LENGTHS:
        x = np.random.randn(2, D, L).astype(np.float32)
        h = np.random.randn(D, L).astype(np.float32)  # global filter
        f_fft = jax.jit(lambda a, b: fft_conv(a, b))
        f_dir = jax.jit(_direct_conv)
        t_f = _time(f_fft, jnp.asarray(x), jnp.asarray(h))
        t_d = _time(f_dir, jnp.asarray(x), jnp.asarray(h))
        emit(f"fftconv,{L},{L},{t_d*1e3:.2f},{t_f*1e3:.2f},{t_d/t_f:.2f}")

    emit("spectral_block.name,seq_len,fwd_ms")
    cfg = ModelConfig(d_model=128, spectral_filter_len=1024, vocab_size=64)
    params, _ = unzip(spectral.spectral_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    fwd = jax.jit(lambda p, x: spectral.spectral_forward(p, x, cfg=cfg))
    for L in (1024, 4096):
        x = jnp.asarray(np.random.randn(2, L, 128).astype(np.float32))
        emit(f"spectral_block,{L},{_time(fwd, params, x)*1e3:.2f}")


if __name__ == "__main__":
    main()
