"""FFT long-convolution: one-shot vs overlap-save vs direct — the LM path.

Sweeps (L, Lh) pairs through the three schedules the conv layer can take:

* ``one_shot``     — ``fft_conv(overlap_save=False)``: ONE padded transform
  of ``next_pow2(L + Lh - 1)`` (split-regime pass program for long signals);
* ``overlap_save`` — ``fft_conv_os``: fused-regime blocks batched through
  one cached plan pair (the Adámek et al. schedule on the planned-FFT API);
* ``direct``       — ``jnp.convolve`` (O(L·Lh); skipped once L·Lh is large
  enough to dwarf the FFT paths — the crossover is the point).

Each row carries ``analysis.roofline.conv_report``'s modeled HBM bytes for
both FFT schedules so the measured ratio can be read against the model, and
full runs append a ``BENCH_conv.json`` trajectory entry so later PRs can
track the overlap-save speedup against this baseline.  ``--smoke`` runs a
tiny sweep and cross-checks the two FFT paths against each other, so CI
exercises the overlap-save engine end to end.

  PYTHONPATH=src python -m benchmarks.bench_fftconv [--smoke]
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.analysis import roofline as rl
from repro.configs.base import ModelConfig
from repro.core.conv import fft_conv
from repro.core.overlap import fft_conv_os
from repro.models.layers import spectral
from repro.utils.params import unzip

# (L, Lh): filter lengths are the odd Hyena/SAR-style taps, signals span the
# fused regime up to the 1M-sample split regime overlap-save exists for.
SWEEP = [(2**14, 257), (2**16, 1025), (2**18, 4097), (2**20, 4097)]
SMOKE_SWEEP = [(2**12, 129)]

#: jnp.convolve is O(L·Lh); beyond this many MACs per row it only adds
#: minutes to the sweep without informing the crossover.
DIRECT_MAC_LIMIT = 2**28

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_conv.json")


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(sweep, reps=3, batch=4, check=False):
    rows = []
    for L, Lh in sweep:
        x = jnp.asarray(np.random.randn(batch, L).astype(np.float32))
        h = jnp.asarray(np.random.randn(Lh).astype(np.float32))
        # xla backend: same arithmetic as the Pallas kernels, which are
        # TPU-targeted — interpret-mode timing is meaningless.
        f_one = jax.jit(lambda a, b: fft_conv(a, b, backend="xla", overlap_save=False))
        f_os = jax.jit(lambda a, b: fft_conv_os(a, b, backend="xla"))
        report = rl.conv_report(L, Lh, batch=batch)
        row = {
            "L": L,
            "Lh": Lh,
            "batch": batch,
            "one_shot_us": _time(f_one, x, h, reps=reps) * 1e6,
            "overlap_save_us": _time(f_os, x, h, reps=reps) * 1e6,
            "block": report["overlap_save"]["block"],
            "num_blocks": report["overlap_save"]["num_blocks"],
            "modeled_one_shot_gb": report["one_shot"]["hbm_bytes"] / 1e9,
            "modeled_os_gb": report["overlap_save"]["hbm_bytes"] / 1e9,
        }
        if L * Lh <= DIRECT_MAC_LIMIT:
            f_dir = jax.jit(
                jax.vmap(lambda a, b: jnp.convolve(a, b, mode="full")[:L], (0, None))
            )
            row["direct_us"] = _time(f_dir, x, h, reps=reps) * 1e6
        if check:
            err = float(
                jnp.abs(f_one(x, h) - f_os(x, h)).max() / jnp.abs(f_one(x, h)).max()
            )
            assert err < 1e-4, f"overlap-save disagrees with one-shot: {err}"
            row["os_vs_one_shot_rel_err"] = err
        rows.append(row)
    return rows


def _spectral_block(emit):
    emit("spectral_block.name,seq_len,fwd_ms")
    cfg = ModelConfig(d_model=128, spectral_filter_len=1024, vocab_size=64)
    params, _ = unzip(spectral.spectral_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    fwd = jax.jit(lambda p, x: spectral.spectral_forward(p, x, cfg=cfg))
    for L in (1024, 4096):
        x = jnp.asarray(np.random.randn(2, L, 128).astype(np.float32))
        emit(f"spectral_block,{L},{_time(fwd, params, x)*1e3:.2f}")


def main(emit=print, smoke: bool = False):
    sweep = SMOKE_SWEEP if smoke else SWEEP
    reps = 2 if smoke else 3
    emit(
        "fftconv.name,seq_len,filter_len,block,num_blocks,direct_ms,"
        "one_shot_ms,overlap_save_ms,modeled_one_shot_gb,modeled_os_gb"
    )
    rows = run(sweep, reps=reps, batch=2 if smoke else 4, check=smoke)
    for r in rows:
        direct = f"{r['direct_us']/1e3:.2f}" if "direct_us" in r else ""
        emit(
            f"fftconv,{r['L']},{r['Lh']},{r['block']},{r['num_blocks']},"
            f"{direct},{r['one_shot_us']/1e3:.2f},{r['overlap_save_us']/1e3:.2f},"
            f"{r['modeled_one_shot_gb']:.4f},{r['modeled_os_gb']:.4f}"
        )
    if smoke:
        return
    _spectral_block(emit)
    append_trajectory(TRAJECTORY, conv=rows)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
