"""Distributed pencil-FFT scaling sweep — packed/overlapped vs serial.

Runs the pencil path at 8/16/32/48 fake devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``, the
test-suite's subprocess idiom, one fresh process per point so the device
count can vary) and times two schedules on the same shape:

* ``serial`` — the historical per-plane schedule (``pack=False``): two
  all-to-alls per transpose step, every collective serialized against the
  local FFT work;
* ``tuned`` — :func:`repro.core.distributed.plan_pencil`'s modeled pick:
  split-complex pair packed into ONE stacked a2a per transpose, the two
  inner transposes strip-mined into K chunks and double-buffered against
  the column FFT/twiddle.

48 devices is not a power of two, so that point runs a 3×16 data×model
mesh (batch sharded 3-way, the transform pencil-split over 16) — the
realistic pod shape where the FFT axis is a power-of-two sub-mesh.

Each row records both wall-clocks, the tuned schedule (n1×n2, K), the
jaxpr-verified collective counts, and the roofline comm model
(``comm_mb_step`` per-transpose wire bytes, ``local_hbm_mb``,
``modeled_s``/``serial_modeled_s`` — :func:`repro.analysis.roofline.
pencil_report`).  Full runs append a ``BENCH_pfft.json`` trajectory
entry.  ``--smoke`` runs one 16-device point with small N, asserts
numerics + collective counts, and skips the trajectory — the CI contract.

  PYTHONPATH=src python -m benchmarks.bench_pfft [--smoke]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks._trajectory import append_trajectory

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_pfft.json")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

#: (devices, data_parallel, fft_shards, n, batch) — the scaling sweep.
SWEEP = [
    (8, 1, 8, 1 << 18, 4),
    (16, 1, 16, 1 << 18, 4),
    (32, 1, 32, 1 << 18, 4),
    (48, 3, 16, 1 << 18, 6),
]
SMOKE_SWEEP = [(16, 1, 16, 1 << 14, 2)]

_CHILD = r"""
import json, sys, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import distributed as D

dp, d, n, batch, reps = (int(a) for a in sys.argv[1:6])
axes = ("b", "x")
mesh = jax.make_mesh((dp, d), axes)
pl = D.plan_pencil(n, d)

spec = P("b", "x")


def make(**kw):
    fn = D.shard_map_compat(
        lambda xr, xi: D.pfft(
            xr, xi, n=n, axis_name="x", num_shards=d, **kw
        ),
        mesh, in_specs=(spec, spec), out_specs=(spec, spec))
    return jax.jit(fn)


serial = make(pack=False)
tuned = make()  # the modeled pick: packed, K chunks

rng = np.random.default_rng(0)
x = rng.standard_normal((batch, n)).astype(np.float32)
sh = jax.sharding.NamedSharding(mesh, spec)
xr = jax.device_put(x, sh)
xi = jax.device_put(np.zeros_like(x), sh)

# correctness first: both schedules against numpy
ref = np.fft.fft(x)
for name, fn in (("serial", serial), ("tuned", tuned)):
    yr, yi = fn(xr, xi)
    rel = (np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
           / np.abs(ref).max())
    assert rel < 5e-5, (name, rel)

# jaxpr-verified collective counts (what the packing/overlap bought)
a2a_serial = str(jax.make_jaxpr(serial)(xr, xi)).count("all_to_all")
a2a_tuned = str(jax.make_jaxpr(tuned)(xr, xi)).count("all_to_all")
assert a2a_serial == 6, a2a_serial
assert a2a_tuned == 2 * pl.a2a_chunks + 1, (a2a_tuned, pl.a2a_chunks)


def time_pair(fa, fb):
    for _ in range(2):
        jax.block_until_ready(fa(xr, xi))
        jax.block_until_ready(fb(xr, xi))
    ta = tb = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fa(xr, xi))
        ta = min(ta, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb(xr, xi))
        tb = min(tb, time.perf_counter() - t0)
    return ta, tb


t_serial, t_tuned = time_pair(serial, tuned)
rep = pl.report
print("ROW=" + json.dumps({
    "devices": dp * d, "mesh": f"{dp}x{d}", "fft_shards": d,
    "n": n, "batch": batch,
    "n1": pl.n1, "n2": pl.n2, "K": pl.a2a_chunks,
    "a2a_serial": a2a_serial, "a2a_tuned": a2a_tuned,
    "t_serial_s": t_serial, "t_tuned_s": t_tuned,
    "speedup": t_serial / t_tuned,
    "comm_mb_step": rep["comm_bytes_per_step"] / 2**20,
    "local_hbm_mb": rep["local_hbm_bytes"] / 2**20,
    "modeled_s": rep["modeled_s"], "serial_modeled_s": rep["serial_s"],
}))
"""


def _run_point(devices, dp, d, n, batch, reps) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, str(dp), str(d), str(n), str(batch),
         str(reps)],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_pfft child ({devices} devices) failed:\n{out.stderr}"
        )
    line = next(
        ln for ln in out.stdout.splitlines() if ln.startswith("ROW=")
    )
    return json.loads(line[len("ROW="):])


def run(sweep, reps=5) -> list:
    rows = []
    print(
        "pfft,devices,mesh,n,batch,n1,n2,K,a2a_serial,a2a_tuned,"
        "t_serial_s,t_tuned_s,speedup,comm_mb_step,modeled_s"
    )
    for devices, dp, d, n, batch in sweep:
        row = _run_point(devices, dp, d, n, batch, reps)
        rows.append(row)
        print(
            f"pfft,{row['devices']},{row['mesh']},{row['n']},{row['batch']},"
            f"{row['n1']},{row['n2']},{row['K']},{row['a2a_serial']},"
            f"{row['a2a_tuned']},{row['t_serial_s']:.4f},"
            f"{row['t_tuned_s']:.4f},{row['speedup']:.2f},"
            f"{row['comm_mb_step']:.3f},{row['modeled_s']:.2e}",
            flush=True,
        )
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        rows = run(SMOKE_SWEEP, reps=3)
        for row in rows:
            assert row["a2a_tuned"] < row["a2a_serial"], row
        print("bench_pfft smoke ok")
        return
    rows = run(SWEEP)
    slow = [r for r in rows if r["t_tuned_s"] > r["t_serial_s"]]
    if slow:
        print(f"# WARNING: tuned slower at {[r['devices'] for r in slow]}")
    append_trajectory(TRAJECTORY, rows=rows)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
