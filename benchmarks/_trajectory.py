"""Shared benchmark-trajectory append: one JSON history file per suite.

Each full benchmark run appends one timestamped entry to its
``BENCH_*.json`` so later PRs can diff numbers against this PR's baseline
on the same host.  One implementation for all suites, so format/robustness
changes (e.g. the corrupt-history fallback) happen in one place.
"""

from __future__ import annotations

import json
import os
import time

import jax


def device_provenance() -> dict:
    """``{backend, device_kind}`` of the device this process would run on —
    recorded in every trajectory row so a number diffed across PRs is only
    ever compared against the same silicon (an A100 row and a CPU row of
    the same suite are different baselines, not a regression)."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - no devices visible
        kind = "unknown"
    return {"backend": jax.default_backend(), "device_kind": kind}


def load_trajectory(path: str) -> list:
    """The JSON history list at ``path``, tolerantly: unreadable/corrupt
    history starts fresh, and rows written before device provenance existed
    (pre-PR-8 ``BENCH_*.json``) are backfilled with
    ``device_kind``/``backend`` of ``"unknown"`` instead of KeyError-ing
    whichever bench script re-appends to the old trajectory."""
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    if not isinstance(history, list):
        history = []
    for row in history:
        if isinstance(row, dict):
            row.setdefault("device_kind", "unknown")
            row.setdefault("backend", "unknown")
    return [row for row in history if isinstance(row, dict)]


def append_trajectory(path: str, **payload) -> None:
    """Append ``{timestamp, backend, device_kind, **payload}`` to the JSON
    list at ``path`` (created if missing; unreadable history starts
    fresh)."""
    entry = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        **device_provenance(),
        **payload,
    }
    path = os.path.abspath(path)
    history = load_trajectory(path)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
