"""Shared benchmark-trajectory append: one JSON history file per suite.

Each full benchmark run appends one timestamped entry to its
``BENCH_*.json`` so later PRs can diff numbers against this PR's baseline
on the same host.  One implementation for all suites, so format/robustness
changes (e.g. the corrupt-history fallback) happen in one place.
"""

from __future__ import annotations

import json
import os
import time

import jax


def append_trajectory(path: str, **payload) -> None:
    """Append ``{timestamp, backend, **payload}`` to the JSON list at
    ``path`` (created if missing; unreadable history starts fresh)."""
    entry = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        **payload,
    }
    path = os.path.abspath(path)
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
