"""Autotuned vs fixed-heuristic overlap-save — the tuner's ledger.

Sweeps the ``BENCH_conv.json`` / ``BENCH_fft.json`` long-conv shapes
(L ∈ 2¹⁸..2²⁰, Lh ∈ {1025, 4097}) through two block policies:

* ``fixed`` — the historical ``OS_FACTOR=8`` heuristic block
  (:func:`repro.core.overlap.pick_block`);
* ``tuned`` — ``tune="measure"``: the roofline model prunes the block
  candidates to the few within ~20% of modeled-minimum HBM bytes, the
  measurement pass times them (fixed heuristic always included, so tuned
  can never lose), and the winner lands in the persistent tuning cache.

Each row records both blocks, both wall-clocks, the measured speedup and
the modeled HBM bytes of both schedules; full runs append a
``BENCH_tuning.json`` trajectory entry.  ``--smoke`` runs a tiny shape,
cross-checks tuned == fixed numerics, and asserts the tune="model" cache
round-trips deterministically (same spec → same config, cache hit on the
second plan, zero measurements) — the CI contract.

  PYTHONPATH=src python -m benchmarks.bench_tuning [--smoke]
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.analysis import roofline as rl
from repro.core import fft as fft_lib
from repro.core import tuning
from repro.core.overlap import fft_conv_os, pick_block

# The acceptance sweep: the bench_fftconv shapes the tuner must never lose
# on, spanning the auto-routed overlap-save regime.
SWEEP = [
    (2**18, 1025), (2**18, 4097),
    (2**19, 1025), (2**19, 4097),
    (2**20, 1025), (2**20, 4097),
]
SMOKE_SWEEP = [(2**13, 129)]

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_tuning.json")


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_pair(fa, fb, *args, reps=3, warmup=1) -> tuple:
    """Interleaved A/B min-of-reps so machine drift (frequency scaling,
    background load) hits both policies alike instead of whichever ran
    second."""
    for _ in range(warmup):
        jax.block_until_ready(fa(*args))
        jax.block_until_ready(fb(*args))
    ta = tb = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fa(*args))
        ta = min(ta, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb(*args))
        tb = min(tb, time.perf_counter() - t0)
    return ta, tb


def run(sweep, reps=3, batch=4):
    rows = []
    for L, Lh in sweep:
        x = jnp.asarray(np.random.randn(batch, L).astype(np.float32))
        h = jnp.asarray(np.random.randn(Lh).astype(np.float32))
        fixed = pick_block(Lh)
        # the measured winner (persistent: a warm cache skips the search)
        tuned = tuning.tuned_block(L, Lh, batch, "xla", "measure")
        f_fixed = jax.jit(
            lambda a, b, blk=fixed: fft_conv_os(a, b, block=blk, backend="xla")
        )
        f_tuned = jax.jit(
            lambda a, b, blk=tuned: fft_conv_os(a, b, block=blk, backend="xla")
        )
        if tuned == fixed:
            # Identical schedule — timing it twice only manufactures noise.
            fixed_s = tuned_s = _time(f_fixed, x, h, reps=reps)
        else:
            fixed_s, tuned_s = _time_pair(f_fixed, f_tuned, x, h, reps=reps)
        rows.append(
            {
                "L": L,
                "Lh": Lh,
                "batch": batch,
                "fixed_block": fixed,
                "tuned_block": tuned,
                "fixed_us": fixed_s * 1e6,
                "tuned_us": tuned_s * 1e6,
                "speedup": fixed_s / tuned_s if tuned_s else float("inf"),
                "modeled_fixed_gb": rl.conv_report(L, Lh, batch=batch, block=fixed)[
                    "overlap_save"
                ]["hbm_bytes"]
                / 1e9,
                "modeled_tuned_gb": rl.conv_report(L, Lh, batch=batch, block=tuned)[
                    "overlap_save"
                ]["hbm_bytes"]
                / 1e9,
            }
        )
    return rows


def _assert_model_cache_round_trip():
    """The CI contract: tune="model" is deterministic and cache-backed —
    same spec → same config, cache hit on the second plan, and the model
    path never touches the device timer."""
    tuning.clear_measure_log()
    spec = fft_lib.FFTSpec(n=2**17, kind="fft")
    cfg1 = fft_lib.plan(spec, backend="pallas", tune="model").tuned
    assert cfg1 is not None, "model mode must produce a tuned config"
    # a fresh interning cache forces plan() back through the tuner, which
    # must now hit the persisted entry and return the identical config
    fft_lib._plan_cached.cache_clear()
    cfg2 = fft_lib.plan(spec, backend="pallas", tune="model").tuned
    assert cfg1 == cfg2, (cfg1, cfg2)
    b1 = tuning.tuned_block(2**18, 1025, 2, "xla", "model")
    b2 = tuning.tuned_block(2**18, 1025, 2, "xla", "model")
    assert b1 == b2
    assert tuning.measure_log() == (), "model mode measured something"
    print(f"tuning.model_cache_round_trip,ok,block={b1}")


def main(emit=print, smoke: bool = False):
    sweep = SMOKE_SWEEP if smoke else SWEEP
    emit(
        "tuning.name,seq_len,filter_len,fixed_block,tuned_block,"
        "fixed_ms,tuned_ms,speedup,modeled_fixed_gb,modeled_tuned_gb"
    )
    rows = run(sweep, reps=2 if smoke else 3, batch=2 if smoke else 4)
    for r in rows:
        emit(
            f"tuning,{r['L']},{r['Lh']},{r['fixed_block']},{r['tuned_block']},"
            f"{r['fixed_us']/1e3:.2f},{r['tuned_us']/1e3:.2f},"
            f"{r['speedup']:.3f},{r['modeled_fixed_gb']:.4f},"
            f"{r['modeled_tuned_gb']:.4f}"
        )
    if smoke:
        # numerics: the tuned block changes the schedule, never the math
        L, Lh = SMOKE_SWEEP[0]
        x = jnp.asarray(np.random.randn(2, L).astype(np.float32))
        h = jnp.asarray(np.random.randn(Lh).astype(np.float32))
        y_f = fft_conv_os(x, h, block=pick_block(Lh), backend="xla")
        y_t = fft_conv_os(x, h, backend="xla", tune="measure")
        err = float(jnp.abs(y_f - y_t).max() / jnp.abs(y_f).max())
        assert err < 1e-4, f"tuned overlap-save diverged: {err}"
        _assert_model_cache_round_trip()
        return
    append_trajectory(TRAJECTORY, tuning=rows)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
