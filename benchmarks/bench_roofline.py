"""Roofline summary from the dry-run artifacts (reads artifacts/dryrun/*).

Emits the per-cell three-term roofline as CSV — the same numbers
EXPERIMENTS.md §Roofline tabulates.  Run the dry-run first:

  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def main(emit=print):
    emit(
        "roofline.arch,shape,mesh,compute_ms,memory_ms,collective_ms,"
        "bound,peak_mem_gb,fits_hbm,useful_flops_frac"
    )
    files = sorted(glob.glob(os.path.join(ART, "*.json")))
    if not files:
        emit("# no dry-run artifacts found — run repro.launch.dryrun first")
        return
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            emit(f"# FAILED {r['arch']},{r['shape']},{r['mesh']}: {r.get('error','')[:60]}")
            continue
        t = r["roofline"]
        emit(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']*1e3:.2f},{t['memory_s']*1e3:.2f},"
            f"{t['collective_s']*1e3:.2f},{t['bound']},"
            f"{r['per_chip']['peak_memory_bytes']/1e9:.2f},{r['fits_hbm']},"
            f"{r.get('useful_flops_frac', 0):.3f}"
        )


if __name__ == "__main__":
    main()
