"""pallas_gpu vs xla on the acceptance FFT sweep — the crossover's ledger.

Times the Triton-shaped claimed-leaf executor (``backend="pallas_gpu"``)
against plain XLA over the 1-D acceptance sizes, alongside both backends'
modeled global-memory bytes (:func:`repro.analysis.roofline.
gpu_program_report` vs :func:`~repro.analysis.roofline.xla_gpu_fft_bytes`)
and the tuner's crossover verdict (``tuning.backend_pick``), so each
``BENCH_gpu.json`` row shows what the model predicted next to what the
clock said on this device_kind.

On a CPU host the kernels run in Pallas interpret mode (set automatically,
or force with ``REPRO_PALLAS_INTERPRET=1``), so wall-clocks are only
meaningful on a real GPU — the smoke mode therefore checks numerics,
per-leaf claims, and the model's report, never relative speed.

  PYTHONPATH=src python -m benchmarks.bench_gpu [--smoke]
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.analysis import roofline as rl
from repro.core import fft as fft_lib
from repro.core import limits
from repro.core import plan as plan_lib
from repro.core import tuning
from repro.kernels.fft_gpu import gpu_claims

SWEEP = [256, 4096, 131072, 262144]
SMOKE_SWEEP = [256, 4096]

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_gpu.json")


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(sweep, reps=3, batch=4):
    rows = []
    for n in sweep:
        spec = fft_lib.FFTSpec(n=n, kind="fft", batch_hint=batch)
        p_gpu = fft_lib.plan(spec, backend="pallas_gpu", tune="off")
        p_xla = fft_lib.plan(spec, backend="xla", tune="off")
        x = jnp.asarray(np.random.randn(batch, n).astype(np.float32))
        zi = jnp.zeros_like(x)
        f_gpu = jax.jit(lambda a, b, p=p_gpu: p.apply_planes(a, b))
        f_xla = jax.jit(lambda a, b, p=p_xla: p.apply_planes(a, b))
        gpu_s = _time(f_gpu, x, zi, reps=reps)
        xla_s = _time(f_xla, x, zi, reps=reps)
        rep = rl.gpu_program_report(
            plan_lib.plan_fft(n).passes, gpu_claims, batch=batch
        )
        rows.append(
            {
                "n": n,
                "batch": batch,
                "claims": list(p_gpu.pass_claims),
                "pallas_gpu_us": gpu_s * 1e6,
                "xla_us": xla_s * 1e6,
                "speedup": xla_s / gpu_s if gpu_s else float("inf"),
                "smem_kib_max": rep["smem_bytes_max"] / 1024,
                "smem_budget_kib": rep["smem_budget"] / 1024,
                "global_round_trips": rep["global_round_trips"],
                "modeled_gpu_gb": rep["modeled_global_bytes"] / 1e9,
                "modeled_xla_gb": rl.xla_gpu_fft_bytes(n, batch) / 1e9,
                "tuner_pick": tuning.backend_pick(spec, jax.default_backend(), "model"),
            }
        )
    return rows


def _assert_numerics(n: int, batch: int = 2) -> None:
    """pallas_gpu must match xla at 1e-3 whatever subset of passes it
    claims — the fallback leaves run inside the same plan."""
    spec = fft_lib.FFTSpec(n=n, kind="fft")
    p_gpu = fft_lib.plan(spec, backend="pallas_gpu", tune="off")
    x = jnp.asarray(np.random.default_rng(0).standard_normal((batch, n)), jnp.float32)
    yr, yi = p_gpu.apply_planes(x, jnp.zeros_like(x))
    ref = np.fft.fft(np.asarray(x))
    err = float(
        np.max(np.abs(np.asarray(yr) + 1j * np.asarray(yi) - ref))
        / np.max(np.abs(ref))
    )
    assert err < 1e-3, f"pallas_gpu diverged from reference at n={n}: {err}"


def main(emit=print, smoke: bool = False):
    sweep = SMOKE_SWEEP if smoke else SWEEP
    emit(
        "gpu.name,n,claims,pallas_gpu_ms,xla_ms,speedup,"
        "smem_kib,smem_budget_kib,round_trips,modeled_gpu_gb,modeled_xla_gb,pick"
    )
    rows = run(sweep, reps=2 if smoke else 3, batch=2 if smoke else 4)
    for r in rows:
        emit(
            f"gpu,{r['n']},{'+'.join(r['claims'])},"
            f"{r['pallas_gpu_us']/1e3:.2f},{r['xla_us']/1e3:.2f},"
            f"{r['speedup']:.3f},{r['smem_kib_max']:.0f},"
            f"{r['smem_budget_kib']:.0f},{r['global_round_trips']},"
            f"{r['modeled_gpu_gb']:.4f},{r['modeled_xla_gb']:.4f},"
            f"{r['tuner_pick']}"
        )
    if smoke:
        for n in sweep:
            _assert_numerics(n)
        # the mixed plan: a strided-column pass the GPU leaf disclaims must
        # fall back to xla inside the same planned call
        claims = fft_lib.plan(
            fft_lib.FFTSpec(n=131072), backend="pallas_gpu", tune="off"
        ).pass_claims
        assert "xla" in claims and "pallas_gpu" in claims, claims
        _assert_numerics(131072)
        print(
            f"gpu.smoke,ok,budget_kib="
            f"{limits.memory_budget() / 1024:.0f}"
        )
        return
    append_trajectory(TRAJECTORY, gpu=rows)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
