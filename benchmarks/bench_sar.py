"""SAR-representative workload (paper §3 motivation): batched 2-D transforms.

Range/azimuth FFTs over a radar scene — "the data scale of FFT operation is
from a few thousands to tens of thousands" (paper).  Measures the full 2-D
pipeline (rows+columns) for our four-step backend vs jnp.fft.fft2, plus the
rfft real-packing path on real-valued raw returns (beyond-paper win: the
paper only handles complex signals).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft as F
from repro.core.conv import fft_conv

SCENES = [(512, 2048), (1024, 4096), (2048, 8192)]


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def main(emit=print):
    emit("sar.name,rows,cols,jnp_fft2_ms,ours_fft2_ms,ours_rfft_rows_ms")
    for rows, cols in SCENES:
        x = (np.random.randn(rows, cols) + 1j * np.random.randn(rows, cols)).astype(
            np.complex64
        )
        xr = np.random.randn(rows, cols).astype(np.float32)
        xj = jnp.asarray(x)
        xrj = jnp.asarray(xr)
        p_fft2 = F.plan(
            F.FFTSpec(n=cols, kind="fft2", n2=rows, batch_hint=rows), backend="xla"
        )
        p_rfft = F.plan(
            F.FFTSpec(n=cols, kind="rfft", batch_hint=rows), backend="xla"
        )
        f_ours = jax.jit(lambda v: p_fft2(v))
        f_jnp = jax.jit(jnp.fft.fft2)
        f_rfft = jax.jit(lambda v: p_rfft(v))
        t_o = _time(f_ours, xj)
        t_j = _time(f_jnp, xj)
        t_r = _time(f_rfft, xrj)
        emit(f"sar,{rows},{cols},{t_j*1e3:.2f},{t_o*1e3:.2f},{t_r*1e3:.2f}")

    # range-compression step: matched filter via fft_conv (the actual SAR op)
    emit("sar_conv.name,rows,cols,filter_len,fftconv_ms")
    for rows, cols in SCENES[:2]:
        x = np.random.randn(rows, cols).astype(np.float32)
        h = np.random.randn(1, 256).astype(np.float32)
        fc = jax.jit(lambda a, b: fft_conv(a, b))
        t = _time(fc, jnp.asarray(x), jnp.asarray(h))
        emit(f"sar_conv,{rows},{cols},256,{t*1e3:.2f}")


if __name__ == "__main__":
    main()
