"""SAR-representative workload (paper §3 motivation): batched 2-D transforms.

Range/azimuth FFTs over a radar scene — "the data scale of FFT operation is
from a few thousands to tens of thousands" (paper).  Every scene runs through
the planned 2-D API: ``fft2`` is ONE joint rows+columns pass program (no
transposes between the axes), ``rfft2`` is the real-packing variant for
real-valued raw returns (beyond-paper: the paper only handles complex
signals), and the range-compression matched filter is ``fft_conv2d`` — an
rfft2/irfft2 plan pair.  Each row reports the plan's pass count and modeled
HBM GB next to wall-clock vs the ``jnp.fft.fft2`` stand-in, and full runs
append a ``BENCH_sar.json`` trajectory entry so later PRs can track the
2-D-program speedup against this baseline.

  PYTHONPATH=src python -m benchmarks.bench_sar [--smoke]
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.analysis import roofline as rl
from repro.core import fft as F
from repro.core.conv import fft_conv2d

SCENES = [(512, 2048), (1024, 4096), (2048, 8192)]
SMOKE_SCENES = [(128, 512)]
FILTER_LEN = 256

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_sar.json")


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(scenes, reps=3):
    rows = []
    for n_az, n_rg in scenes:
        x = (np.random.randn(n_az, n_rg) + 1j * np.random.randn(n_az, n_rg)).astype(
            np.complex64
        )
        xreal = np.random.randn(n_az, n_rg).astype(np.float32)
        xj, xrj = jnp.asarray(x), jnp.asarray(xreal)
        # The joint 2-D program (timed on the xla backend: same arithmetic
        # as the Pallas kernels, which are TPU-targeted — interpret-mode
        # timing is meaningless, see EXPERIMENTS.md).
        p_fft2 = F.plan(F.FFTSpec(n=n_rg, kind="fft2", n2=n_az), backend="xla")
        p_rfft2 = F.plan(F.FFTSpec(n=n_rg, kind="rfft2", n2=n_az), backend="xla")
        f_ours = jax.jit(lambda v: p_fft2(v))
        f_jnp = jax.jit(jnp.fft.fft2)
        f_r2 = jax.jit(lambda v: p_rfft2(v))
        report = rl.fft_pass_report(n_rg, batch=1, n2=n_az)
        rows.append(
            {
                "rows": n_az,
                "cols": n_rg,
                "jnp_fft2_us": _time(f_jnp, xj, reps=reps) * 1e6,
                "ours_fft2_us": _time(f_ours, xj, reps=reps) * 1e6,
                "ours_rfft2_us": _time(f_r2, xrj, reps=reps) * 1e6,
                "passes": report["hbm_round_trips"],
                "modeled_hbm_gb": report["modeled_hbm_bytes"] / 1e9,
            }
        )
    return rows


def run_conv(scenes, reps=3):
    """Range-compression matched filter: fft_conv2d (rfft2/irfft2 pair)."""
    rows = []
    for n_az, n_rg in scenes:
        x = np.random.randn(n_az, n_rg).astype(np.float32)
        h = np.random.randn(1, FILTER_LEN).astype(np.float32)
        fc = jax.jit(lambda a, b: fft_conv2d(a, b, backend="xla"))
        t = _time(fc, jnp.asarray(x), jnp.asarray(h), reps=reps)
        rows.append(
            {"rows": n_az, "cols": n_rg, "filter": FILTER_LEN, "us": t * 1e6}
        )
    return rows


def _append_trajectory(fft_rows, conv_rows) -> None:
    """BENCH_sar.json: one entry per run, so later PRs can diff the 2-D
    program numbers against this PR's baseline on the same host."""
    append_trajectory(TRAJECTORY, fft2=fft_rows, range_conv=conv_rows)


def main(emit=print, smoke: bool = False):
    scenes = SMOKE_SCENES if smoke else SCENES
    reps = 2 if smoke else 3
    emit("sar.name,rows,cols,jnp_fft2_ms,ours_fft2_ms,ours_rfft2_ms,"
         "plan_passes,modeled_hbm_gb")
    fft_rows = run(scenes, reps=reps)
    for r in fft_rows:
        emit(
            f"sar,{r['rows']},{r['cols']},{r['jnp_fft2_us']/1e3:.2f},"
            f"{r['ours_fft2_us']/1e3:.2f},{r['ours_rfft2_us']/1e3:.2f},"
            f"{r['passes']},{r['modeled_hbm_gb']:.4f}"
        )
    emit("sar_conv.name,rows,cols,filter_len,fftconv2d_ms")
    conv_rows = run_conv(scenes if smoke else scenes[:2], reps=reps)
    for r in conv_rows:
        emit(f"sar_conv,{r['rows']},{r['cols']},{r['filter']},{r['us']/1e3:.2f}")
    if not smoke:
        _append_trajectory(fft_rows, conv_rows)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
