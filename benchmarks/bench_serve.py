"""Serving microbenchmark: prefill / insert / generate timed separately.

Decode-microbenchmark in the maxtext style: each serving phase is timed on
its own (prompt prefill, slot insert, scan generate) and throughput is
swept over batch sizes, all through the one measurement path the CLI also
uses (:func:`repro.serving.spectral_serve.sweep_once`).

Before any timing, two gates must pass:

* **numerics** — streamed spectral decode must match the one-shot
  ``spectral_forward`` to 1e-3 (full mode checks a prompt PAST the fused
  FFT regime, so prefill provably routes through overlap-save), and
  stream-mode greedy generation must equal the ring-buffer oracle
  token-for-token;
* **plan discipline** — a warm serving sweep must create ZERO new FFT
  plans (``core.fft.plan_log()``): every spectral flush inside the scan
  reuses the plan cached at trace time.

Full runs append a ``BENCH_serve.json`` trajectory entry (per-phase
seconds, decode and end-to-end tokens/sec per batch size, and the spectral
stream plan metadata).  ``--smoke`` shrinks sizes for CI.

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""

from __future__ import annotations

import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.configs.base import get_config
from repro.configs.reduce import make_reduced
from repro.core import fft as fft_lib
from repro.core.limits import FUSED_MAX
from repro.models import model as model_lib
from repro.models.layers import spectral as spec_lib
from repro.serving.engine import Engine, ServeConfig
from repro.serving.spectral_serve import sweep_once

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ARCH = "h2o-danube-1.8b"


def _cfg(compute_dtype: str = "bfloat16", filter_len: int = 32):
    cfg = make_reduced(get_config(ARCH))
    return dataclasses.replace(
        cfg,
        num_layers=2,
        block_pattern=("spectral", "attn"),
        spectral_filter_len=filter_len,
        compute_dtype=compute_dtype,
    )


def _gate_layer_stream(emit, s: int, lf: int, d: int, tol: float = 1e-3):
    """Streamed decode == one-shot spectral_forward on the mixer layer."""
    cfg = dataclasses.replace(_cfg("float32", lf), d_model=d)
    c, _ = spec_lib.stream_grain(cfg)
    t = c + c // 2  # crosses at least one chunk flush
    from repro.utils.params import unzip

    params, _ = unzip(spec_lib.spectral_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, s + t, d), jnp.float32)
    ref = spec_lib.spectral_forward(params, x, cfg=cfg)
    _, cache = spec_lib.spectral_forward(params, x[:, :s], cfg=cfg, return_cache=True)
    step = jax.jit(
        lambda xt, cc: spec_lib.spectral_stream_decode(params, xt, cc, cfg=cfg)
    )
    err = 0.0
    for i in range(t):
        y, cache = step(x[:, s + i : s + i + 1], cache)
        err = max(err, float(jnp.abs(y - ref[:, s + i : s + i + 1]).max()))
    emit(f"gate,layer_stream,S={s},Lf={lf},err={err:.2e}")
    assert err < tol, f"streamed decode vs one-shot: err {err} >= {tol} at S={s}"


def _gate_model_oracle(emit, engine: Engine, params, prompts, max_new: int):
    """Stream-mode greedy tokens == ring-buffer oracle tokens."""
    ring = Engine(
        dataclasses.replace(engine.cfg, spectral_decode_mode="ring"),
        params,
        engine.scfg,
    )
    a = np.asarray(engine.generate(prompts, max_new=max_new))
    b = np.asarray(ring.generate(prompts, max_new=max_new))
    emit(f"gate,stream_vs_ring,match={bool((a == b).all())}")
    assert (a == b).all(), "stream-mode tokens diverge from ring oracle"


def _gate_plan_discipline(emit, engine: Engine, *, batch, prompt_len, max_new):
    """Warm serving sweep must create zero new FFT plans."""
    sweep_once(engine, batch=batch, prompt_len=prompt_len, max_new=max_new, warmup=0)
    fft_lib.clear_plan_log()
    sweep_once(engine, batch=batch, prompt_len=prompt_len, max_new=max_new, warmup=0)
    n = len(fft_lib.plan_log())
    emit(f"gate,plan_discipline,new_plans={n}")
    assert n == 0, f"{n} new FFT plans created during a warm serving sweep"


def main(emit=print, smoke: bool = False):
    filter_len = 16 if smoke else 32
    prompt_len = 12 if smoke else 64
    max_new = 8 if smoke else 32
    batches = [2] if smoke else [1, 2, 4, 8]

    # -- gates (float32 engine: numerics before timing) --------------------
    _gate_layer_stream(emit, s=48, lf=filter_len, d=16)
    if not smoke:
        # prompt past the fused FFT regime: prefill must route through
        # overlap-save and the carried tail must still line up exactly.
        _gate_layer_stream(emit, s=FUSED_MAX + 128, lf=filter_len, d=4)

    cfg32 = _cfg("float32", filter_len)
    params, _ = model_lib.init_unzipped(jax.random.PRNGKey(0), cfg32)
    eng32 = Engine(cfg32, params, ServeConfig(max_new=max_new))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (2, prompt_len), 4, cfg32.vocab_size
    )
    _gate_model_oracle(emit, eng32, params, prompts, max_new)
    _gate_plan_discipline(
        emit, eng32, batch=2, prompt_len=prompt_len, max_new=max_new
    )

    # -- timed sweep (serving dtype) ---------------------------------------
    cfg = _cfg("float32" if smoke else "bfloat16", filter_len)
    if not smoke:
        params, _ = model_lib.init_unzipped(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, ServeConfig(max_new=max_new))

    cols = (
        "batch,prompt_len,max_new,prefill_s,insert_s,generate_s,"
        "decode_tok_per_s,e2e_tok_per_s"
    )
    emit(f"name,{cols}")
    rows = []
    for b in batches:
        r = sweep_once(
            engine, batch=b, prompt_len=prompt_len, max_new=max_new, warmup=1
        )
        rows.append(r)
        emit(
            "serve,"
            + ",".join(str(r[k]) for k in cols.split(","))
        )

    if not smoke:
        append_trajectory(
            TRAJECTORY,
            model=ARCH,
            sweep=rows,
            plan=spec_lib.stream_plan_info(cfg, batch=max(batches)),
        )
        emit(f"# trajectory appended to {os.path.abspath(TRAJECTORY)}")
    return rows


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
