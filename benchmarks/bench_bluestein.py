"""Arbitrary-length FFT: Bluestein leaf vs padded-pow2 vs ``jnp.fft``.

For each non-pow2 length the sweep times three routes to a usable spectrum:

* ``bluestein``   — the planned FFT at exactly ``n`` (chirp-conv leaves,
  correct n-point spectrum);
* ``padded_pow2`` — zero-pad to ``next_pow2(n)`` and run the pow2 plan
  (cheaper transform, but the WRONG bins unless the consumer interpolates);
* ``jnp_fft``     — XLA's native mixed-radix/Bluestein at ``n``, the
  external yardstick.

Each row carries ``analysis.roofline.bluestein_report``'s modeled pad ratio
and flops overhead so the measured gap can be read against the model.  Full
runs append a ``BENCH_bluestein.json`` trajectory entry; ``--smoke`` runs a
tiny sweep and gates on numerics vs ``numpy.fft`` at 1e-3, so CI exercises
the chirp-conv leaves end to end.

  PYTHONPATH=src python -m benchmarks.bench_bluestein [--smoke]
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.analysis import roofline as rl
from repro.core import fft as fft_lib
from repro.core.limits import next_pow2

# primes and 3·2^k — the pulse-sized lengths real radar/audio dictate.
SWEEP = [2029, 4093, 12288, 40000]
SMOKE_SWEEP = [97, 1536]

TRAJECTORY = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_bluestein.json"
)


def _time(fn, *args, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(sweep, reps=3, batch=4, check=False):
    rows = []
    for n in sweep:
        rng = np.random.default_rng(n)
        x = (rng.standard_normal((batch, n)) + 1j * rng.standard_normal((batch, n))).astype(np.complex64)
        xj = jnp.asarray(x)
        m = next_pow2(n)
        xp = jnp.pad(xj, ((0, 0), (0, m - n)))
        # xla backend: same arithmetic as the Pallas kernels, which are
        # accelerator-targeted — interpret-mode timing is meaningless.
        p_blu = fft_lib.plan(fft_lib.FFTSpec(n=n), backend="xla")
        p_pow = fft_lib.plan(fft_lib.FFTSpec(n=m), backend="xla")
        f_blu = jax.jit(lambda a: p_blu(a))
        f_pow = jax.jit(lambda a: p_pow(a))
        f_jnp = jax.jit(lambda a: jnp.fft.fft(a))
        rep = rl.bluestein_report(n, batch=batch)
        row = {
            "n": n,
            "batch": batch,
            "pad": rep["pad"],
            "pad_ratio": rep["pad_ratio"],
            "modeled_flops_overhead": rep["flops_overhead"],
            "bluestein_us": _time(f_blu, xj, reps=reps) * 1e6,
            "padded_pow2_us": _time(f_pow, xp, reps=reps) * 1e6,
            "jnp_fft_us": _time(f_jnp, xj, reps=reps) * 1e6,
        }
        if check:
            ref = np.fft.fft(x)
            err = float(
                np.abs(np.asarray(f_blu(xj)) - ref).max() / np.abs(ref).max()
            )
            assert err < 1e-3, f"Bluestein leaf disagrees with numpy at n={n}: {err}"
            row["rel_err_vs_numpy"] = err
        rows.append(row)
    return rows


def main(emit=print, smoke: bool = False):
    sweep = SMOKE_SWEEP if smoke else SWEEP
    emit(
        "bluestein.name,n,pad,pad_ratio,modeled_flops_overhead,"
        "bluestein_ms,padded_pow2_ms,jnp_fft_ms"
    )
    rows = run(
        sweep, reps=2 if smoke else 3, batch=2 if smoke else 4, check=smoke
    )
    for r in rows:
        emit(
            f"bluestein,{r['n']},{r['pad']},{r['pad_ratio']:.2f},"
            f"{r['modeled_flops_overhead']:.1f},{r['bluestein_us']/1e3:.2f},"
            f"{r['padded_pow2_us']/1e3:.2f},{r['jnp_fft_us']/1e3:.2f}"
        )
    if smoke:
        return
    append_trajectory(TRAJECTORY, bluestein=rows)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
