"""Paper Table 1 / Figs 7-10: our FFT vs FFTW stand-in vs CUFFT stand-in.

Stand-ins on this CPU container:
  FFTW  → numpy.pocketfft (the highly-tuned portable CPU FFT)
  CUFFT → jnp.fft (XLA's native FFT through the same jit pipeline as ours)
  ours  → the paper's algorithm, four-step memory-optimized plan, 'xla'
          backend (identical arithmetic to the Pallas kernels; the kernels
          themselves are TPU-targeted and only run in interpret mode here —
          interpret-mode timing is meaningless, see EXPERIMENTS.md).

The paper's Table 1 sizes 16..65536, single transforms, plus the batched
mid-size regime the paper's SAR motivation cares about, plus the split
regime (2¹⁷..2²⁰) where the linearized pass program rules: each row reports
the plan's HBM round-trip count and modeled HBM GB alongside wall-clock, so
the schedule is visible next to the time it buys.  Every run appends a
trajectory entry to ``BENCH_fft.json`` so later PRs can track the
split-regime speedup against this baseline.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._trajectory import append_trajectory
from repro.analysis import roofline as rl
from repro.core import fft as F

SIZES = [16, 64, 256, 1024, 4096, 16384, 65536]
#: Split-regime sizes — the linearized pass-program path this repo optimizes.
SPLIT_SIZES = [2**17, 2**18, 2**20]
SMOKE_SIZES = [256, 4096, 2**17]

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_fft.json")


def _time(fn, *args, reps=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if hasattr(fn(*args), "block_until_ready") else fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_np(fn, *args, reps=5, warmup=1) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(batch: int = 1, sizes=None, reps: int = 5):
    rows = []
    for n in sizes if sizes is not None else SIZES:
        x = (np.random.randn(batch, n) + 1j * np.random.randn(batch, n)).astype(
            np.complex64
        )
        xj = jnp.asarray(x)

        planned = F.plan(F.FFTSpec(n=n, kind="fft", batch_hint=batch), backend="xla")
        ours = jax.jit(lambda v: planned(v))
        cufft_standin = jax.jit(jnp.fft.fft)
        t_ours = _time(ours, xj, reps=reps)
        t_jnp = _time(cufft_standin, xj, reps=reps)
        t_np = _time_np(np.fft.fft, x, reps=reps)
        report = rl.fft_pass_report(n, batch=batch)
        rows.append(
            {
                "n": n,
                "batch": batch,
                "fftw_us": t_np * 1e6,
                "cufft_us": t_jnp * 1e6,
                "ours_us": t_ours * 1e6,
                "passes": report["hbm_round_trips"],
                "modeled_hbm_gb": report["modeled_hbm_bytes"] / 1e9,
            }
        )
    return rows


def _append_trajectory(all_rows) -> None:
    """BENCH_fft.json: one entry per run, so later PRs can diff the
    split-regime numbers against this PR's baseline on the same host."""
    append_trajectory(TRAJECTORY, rows=all_rows)


def main(emit=print, smoke: bool = False):
    emit("table1.name,n,batch,fftw_standin_us,cufft_standin_us,ours_us,"
         "speedup_vs_fftw,speedup_vs_cufft,plan_passes,modeled_hbm_gb")
    all_rows = []
    batches = (1,) if smoke else (1, 64)
    reps = 2 if smoke else 5
    for batch in batches:
        sizes = SMOKE_SIZES if smoke else SIZES + (SPLIT_SIZES if batch == 1 else [])
        for r in run(batch, sizes=sizes, reps=reps):
            emit(
                f"table1,{r['n']},{r['batch']},{r['fftw_us']:.1f},"
                f"{r['cufft_us']:.1f},{r['ours_us']:.1f},"
                f"{r['fftw_us']/r['ours_us']:.2f},"
                f"{r['cufft_us']/r['ours_us']:.2f},"
                f"{r['passes']},{r['modeled_hbm_gb']:.4f}"
            )
            all_rows.append(r)
    if not smoke:
        _append_trajectory(all_rows)


if __name__ == "__main__":
    main()
