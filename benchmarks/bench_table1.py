"""Paper Table 1 / Figs 7-10: our FFT vs FFTW stand-in vs CUFFT stand-in.

Stand-ins on this CPU container:
  FFTW  → numpy.pocketfft (the highly-tuned portable CPU FFT)
  CUFFT → jnp.fft (XLA's native FFT through the same jit pipeline as ours)
  ours  → the paper's algorithm, four-step memory-optimized plan, 'xla'
          backend (identical arithmetic to the Pallas kernels; the kernels
          themselves are TPU-targeted and only run in interpret mode here —
          interpret-mode timing is meaningless, see EXPERIMENTS.md).

The paper's Table 1 sizes 16..65536, single transforms, plus the batched
mid-size regime the paper's SAR motivation cares about.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft as F

SIZES = [16, 64, 256, 1024, 4096, 16384, 65536]


def _time(fn, *args, reps=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if hasattr(fn(*args), "block_until_ready") else fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_np(fn, *args, reps=5, warmup=1) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(batch: int = 1):
    rows = []
    for n in SIZES:
        x = (np.random.randn(batch, n) + 1j * np.random.randn(batch, n)).astype(
            np.complex64
        )
        xj = jnp.asarray(x)

        planned = F.plan(F.FFTSpec(n=n, kind="fft", batch_hint=batch), backend="xla")
        ours = jax.jit(lambda v: planned(v))
        cufft_standin = jax.jit(jnp.fft.fft)
        t_ours = _time(ours, xj)
        t_jnp = _time(cufft_standin, xj)
        t_np = _time_np(np.fft.fft, x)
        rows.append((n, batch, t_np, t_jnp, t_ours))
    return rows


def main(emit=print):
    emit("table1.name,n,batch,fftw_standin_us,cufft_standin_us,ours_us,"
         "speedup_vs_fftw,speedup_vs_cufft")
    for batch in (1, 64):
        for n, b, t_np, t_jnp, t_ours in run(batch):
            emit(
                f"table1,{n},{b},{t_np*1e6:.1f},{t_jnp*1e6:.1f},{t_ours*1e6:.1f},"
                f"{t_np/t_ours:.2f},{t_jnp/t_ours:.2f}"
            )


if __name__ == "__main__":
    main()
