"""Regenerate the shipped seed tuning cache (repro/data/tuning_seed.json).

Measures the plan-level tuning spaces for a small roster of common specs on
the current device and dumps the winners — ``mode: "measure"`` entries, so
``tune="measure"`` plans of a seeded spec hit the seed and perform ZERO
first-request measurements (the package-data layer sits beneath the user
cache; see :func:`repro.core.tuning.seed_cache`).

Run on each device_kind whose entries should ship; the JSON accumulates
across runs (existing keys for other devices are preserved).  The roster
deliberately avoids the specs the tuning test-suite measures
(n=2**17 batch=0, n=4096 batch=2, fft2 64×2**17) — those tests assert that
a fresh cache DOES measure, which a seed hit would silence.

  PYTHONPATH=src python -m benchmarks.gen_tuning_seed
"""

from __future__ import annotations

import json
import os
import tempfile

SEED_PATH = os.path.join(
    os.path.dirname(__file__), "..", "src", "repro", "data", "tuning_seed.json"
)

#: (n, batch_hint) roster — the serving/bench hot sizes.
ROSTER = [(8192, 2), (65536, 2)]


def main() -> None:
    # Measure into a scratch user cache so this run neither reads the
    # developer's warm cache nor pollutes it with roster entries.
    scratch = tempfile.mkdtemp(prefix="seed_gen_")
    os.environ["REPRO_TUNING_CACHE"] = os.path.join(scratch, "cache.json")

    import jax

    from repro.core import fft as fft_lib
    from repro.core import tuning

    entries: dict = {}
    if os.path.exists(SEED_PATH):
        with open(SEED_PATH) as f:
            entries = json.load(f)

    platform = jax.default_backend()
    for n, batch in ROSTER:
        spec = fft_lib.FFTSpec(n=n, kind="fft", batch_hint=batch)
        for backend in ("pallas", "pallas_gpu"):
            space = tuning.TuningSpace.for_plan(spec, backend)
            cfg = space.decide("measure")
            entries[f"{tuning.device_key()}|{space.key}"] = {
                "config": cfg,
                "mode": "measure",
            }
        xspace = tuning.TuningSpace.for_backend(spec, platform)
        entries[f"{tuning.device_key()}|{xspace.key}"] = {
            "config": xspace.decide("measure"),
            "mode": "measure",
        }

    with open(SEED_PATH, "w") as f:
        json.dump(entries, f, indent=1, sort_keys=True)
    print(f"wrote {len(entries)} entries to {SEED_PATH}")


if __name__ == "__main__":
    main()
