"""Benchmark harness entrypoint — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table1 sar # subset
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: fast sanity pass

``--smoke`` runs a tiny-size, low-rep subset so CI catches import breakage
and API drift in every bench module without paying full benchmark time.
Emits ``name,...`` CSV rows (paper-table stand-ins documented per module).
"""

import sys

from benchmarks import (
    bench_bluestein,
    bench_fftconv,
    bench_gpu,
    bench_pfft,
    bench_roofline,
    bench_sar,
    bench_serve,
    bench_table1,
    bench_tuning,
)

SUITES = {
    "table1": bench_table1.main,     # paper Table 1 / Figs 7-10
    "sar": bench_sar.main,           # paper §3 SAR motivation
    "fftconv": bench_fftconv.main,   # LM integration (spectral layers)
    "tuning": bench_tuning.main,     # autotuned vs fixed-heuristic blocks
    "roofline": bench_roofline.main, # dry-run roofline summary
    "serve": bench_serve.main,       # prefill/insert/generate phase timings
    "pfft": bench_pfft.main,         # distributed pencil scaling (fake devices)
    "gpu": bench_gpu.main,           # pallas_gpu vs xla crossover ledger
    "bluestein": bench_bluestein.main,  # non-pow2 vs padded-pow2 vs jnp.fft
}

#: Suites with a fast-path smoke mode; the rest are import-checked only.
SMOKE_SUITES = {
    "table1": lambda: bench_table1.main(smoke=True),
    "sar": lambda: bench_sar.main(smoke=True),
    # cross-checks overlap-save against one-shot, so CI exercises the engine
    "fftconv": lambda: bench_fftconv.main(smoke=True),
    # runs the tuner (model + measure) and asserts cache determinism
    "tuning": lambda: bench_tuning.main(smoke=True),
    # asserts streamed == one-shot numerics + zero-new-plan discipline
    # before timing a small serving sweep
    "serve": lambda: bench_serve.main(smoke=True),
    # one 16-fake-device point: numerics + packed collective counts
    "pfft": lambda: bench_pfft.main(smoke=True),
    # Triton-path kernels under interpret: numerics + per-leaf claims
    "gpu": lambda: bench_gpu.main(smoke=True),
    # gates chirp-conv leaves on numerics vs numpy before timing
    "bluestein": lambda: bench_bluestein.main(smoke=True),
}


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    picks = [a for a in args if a in SUITES] or list(SUITES)
    for name in picks:
        if smoke:
            runner = SMOKE_SUITES.get(name)
            if runner is None:
                print(f"# ---- {name}: import ok, no smoke mode ----", flush=True)
                continue
            print(f"# ---- {name} (smoke) ----", flush=True)
            runner()
            continue
        print(f"# ---- {name} ----", flush=True)
        SUITES[name]()


if __name__ == "__main__":
    main()
