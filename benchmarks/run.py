"""Benchmark harness entrypoint — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table1 sar # subset

Emits ``name,...`` CSV rows (paper-table stand-ins documented per module).
"""

import sys

from benchmarks import bench_fftconv, bench_roofline, bench_sar, bench_table1

SUITES = {
    "table1": bench_table1.main,     # paper Table 1 / Figs 7-10
    "sar": bench_sar.main,           # paper §3 SAR motivation
    "fftconv": bench_fftconv.main,   # LM integration (spectral layers)
    "roofline": bench_roofline.main, # dry-run roofline summary
}


def main() -> None:
    picks = [a for a in sys.argv[1:] if a in SUITES] or list(SUITES)
    for name in picks:
        print(f"# ---- {name} ----", flush=True)
        SUITES[name]()


if __name__ == "__main__":
    main()
