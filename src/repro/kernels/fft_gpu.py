"""Pallas-on-Triton GPU variants of the FFT row leaves — the paper's
native hardware, landed leaf-by-leaf.

The source paper's speedup is a *shared-memory* budget argument: tile the
transform so the working set lives in the SM's fast tier and the signal
touches global memory once per pass.  The TPU kernels already encode that
schedule; what changes on CUDA-class devices is only the launch surface:

* BlockSpecs stay (they are the tiling), but the index maps must be
  Triton-friendly — no ``dimension_semantics`` or other Mosaic-only
  compiler params (``kernels.pallas_compat.gpu_compiler_params`` supplies
  ``num_warps``/``num_stages`` instead, or ``None`` when no Triton
  lowering is available);
* batch tiles are picked against the per-SM shared-memory budget
  (:func:`repro.core.plan.pick_batch_tile_gpu` /
  :func:`repro.core.limits.memory_budget`) rather than ``VMEM_BUDGET`` —
  the LUT operands software-pipeline through the ``dot`` K loop instead of
  residing whole, so the model charges stripes, not matrices;
* the in-kernel math is *identical*: :func:`~repro.kernels.dft_matmul.dft_tile`
  and :func:`~repro.kernels.fft4step.four_step_tile` are pure-jnp tile
  engines and compile unchanged under either lowering.

Claim surface (:func:`gpu_claims`): row transforms over the contiguous
last axis — whole-signal passes (the ≤ ``FUSED_MAX`` one-call regimes),
contiguous pencil-order row passes, and the natural-order fused-write row
pass.  Strided-column passes, digit-reversal reorders, ``axis=-2`` image
columns and the Hermitian recombination epilogues are **not claimed yet**:
:func:`execute_program_gpu` runs those through a traced-XLA per-pass
fallback (same LUT tables, same scaling convention) so a mixed program
stays correct while the backend grows leaf-by-leaf.

Everything runs under ``REPRO_PALLAS_INTERPRET=1`` (or automatically on a
CPU host) through the Pallas interpreter, so CI proves numerics and jaxpr
purity without a GPU; a real GPU picks up the Triton lowering with zero
code changes.
"""

from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import faults
from repro.core import plan as plan_lib
from repro.core.fft_xla import cmul
from repro.kernels import ops, pencil
from repro.kernels.dft_matmul import dft_tile
from repro.kernels.fft4step import four_step_tile
from repro.kernels.pallas_compat import gpu_compiler_params

Planes = Tuple[jax.Array, jax.Array]

__all__ = [
    "dft_matmul_gpu_call",
    "fft4step_gpu_call",
    "rows_natural_gpu_call",
    "execute_program_gpu",
    "execute_plan_gpu",
    "gpu_claims",
]


def gpu_claims(p: plan_lib.Pass) -> bool:
    """Does the GPU backend execute this program pass natively?

    Claimed: ``axis=-1`` direct/fused4 row leaves — whole-signal passes
    and contiguous-row passes (``stride == 1``), including the
    natural-order fused transposed write — and every Bluestein stage
    (chirp pre/post multiplies, the B̂ product, and the fused pad-conv
    passes: :mod:`repro.kernels.bluestein` lowers on both backends).
    Unclaimed (→ xla fallback): strided-column passes, reorders,
    ``axis=-2`` column transforms, and epilogue pass kinds (rfft/irfft
    recombination).
    """
    if p.axis != -1 or p.kind not in ("direct", "fused4", "bluestein"):
        return False
    if p.kind == "bluestein":
        return True
    pencils, stride, _f = p.view_in if p.view_in else (1, 1, p.n)
    return pencils == 1 or stride == 1


def _call_kwargs(interpret: bool) -> dict:
    """Triton compiler params for real lowering; nothing under interpret
    (the interpreter has no backend to hand them to)."""
    if interpret:
        return {}
    params = gpu_compiler_params()
    return {} if params is None else {"compiler_params": params}


def dft_matmul_gpu_call(
    xr: jax.Array,
    xi: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    *,
    batch_tile: int,
    interpret: bool = False,
) -> Planes:
    """Triton-shaped direct DFT GEMM: y = x @ W, x (B, N) split-complex.

    Same BlockSpec tiling as :func:`~repro.kernels.dft_matmul.dft_matmul_call`
    — signal blocked over the batch grid, LUT pinned to block (0, 0) — with
    GPU compiler params instead of Mosaic ``dimension_semantics``.
    """
    b, n = xr.shape
    assert b % batch_tile == 0, (b, batch_tile)

    def kernel(x_r, x_i, w_r, w_i, o_r, o_i):
        yr, yi = dft_tile(x_r[...], x_i[...], w_r[...], w_i[...])
        o_r[...] = yr
        o_i[...] = yi

    sig = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    lut = pl.BlockSpec((n, n), lambda i: (0, 0))
    fn = pl.pallas_call(
        kernel,
        grid=(b // batch_tile,),
        in_specs=[sig, sig, lut, lut],
        out_specs=[sig, sig],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
        **_call_kwargs(interpret),
    )
    return tuple(fn(xr, xi, wr, wi))


def fft4step_gpu_call(
    xr: jax.Array,
    xi: jax.Array,
    w1r: jax.Array,
    w1i: jax.Array,
    twr: jax.Array,
    twi: jax.Array,
    w2r: jax.Array,
    w2i: jax.Array,
    *,
    batch_tile: int,
    natural_order: bool = True,
    interpret: bool = False,
) -> Planes:
    """Triton-shaped fused four-step FFT, x (B, n1·n2) split-complex."""
    b, n = xr.shape
    n1, n2 = w1r.shape[0], w2r.shape[0]
    assert n == n1 * n2, (n, n1, n2)
    assert b % batch_tile == 0, (b, batch_tile)

    def kernel(x_r, x_i, w1_r, w1_i, t_r, t_i, w2_r, w2_i, o_r, o_i):
        yr, yi = four_step_tile(
            x_r[...], x_i[...],
            w1_r[...], w1_i[...], t_r[...], t_i[...], w2_r[...], w2_i[...],
            n1, n2, natural_order,
        )
        o_r[...] = yr
        o_i[...] = yi

    sig = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    lut1 = pl.BlockSpec((n1, n1), lambda i: (0, 0))
    lutt = pl.BlockSpec((n1, n2), lambda i: (0, 0))
    lut2 = pl.BlockSpec((n2, n2), lambda i: (0, 0))
    fn = pl.pallas_call(
        kernel,
        grid=(b // batch_tile,),
        in_specs=[sig, sig, lut1, lut1, lutt, lutt, lut2, lut2],
        out_specs=[sig, sig],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
        **_call_kwargs(interpret),
    )
    return tuple(fn(xr, xi, w1r, w1i, twr, twi, w2r, w2i))


def rows_natural_gpu_call(
    xr: jax.Array,
    xi: jax.Array,
    luts,
    *,
    kind: str,
    n1: int = 0,
    n2: int = 0,
    chunk: int,
    interpret: bool = False,
) -> Planes:
    """Contiguous-row pass with the natural-order transpose fused into its
    strided write, Triton-shaped: x (B, p, f) → y (B, f, p)."""
    b, p, f = xr.shape
    assert p % chunk == 0, (p, chunk)
    in_sig = pl.BlockSpec((1, chunk, f), lambda i, j: (i, j, 0))
    out_sig = pl.BlockSpec((1, f, chunk), lambda i, j: (i, 0, j))
    in_specs = [in_sig, in_sig] + pencil._lut_specs(
        kind, f, n1, n2, lambda i, j: (0, 0)
    )
    fn = pl.pallas_call(
        pencil._make_rows_kernel(kind, n1, n2, len(luts)),
        grid=(b, p // chunk),
        in_specs=in_specs,
        out_specs=[out_sig, out_sig],
        out_shape=[
            jax.ShapeDtypeStruct((b, f, p), jnp.float32),
            jax.ShapeDtypeStruct((b, f, p), jnp.float32),
        ],
        interpret=interpret,
        **_call_kwargs(interpret),
    )
    return tuple(fn(xr, xi, *pencil._as_ops(luts)))


def _tile_for_gpu(p: plan_lib.Pass, batch_tiles: Mapping[int, int] | None) -> int:
    if batch_tiles is not None and p.n in batch_tiles:
        return batch_tiles[p.n]
    return plan_lib.pick_batch_tile_gpu(p)


def _leaf_kernel_gpu(
    xr, xi, p: plan_lib.Pass, inverse, interpret, batch_tiles, natural_order=True
) -> Planes:
    """Single-pallas_call GPU transform of the last axis (2-D input)."""
    if p.n == 1:
        return xr, xi
    bt = _tile_for_gpu(p, batch_tiles)
    xr, xi, b, pad = ops._pad_batch(xr, xi, bt)
    if p.kind == "direct":
        wr, wi = ops._direct_luts(p.n, inverse)
        yr, yi = dft_matmul_gpu_call(
            xr, xi, jnp.asarray(wr), jnp.asarray(wi),
            batch_tile=bt, interpret=interpret,
        )
    else:
        w1r, w1i, tr, ti, w2r, w2i = ops._fused_luts(p.n1, p.n2, inverse)
        yr, yi = fft4step_gpu_call(
            xr, xi,
            jnp.asarray(w1r), jnp.asarray(w1i),
            jnp.asarray(tr), jnp.asarray(ti),
            jnp.asarray(w2r), jnp.asarray(w2i),
            batch_tile=bt, natural_order=natural_order, interpret=interpret,
        )
    return (yr, yi) if pad == 0 else (yr[:b], yi[:b])


def _row_transform_xla(xr2, xi2, p: plan_lib.Pass, luts, natural: bool = True):
    """Traced last-axis transform of (R, f) planes — the fallback's engine
    (the same pure-jnp tiles the kernels embed, just not inside a
    pallas_call)."""
    if p.kind == "direct":
        return dft_tile(xr2, xi2, jnp.asarray(luts[0]), jnp.asarray(luts[1]))
    w1r, w1i, tr, ti, w2r, w2i = (jnp.asarray(a) for a in luts)
    return four_step_tile(xr2, xi2, w1r, w1i, tr, ti, w2r, w2i, p.n1, p.n2, natural)


def _bluestein_xla_pass(xr, xi, p: plan_lib.Pass, inverse) -> Planes:
    """One Bluestein program stage, traced through XLA.

    Same interned chirp/B̂ tables as the kernel path; the pad-length
    transform runs through :func:`repro.core.fft_xla.four_step_fft`
    (forward for ``fwd``, true inverse — 1/M folded — for ``inv``).
    """
    from repro.core import fft_xla
    from repro.core import twiddle as tw

    n, m_pad = p.n, p.n1
    if p.stage in ("pre", "fwd"):
        ar, ai = tw.bluestein_chirp(n, inverse)
        xr, xi = cmul(xr, xi, jnp.asarray(ar)[None], jnp.asarray(ai)[None])
        xr = jnp.pad(xr, ((0, 0), (0, m_pad - n)))
        xi = jnp.pad(xi, ((0, 0), (0, m_pad - n)))
        if p.stage == "pre":
            return xr, xi
        xr, xi = fft_xla.four_step_fft(xr, xi)
    if p.stage in ("mul", "fwd"):
        br, bi = tw.bluestein_spectrum(n, m_pad, inverse)
        return cmul(xr, xi, jnp.asarray(br)[None], jnp.asarray(bi)[None])
    if p.stage == "inv":
        xr, xi = fft_xla.four_step_fft(xr, xi, inverse=True)
    elif p.stage != "post":
        raise ValueError(f"unknown bluestein stage {p.stage!r}")
    pr, pi = tw.bluestein_postchirp(n, inverse)
    return cmul(
        xr[:, :n], xi[:, :n], jnp.asarray(pr)[None], jnp.asarray(pi)[None]
    )


def _xla_pass(xr, xi, p: plan_lib.Pass, fs, inverse) -> Planes:
    """One unclaimed program pass over (B, n) planes, traced through XLA.

    Mirrors :func:`repro.kernels.ops._apply_pass` semantics — same host-cached
    LUT tables, same per-pass 1/f inverse folding, same twiddle-after
    convention — but materializes its transposes as plain XLA ops.  This is
    the per-leaf fallback the capability negotiation promises: a plan whose
    program mixes claimed and unclaimed passes still executes end to end.
    """
    b, n = xr.shape
    if p.kind == "reorder":
        perm = (0,) + tuple(range(len(fs), 0, -1))
        xr = xr.reshape(b, *fs).transpose(perm).reshape(b, n)
        xi = xi.reshape(b, *fs).transpose(perm).reshape(b, n)
        return xr, xi
    if p.kind == "bluestein":
        return _bluestein_xla_pass(xr, xi, p, inverse)
    pencils, stride, f = p.view_in if p.view_in else (1, 1, p.n)
    luts = ops._transform_luts(p, inverse)
    if pencils == 1:
        yr, yi = _row_transform_xla(xr, xi, p, luts, natural=p.order == "natural")
        return yr, yi
    if stride == 1:
        rr = xr.reshape(b * pencils, f)
        ri = xi.reshape(b * pencils, f)
        rr, ri = _row_transform_xla(rr, ri, p, luts)
        if p.view_out != p.view_in:
            # Natural-order write: (b, p, f) → (b, f, p), materialized.
            rr = rr.reshape(b, pencils, f).swapaxes(-1, -2)
            ri = ri.reshape(b, pencils, f).swapaxes(-1, -2)
        return rr.reshape(b, n), ri.reshape(b, n)
    # Strided-column pass: transform length f down axis -2 of the
    # (b·groups, f, stride) view, then the inter-factor twiddle.
    groups = pencils // stride
    xr3 = xr.reshape(b * groups, f, stride).swapaxes(-1, -2)
    xi3 = xi.reshape(b * groups, f, stride).swapaxes(-1, -2)
    rr, ri = _row_transform_xla(xr3.reshape(-1, f), xi3.reshape(-1, f), p, luts)
    yr3 = rr.reshape(b * groups, stride, f).swapaxes(-1, -2)
    yi3 = ri.reshape(b * groups, stride, f).swapaxes(-1, -2)
    if p.twiddle_after is not None:
        tr, ti = ops._pass_twiddle_luts(*p.twiddle_after, inverse)
        yr3, yi3 = cmul(yr3, yi3, jnp.asarray(tr)[None], jnp.asarray(ti)[None])
    return yr3.reshape(b, n), yi3.reshape(b, n)


def _gpu_pass(xr, xi, p: plan_lib.Pass, inverse, interpret, batch_tiles) -> Planes:
    """One claimed row-leaf pass through the Triton-shaped kernels."""
    b, n = xr.shape
    if p.kind == "bluestein":
        return ops._bluestein_pass(
            xr, xi, p, inverse, interpret, _tile_for_gpu(p, batch_tiles), gpu=True
        )
    pencils, stride, f = p.view_in if p.view_in else (1, 1, p.n)
    if pencils == 1:
        return _leaf_kernel_gpu(
            xr, xi, p, inverse, interpret, batch_tiles,
            natural_order=p.order == "natural",
        )
    luts = ops._transform_luts(p, inverse)
    if p.view_out != p.view_in:
        chunk = plan_lib.pick_pass_chunk(p, budget=plan_lib.memory_budget())
        xr3 = xr.reshape(b, pencils, f)
        xi3 = xi.reshape(b, pencils, f)
        yr3, yi3 = rows_natural_gpu_call(
            xr3, xi3, luts, kind=p.kind, n1=p.n1, n2=p.n2,
            chunk=chunk, interpret=interpret,
        )
        return yr3.reshape(b, n), yi3.reshape(b, n)
    rr = xr.reshape(b * pencils, f)
    ri = xi.reshape(b * pencils, f)
    rr, ri = _leaf_kernel_gpu(rr, ri, p, inverse, interpret, batch_tiles)
    return rr.reshape(b, n), ri.reshape(b, n)


def execute_program_gpu(
    xr: jax.Array,
    xi: jax.Array,
    passes: Sequence[plan_lib.Pass],
    *,
    inverse: bool = False,
    interpret: bool | None = None,
    batch_tiles: Mapping[int, int] | None = None,
    claims: Callable[[plan_lib.Pass], bool] = gpu_claims,
    degradations: list | None = None,
) -> Planes:
    """Walk a linearized pass program over (B, n) split planes, executing
    claimed passes through the Triton-shaped kernels and the rest through
    the traced-XLA fallback — per-leaf negotiation, one buffer.

    Claimed leaves run under :func:`repro.core.faults.run_leaf`: a leaf
    that fails to trace/compile is retried once, then (pallas_gpu, kind)
    is quarantined and the leaf demotes to the same traced-XLA fallback
    unclaimed passes use, recorded on ``degradations``."""
    if interpret is None:
        interpret = ops.should_interpret()
    fs = [q.n for q in passes if q.kind != "reorder"]
    for i, p in enumerate(passes):
        # Passes may pin their own direction (the Bluestein inner conv).
        eff = p.inverse if p.inverse is not None else inverse
        if claims(p):
            xr, xi = faults.run_leaf(
                "pallas_gpu",
                p.kind,
                lambda xr=xr, xi=xi, p=p, eff=eff: _gpu_pass(
                    xr, xi, p, eff, interpret, batch_tiles
                ),
                lambda xr=xr, xi=xi, p=p, eff=eff: _xla_pass(xr, xi, p, fs, eff),
                degradations=degradations,
                index=i,
            )
        else:
            xr, xi = _xla_pass(xr, xi, p, fs, eff)
    return xr, xi


def execute_plan_gpu(
    xr: jax.Array,
    xi: jax.Array,
    fft_plan: plan_lib.FFTPlan,
    *,
    inverse: bool = False,
    interpret: bool | None = None,
    batch_tiles: Mapping[int, int] | None = None,
    order: str = "natural",
    degradations: list | None = None,
) -> Planes:
    """Execute a 1-D :class:`~repro.core.plan.FFTPlan` over the last axis
    with the GPU claim surface (any leading batch dims)."""
    n = xr.shape[-1]
    if n != fft_plan.n:
        raise faults.PlanError(f"plan is for n={fft_plan.n}, input has n={n}")
    passes = (
        fft_plan.passes
        if order == "natural"
        else plan_lib.compile_passes(fft_plan.n, order=order)
    )
    lead = xr.shape[:-1]
    b = int(np.prod(lead)) if lead else 1
    yr, yi = execute_program_gpu(
        xr.reshape(b, n), xi.reshape(b, n), passes,
        inverse=inverse, interpret=interpret, batch_tiles=batch_tiles,
        degradations=degradations,
    )
    return yr.reshape(*lead, n), yi.reshape(*lead, n)
