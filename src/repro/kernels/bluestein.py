"""Bluestein chirp-conv Pallas kernels — arbitrary-length FFT leaves.

Bluestein's identity jk = (j² + k² − (k−j)²)/2 turns a length-``n`` DFT of
ANY ``n`` into one circular convolution at a pow2 pad ``M ≥ 2n−1`` between
the chirp-modulated signal and the conjugate chirp — a transform this
engine already knows how to run in one HBM round trip.  These kernels keep
the §2.3.2 call-count discipline for the new leaf kind: in the fused
regime (``M ≤ FUSED_MAX``) the whole pipeline is exactly TWO
``pallas_call``s —

* ``bluestein_fwd_call`` — chirp pre-multiply, the zero-pad to ``M``
  (VMEM-internal ``concatenate``, never an HBM pad pass), the forward
  pad-length transform through the same :func:`~repro.kernels.dft_matmul.
  dft_tile` / :func:`~repro.kernels.fft4step.four_step_tile` engines every
  other leaf uses, and the ⊙B̂ chirp-spectrum multiply — one kernel;
* ``bluestein_inv_call`` — the inverse pad-length transform (1/M folded in
  its LUTs), the slice back to ``n`` (VMEM-internal) and the chirp
  post-multiply (1/n folded for outer-inverse transforms) — the second.

Past the fused regime the pad length's own split program runs the conv and
``bluestein_elem_call`` supplies the elementwise chirp stages (``pre`` /
``mul`` / ``post``) as single-call passes bracketing it.

The chirp planes and the B̂ spectrum are host-cached float64 tables
(:mod:`repro.core.twiddle`), pinned to block (0, 0) like every other LUT —
computed once per interned plan, served at VMEM bandwidth.  ``gpu=True``
swaps Mosaic ``dimension_semantics`` for Triton ``num_warps``/``num_stages``
(or nothing under interpret), exactly the :mod:`repro.kernels.fft_gpu`
convention, so both accelerator paths share one kernel body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fft_xla import cmul
from repro.kernels.dft_matmul import dft_tile
from repro.kernels.fft4step import four_step_tile
from repro.kernels.pallas_compat import compiler_params, gpu_compiler_params

Planes = tuple[jax.Array, jax.Array]

__all__ = [
    "bluestein_fwd_call",
    "bluestein_inv_call",
    "bluestein_elem_call",
]


def _params(gpu: bool, interpret: bool) -> dict:
    """Per-lowering compiler params: Mosaic batch-parallel semantics on the
    TPU path, Triton launch hints on the GPU path (none under interpret)."""
    if gpu:
        if interpret:
            return {}
        p = gpu_compiler_params()
        return {} if p is None else {"compiler_params": p}
    return {"compiler_params": compiler_params(dimension_semantics=("parallel",))}


def _inner_specs(inner_kind: str, m_pad: int, in1: int, in2: int) -> list:
    """BlockSpecs of the pad-length transform's LUT operands."""
    pin = lambda i: (0, 0)  # noqa: E731
    if inner_kind == "direct":
        lut = pl.BlockSpec((m_pad, m_pad), pin)
        return [lut, lut]
    lut1 = pl.BlockSpec((in1, in1), pin)
    lutt = pl.BlockSpec((in1, in2), pin)
    lut2 = pl.BlockSpec((in2, in2), pin)
    return [lut1, lut1, lutt, lutt, lut2, lut2]


def _inner_transform(yr, yi, inner, inner_kind: str, in1: int, in2: int):
    """The pad-length transform on a VMEM-resident tile — the same engines
    every pow2 leaf runs, just called from inside the chirp kernel."""
    if inner_kind == "direct":
        return dft_tile(yr, yi, inner[0][...], inner[1][...])
    return four_step_tile(
        yr, yi, *(w[...] for w in inner), in1, in2, True
    )


def bluestein_fwd_call(
    xr: jax.Array,
    xi: jax.Array,
    luts,
    *,
    n: int,
    m_pad: int,
    inner_kind: str,
    in1: int = 0,
    in2: int = 0,
    batch_tile: int,
    interpret: bool = False,
    gpu: bool = False,
) -> Planes:
    """Fused Bluestein forward half: x (B, n) → FFT_M(chirp·x ‖ 0) ⊙ B̂ (B, M).

    ``luts`` = (chirp_r, chirp_i, *inner_fwd_luts, spec_r, spec_i): the
    (1, n) pre-chirp planes, the forward pad-length transform's LUTs
    (direct W or fused W1/T/W2), and the (1, M) B̂ spectrum planes.
    """
    b, _n = xr.shape
    assert _n == n and b % batch_tile == 0, (xr.shape, n, batch_tile)

    def kernel(x_r, x_i, a_r, a_i, *rest):
        inner = rest[: -4]
        b_r, b_i, o_r, o_i = rest[-4:]
        yr, yi = cmul(x_r[...], x_i[...], a_r[...], a_i[...])
        zeros = jnp.zeros((yr.shape[0], m_pad - n), jnp.float32)
        yr = jnp.concatenate([yr, zeros], axis=-1)
        yi = jnp.concatenate([yi, zeros], axis=-1)
        fr, fi = _inner_transform(yr, yi, inner, inner_kind, in1, in2)
        fr, fi = cmul(fr, fi, b_r[...], b_i[...])
        o_r[...] = fr
        o_i[...] = fi

    sig_in = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    sig_out = pl.BlockSpec((batch_tile, m_pad), lambda i: (i, 0))
    chirp = pl.BlockSpec((1, n), lambda i: (0, 0))
    spec = pl.BlockSpec((1, m_pad), lambda i: (0, 0))
    in_specs = [sig_in, sig_in, chirp, chirp]
    in_specs += _inner_specs(inner_kind, m_pad, in1, in2)
    in_specs += [spec, spec]
    fn = pl.pallas_call(
        kernel,
        grid=(b // batch_tile,),
        in_specs=in_specs,
        out_specs=[sig_out, sig_out],
        out_shape=[
            jax.ShapeDtypeStruct((b, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, m_pad), jnp.float32),
        ],
        interpret=interpret,
        **_params(gpu, interpret),
    )
    return tuple(fn(xr, xi, *(jnp.asarray(a) for a in luts)))


def bluestein_inv_call(
    xr: jax.Array,
    xi: jax.Array,
    luts,
    *,
    n: int,
    m_pad: int,
    inner_kind: str,
    in1: int = 0,
    in2: int = 0,
    batch_tile: int,
    interpret: bool = False,
    gpu: bool = False,
) -> Planes:
    """Fused Bluestein inverse half: x (B, M) → chirp·IFFT_M(x)[:n] (B, n).

    ``luts`` = (*inner_inv_luts, post_r, post_i): the inverse pad-length
    transform's LUTs (1/M folded in) and the (1, n) post-chirp planes (1/n
    folded when the outer transform is an inverse DFT).
    """
    b, _m = xr.shape
    assert _m == m_pad and b % batch_tile == 0, (xr.shape, m_pad, batch_tile)

    def kernel(x_r, x_i, *rest):
        inner = rest[: -4]
        p_r, p_i, o_r, o_i = rest[-4:]
        gr, gi = _inner_transform(
            x_r[...], x_i[...], inner, inner_kind, in1, in2
        )
        gr, gi = gr[:, :n], gi[:, :n]
        gr, gi = cmul(gr, gi, p_r[...], p_i[...])
        o_r[...] = gr
        o_i[...] = gi

    sig_in = pl.BlockSpec((batch_tile, m_pad), lambda i: (i, 0))
    sig_out = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    chirp = pl.BlockSpec((1, n), lambda i: (0, 0))
    in_specs = [sig_in, sig_in]
    in_specs += _inner_specs(inner_kind, m_pad, in1, in2)
    in_specs += [chirp, chirp]
    fn = pl.pallas_call(
        kernel,
        grid=(b // batch_tile,),
        in_specs=in_specs,
        out_specs=[sig_out, sig_out],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
        **_params(gpu, interpret),
    )
    return tuple(fn(xr, xi, *(jnp.asarray(a) for a in luts)))


def bluestein_elem_call(
    xr: jax.Array,
    xi: jax.Array,
    planes,
    *,
    stage: str,
    n: int,
    m_pad: int,
    batch_tile: int,
    interpret: bool = False,
    gpu: bool = False,
) -> Planes:
    """One elementwise chirp stage of the split-regime Bluestein program.

    ``pre``  — (B, n) → chirp·x zero-padded to (B, M);
    ``mul``  — (B, M) → x ⊙ B̂ in place;
    ``post`` — (B, M) → chirp·x[:, :n] (B, n).
    ``planes`` is the stage's (1, width) LUT pair.  One ``pallas_call``
    each — the split-regime conv pays 3 chirp trips on top of the pad
    program's own, all still kernels (no traced glue).
    """
    b = xr.shape[0]
    assert b % batch_tile == 0, (b, batch_tile)
    w_in = n if stage == "pre" else m_pad
    w_out = m_pad if stage in ("pre", "mul") else n
    w_lut = n if stage in ("pre", "post") else m_pad
    assert xr.shape[1] == w_in, (xr.shape, stage, w_in)

    def kernel(x_r, x_i, a_r, a_i, o_r, o_i):
        yr, yi = x_r[...], x_i[...]
        if stage == "post":
            yr, yi = yr[:, :n], yi[:, :n]
        yr, yi = cmul(yr, yi, a_r[...], a_i[...])
        if stage == "pre":
            zeros = jnp.zeros((yr.shape[0], m_pad - n), jnp.float32)
            yr = jnp.concatenate([yr, zeros], axis=-1)
            yi = jnp.concatenate([yi, zeros], axis=-1)
        o_r[...] = yr
        o_i[...] = yi

    sig_in = pl.BlockSpec((batch_tile, w_in), lambda i: (i, 0))
    sig_out = pl.BlockSpec((batch_tile, w_out), lambda i: (i, 0))
    lut = pl.BlockSpec((1, w_lut), lambda i: (0, 0))
    fn = pl.pallas_call(
        kernel,
        grid=(b // batch_tile,),
        in_specs=[sig_in, sig_in, lut, lut],
        out_specs=[sig_out, sig_out],
        out_shape=[
            jax.ShapeDtypeStruct((b, w_out), jnp.float32),
            jax.ShapeDtypeStruct((b, w_out), jnp.float32),
        ],
        interpret=interpret,
        **_params(gpu, interpret),
    )
    return tuple(fn(xr, xi, *(jnp.asarray(a) for a in planes)))
