"""Pallas TPU kernels for the paper's compute hot-spot (the FFT itself).

dft_matmul  direct DFT GEMM (N <= 1024, paper's 1-call regime)
fft4step    fused four-step (N <= 65536, one HBM round trip)
pencil      strided-pencil pass kernels (split regime: in-place column
            passes, fused natural-order writes, rfft recombination)
ops         jit wrappers + the linearized pass-program executor
ref         oracles (naive float64 DFT, jnp.fft, four-step reference)
"""

from repro.kernels import ops, pencil, ref
from repro.kernels.dft_matmul import dft_matmul_call, dft_tile
from repro.kernels.fft4step import fft4step_call, four_step_tile

__all__ = [
    "ops",
    "pencil",
    "ref",
    "dft_matmul_call",
    "dft_tile",
    "fft4step_call",
    "four_step_tile",
]
