"""Pallas TPU kernels for the paper's compute hot-spot (the FFT itself).

dft_matmul  direct DFT GEMM (N <= 1024, paper's 1-call regime)
fft4step    fused four-step (N <= 65536, one HBM round trip)
ops         jit wrappers + plan-driven recursion (2-/3-call regimes)
ref         oracles (naive float64 DFT, jnp.fft, four-step reference)
"""

from repro.kernels import ops, ref
from repro.kernels.dft_matmul import dft_matmul_call
from repro.kernels.fft4step import fft4step_call

__all__ = ["ops", "ref", "dft_matmul_call", "fft4step_call"]
