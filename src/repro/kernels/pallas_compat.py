"""Version-tolerant shims over the Pallas TPU/Triton API surface.

The TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``), and the Triton
variant moved between ``pl.triton`` spellings.  Kernels go through
:func:`compiler_params` (TPU) / :func:`gpu_compiler_params` (Triton) so
either spelling works without pinning JAX.  GPU-path kernels must never
receive TPU params (``dimension_semantics`` is a Mosaic concept); they pass
``gpu_compiler_params(...)``, which degrades to ``None`` where the Triton
dataclass is unavailable (pure-interpret environments).
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["compiler_params", "gpu_compiler_params"]

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if _COMPILER_PARAMS_CLS is None:  # pragma: no cover - very old/new pallas
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported JAX version"
    )


def compiler_params(**kwargs):
    """Build the TPU compiler-params object under either JAX naming."""
    return _COMPILER_PARAMS_CLS(**kwargs)


def gpu_compiler_params(num_warps: int = 4, num_stages: int = 2):
    """Triton compiler params under any available spelling, else ``None``.

    ``None`` is a valid ``pallas_call`` argument everywhere (including
    interpret mode), so callers can pass the result unconditionally.
    """
    try:
        from jax.experimental.pallas import triton as plt
    except Exception:  # pragma: no cover - no Triton lowering available
        return None
    cls = getattr(plt, "CompilerParams", getattr(plt, "TritonCompilerParams", None))
    if cls is None:  # pragma: no cover
        return None
    try:
        return cls(num_warps=num_warps, num_stages=num_stages)
    except TypeError:  # pragma: no cover - signature drift
        return cls()
