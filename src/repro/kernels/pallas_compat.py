"""Version-tolerant shims over the Pallas TPU API surface.

The TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``).  Kernels go through
:func:`compiler_params` so either spelling works without pinning JAX.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["compiler_params"]

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if _COMPILER_PARAMS_CLS is None:  # pragma: no cover - very old/new pallas
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported JAX version"
    )


def compiler_params(**kwargs):
    """Build the TPU compiler-params object under either JAX naming."""
    return _COMPILER_PARAMS_CLS(**kwargs)
