"""Direct DFT-by-matmul Pallas kernel — the N ≤ 1024 one-call regime.

Paper §2.3.2: "When the data quantity is less than 1024, we don't need to
divide" — the whole transform runs in shared memory from one kernel launch.
TPU translation: the whole batch tile, the DFT matrix and the result are
co-resident in VMEM, and the transform is a single (bt, N) × (N, N) MXU
matmul per plane combination:

    Y = X @ W,   W[n, k] = exp(∓2πi·n·k/N)

The DFT matrix enters through a BlockSpec whose index map pins every grid
step to the same block — Mosaic keeps it in VMEM across the whole batch grid,
which is exactly the texture-LUT behaviour of §2.3.1 (computed once, served
from the fast tier).  Complex arithmetic uses the 3-GEMM Karatsuba split.
Inverse scaling (1/N) is folded into the W operand by the wrapper: zero extra
arithmetic, the LUT *is* the scaled table.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params

__all__ = ["dft_matmul_call"]


def _kernel(x_r, x_i, w_r, w_i, o_r, o_i):
    xr, xi = x_r[...], x_i[...]
    wr, wi = w_r[...], w_i[...]
    dot = functools.partial(
        jnp.dot, preferred_element_type=jnp.float32
    )
    # Karatsuba: 3 real GEMMs instead of 4.
    k1 = dot(xr + xi, wr)
    k2 = dot(xr, wi - wr)
    k3 = dot(xi, wr + wi)
    o_r[...] = k1 - k3
    o_i[...] = k1 + k2


def dft_matmul_call(
    xr: jax.Array,
    xi: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    *,
    batch_tile: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """y = x @ W for split-complex x:(B, N), W:(N, N); B % batch_tile == 0."""
    b, n = xr.shape
    assert b % batch_tile == 0, (b, batch_tile)
    grid = (b // batch_tile,)
    sig_spec = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    lut_spec = pl.BlockSpec((n, n), lambda i: (0, 0))  # VMEM-resident LUT
    out_shape = [
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    ]
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[sig_spec, sig_spec, lut_spec, lut_spec],
        out_specs=[sig_spec, sig_spec],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)
        ),
    )
    return tuple(fn(xr, xi, wr, wi))
