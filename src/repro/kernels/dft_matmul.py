"""Direct DFT-by-matmul Pallas kernel — the N ≤ 1024 one-call regime.

Paper §2.3.2: "When the data quantity is less than 1024, we don't need to
divide" — the whole transform runs in shared memory from one kernel launch.
TPU translation: the whole batch tile, the DFT matrix and the result are
co-resident in VMEM, and the transform is a single (bt, N) × (N, N) MXU
matmul per plane combination:

    Y = X @ W,   W[n, k] = exp(∓2πi·n·k/N)

The DFT matrix enters through a BlockSpec whose index map pins every grid
step to the same block — Mosaic keeps it in VMEM across the whole batch grid,
which is exactly the texture-LUT behaviour of §2.3.1 (computed once, served
from the fast tier).  Complex arithmetic uses the 3-GEMM Karatsuba split.
Inverse scaling (1/N) is folded into the W operand by the wrapper: zero extra
arithmetic, the LUT *is* the scaled table.

:func:`dft_tile` is the reusable VMEM tile transform the pass-program
kernels (``repro.kernels.pencil``) embed for their strided-column and
transposed-write passes, and ``dft_matmul_call`` grows a post-GEMM per-bin
twiddle epilogue (``twiddle``) so a multiplicative phase stage rides the
same HBM round trip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fft_xla import cmul
from repro.kernels.pallas_compat import compiler_params

__all__ = ["dft_matmul_call", "dft_tile"]


def dft_tile(xr, xi, wr, wi):
    """Y = X @ W on a VMEM-resident (bt, n) tile — Karatsuba, 3 real GEMMs.

    Pure jnp on arrays already in VMEM; callable from any Pallas kernel body.
    """
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    k1 = dot(xr + xi, wr)
    k2 = dot(xr, wi - wr)
    k3 = dot(xi, wr + wi)
    return k1 - k3, k1 + k2


def _make_kernel(has_epilogue: bool):
    def kernel(x_r, x_i, w_r, w_i, *rest):
        if has_epilogue:
            e_r, e_i, o_r, o_i = rest
        else:
            o_r, o_i = rest
        yr, yi = dft_tile(x_r[...], x_i[...], w_r[...], w_i[...])
        if has_epilogue:
            # Post-GEMM per-bin twiddle: y[b, k] *= e[k] (split complex).
            yr, yi = cmul(yr, yi, e_r[...], e_i[...])
        o_r[...] = yr
        o_i[...] = yi

    return kernel


def dft_matmul_call(
    xr: jax.Array,
    xi: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    *,
    batch_tile: int,
    twiddle: tuple[jax.Array, jax.Array] | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """y = x @ W for split-complex x:(B, N), W:(N, N); B % batch_tile == 0.

    ``twiddle`` — optional (real, imag) per-bin phasors of shape (N,),
    multiplied into the result in the VMEM epilogue.
    """
    b, n = xr.shape
    assert b % batch_tile == 0, (b, batch_tile)
    grid = (b // batch_tile,)
    sig_spec = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    lut_spec = pl.BlockSpec((n, n), lambda i: (0, 0))  # VMEM-resident LUT
    in_specs = [sig_spec, sig_spec, lut_spec, lut_spec]
    operands = [xr, xi, wr, wi]
    if twiddle is not None:
        er, ei = twiddle
        er = jnp.asarray(er, jnp.float32).reshape(1, n)
        ei = jnp.asarray(ei, jnp.float32).reshape(1, n)
        tw_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
        in_specs += [tw_spec, tw_spec]
        operands += [er, ei]
    out_shape = [
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    ]
    fn = pl.pallas_call(
        _make_kernel(twiddle is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[sig_spec, sig_spec],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)
        ),
    )
    return tuple(fn(*operands))
