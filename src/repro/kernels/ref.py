"""Pure-jnp / numpy oracles for the FFT kernels.

Every Pallas kernel in this package is validated against these references in
``tests/test_kernels.py`` across shape/dtype sweeps (interpret mode on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["naive_dft", "jnp_fft", "jnp_fft_planes", "four_step_ref"]


def naive_dft(x: np.ndarray, inverse: bool = False) -> np.ndarray:
    """O(N²) float64 DFT over the last axis — the ground-truth oracle."""
    x = np.asarray(x, dtype=np.complex128)
    n = x.shape[-1]
    k = np.arange(n)
    sign = 2j if inverse else -2j
    w = np.exp(sign * np.pi * np.outer(k, k) / n)
    y = x @ w
    if inverse:
        y = y / n
    return y


def jnp_fft(x, inverse: bool = False):
    """XLA's native FFT (the repo's "CUFFT" stand-in)."""
    return jnp.fft.ifft(x) if inverse else jnp.fft.fft(x)


def jnp_fft_planes(xr, xi, inverse: bool = False):
    x = jax.lax.complex(jnp.asarray(xr, jnp.float32), jnp.asarray(xi, jnp.float32))
    y = jnp_fft(x, inverse)
    return jnp.real(y), jnp.imag(y)


def four_step_ref(x: np.ndarray, n1: int, n2: int, inverse: bool = False) -> np.ndarray:
    """Numpy four-step reference mirroring the fused kernel's dataflow.

    x: (..., n1*n2) complex.  Returns natural-order transform, computed via
    the same (W1·X ⊙ T)·W2 factorisation the kernel uses, in float64 — used
    to localise kernel bugs independently of factorisation bugs.
    """
    x = np.asarray(x, dtype=np.complex128)
    n = n1 * n2
    sign = 2j if inverse else -2j
    j1 = np.arange(n1)
    j2 = np.arange(n2)
    w1 = np.exp(sign * np.pi * np.outer(j1, j1) / n1)
    w2 = np.exp(sign * np.pi * np.outer(j2, j2) / n2)
    # T[j1, j2] = exp(∓2πi·j1·j2/n); sign = ∓2j already carries the 2.
    tw = np.exp(sign * np.pi * np.outer(j1, j2) / n)
    X = x.reshape(*x.shape[:-1], n1, n2)
    A = np.einsum("ij,...jk->...ik", w1, X)
    B = A * tw
    C = np.einsum("...ij,jk->...ik", B, w2)
    out = np.swapaxes(C, -1, -2).reshape(*x.shape[:-1], n)
    if inverse:
        out = out / n
    return out
