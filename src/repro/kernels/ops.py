"""Jit-ready wrappers around the Pallas FFT kernels.

``ops.execute_plan`` *consumes* an :class:`repro.core.plan.FFTPlan` — the
split levels and leaf passes are read off the plan rather than re-derived by
calling ``balanced_split`` at every recursion, so the schedule the planner
(and the tests) reason about is exactly the schedule that executes:

* leaf ``direct``   → one :func:`dft_matmul_call`
* leaf ``fused4``   → one :func:`fft4step_call` (one HBM round trip)
* each plan level   → ops-level split (the paper's 2-call / 3-call regimes):
  reshape → column pass (kernel) → twiddle → row pass (kernel) →
  natural-order transpose, recursing per the plan's level table.

Responsibilities handled here so kernels stay minimal: batch flattening and
tile padding, LUT construction (host-cached, inverse scaling folded into W2 /
W), interpret-mode selection (auto on CPU), and plan-consistent recursion.
``ops.fft``/``ops.ifft`` remain as plan-deriving conveniences.
"""

from __future__ import annotations

import functools
import os
from typing import Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core import twiddle as tw
from repro.core.fft_xla import cmul
from repro.kernels.dft_matmul import dft_matmul_call
from repro.kernels.fft4step import fft4step_call

Planes = Tuple[jax.Array, jax.Array]

__all__ = ["execute_plan", "fft", "ifft", "should_interpret"]


def should_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=256)
def _direct_luts(n: int, inverse: bool):
    wr, wi = tw.dft_matrix(n, inverse)
    if inverse:
        wr = wr / np.float32(n)  # fold 1/N into the LUT
        wi = wi / np.float32(n)
    return wr, wi


@functools.lru_cache(maxsize=256)
def _fused_luts(n1: int, n2: int, inverse: bool):
    w1r, w1i = tw.dft_matrix(n1, inverse)
    tr, ti = tw.twiddle_grid(n1, n2, inverse)
    w2r, w2i = tw.dft_matrix(n2, inverse)
    if inverse:
        s = np.float32(1.0 / (n1 * n2))
        w2r, w2i = w2r * s, w2i * s
    return w1r, w1i, tr, ti, w2r, w2i


def _pad_batch(xr, xi, bt):
    b = xr.shape[0]
    pad = (-b) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    return xr, xi, b


def _tile_for(p: plan_lib.Pass, batch_tiles: Mapping[int, int] | None) -> int:
    if batch_tiles is not None and p.n in batch_tiles:
        return batch_tiles[p.n]
    return plan_lib.pick_batch_tile(p)


def _leaf_kernel(xr, xi, p: plan_lib.Pass, inverse, interpret, batch_tiles) -> Planes:
    """Single-pallas_call transform of the last axis (2-D input), executing
    the plan's leaf :class:`~repro.core.plan.Pass` as scheduled."""
    if p.n == 1:
        return xr, xi
    bt = _tile_for(p, batch_tiles)
    xr, xi, b = _pad_batch(xr, xi, bt)
    if p.kind == "direct":
        wr, wi = _direct_luts(p.n, inverse)
        yr, yi = dft_matmul_call(
            xr, xi, jnp.asarray(wr), jnp.asarray(wi), batch_tile=bt, interpret=interpret
        )
        return yr[:b], yi[:b]
    w1r, w1i, tr, ti, w2r, w2i = _fused_luts(p.n1, p.n2, inverse)
    yr, yi = fft4step_call(
        xr,
        xi,
        jnp.asarray(w1r),
        jnp.asarray(w1i),
        jnp.asarray(tr),
        jnp.asarray(ti),
        jnp.asarray(w2r),
        jnp.asarray(w2i),
        batch_tile=bt,
        interpret=interpret,
    )
    return yr[:b], yi[:b]


def _transform(xr, xi, n, fft_plan, inverse, interpret, batch_tiles) -> Planes:
    """Transform last axis of 2-D (B, n) input, walking the plan's levels."""
    level = fft_plan.level_for(n)
    if level is None:
        return _leaf_kernel(
            xr, xi, fft_plan.leaf_pass(n), inverse, interpret, batch_tiles
        )
    # Split level — one extra HBM round trip (paper's 2nd/3rd kernel call).
    n1, n2 = level
    b = xr.shape[0]
    xr = xr.reshape(b, n1, n2)
    xi = xi.reshape(b, n1, n2)
    # Column pass: transform over n1.  Fold the batch into rows so the leaf
    # kernel always sees (rows, n_leaf).
    xr = jnp.swapaxes(xr, -1, -2).reshape(b * n2, n1)
    xi = jnp.swapaxes(xi, -1, -2).reshape(b * n2, n1)
    xr, xi = _transform(xr, xi, n1, fft_plan, inverse, interpret, batch_tiles)
    # Twiddle in (n2, n1) layout (traced: too large to embed).
    tr, ti = tw.traced_twiddle(n2, n1, inverse)
    xr = xr.reshape(b, n2, n1)
    xi = xi.reshape(b, n2, n1)
    xr, xi = cmul(xr, xi, tr, ti)
    # Row pass: transform over n2.
    xr = jnp.swapaxes(xr, -1, -2).reshape(b * n1, n2)
    xi = jnp.swapaxes(xi, -1, -2).reshape(b * n1, n2)
    xr, xi = _transform(xr, xi, n2, fft_plan, inverse, interpret, batch_tiles)
    # Natural order: X[k1 + n1·k2] = C[k1, k2] → flatten Cᵀ.
    xr = jnp.swapaxes(xr.reshape(b, n1, n2), -1, -2).reshape(b, n1 * n2)
    xi = jnp.swapaxes(xi.reshape(b, n1, n2), -1, -2).reshape(b, n1 * n2)
    return xr, xi


def execute_plan(
    xr: jax.Array,
    xi: jax.Array,
    fft_plan: plan_lib.FFTPlan,
    *,
    inverse: bool = False,
    interpret: bool | None = None,
    batch_tiles: Mapping[int, int] | None = None,
) -> Planes:
    """Execute a pre-computed :class:`~repro.core.plan.FFTPlan` with the
    Pallas kernels over the last axis (any leading batch dims).

    ``batch_tiles`` (leaf length → tile) lets a :class:`PlannedFFT` carry the
    negotiated tile sizes; unlisted leaves fall back to the VMEM-budget pick.
    """
    if interpret is None:
        interpret = should_interpret()
    n = xr.shape[-1]
    if n != fft_plan.n:
        raise ValueError(f"plan is for n={fft_plan.n}, input has n={n}")
    lead = xr.shape[:-1]
    b = int(np.prod(lead)) if lead else 1
    yr, yi = _transform(
        xr.reshape(b, n), xi.reshape(b, n), n, fft_plan, inverse, interpret, batch_tiles
    )
    # Inverse scaling is folded into the leaf LUTs (1/n_leaf each); the split
    # levels multiply the partial scalings so the total is exactly 1/n.
    return yr.reshape(*lead, n), yi.reshape(*lead, n)


def fft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    inverse: bool = False,
    interpret: bool | None = None,
) -> Planes:
    """Plan-deriving convenience: plans ``n`` and calls :func:`execute_plan`."""
    n = xr.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length must be a power of two, got {n}")
    return execute_plan(
        xr, xi, plan_lib.plan_fft(n), inverse=inverse, interpret=interpret
    )


def ifft(xr, xi, *, interpret: bool | None = None) -> Planes:
    return fft(xr, xi, inverse=True, interpret=interpret)
