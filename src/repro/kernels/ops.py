"""Jit-ready wrappers around the Pallas FFT kernels.

``ops.execute_plan`` *consumes* an :class:`repro.core.plan.FFTPlan` by
walking its **linearized pass program** (:attr:`FFTPlan.passes`) with
:func:`execute_program` — an iterative executor, not a recursion.  Every
program pass is exactly one ``pallas_call`` HBM round trip:

* whole-signal pass  → :func:`dft_matmul_call` / :func:`fft4step_call`
  (the ≤ FUSED_MAX one-call regimes);
* strided-column pass → :func:`~repro.kernels.pencil.cols_pass_call`, which
  reads/writes the ``(b, n1, n2)`` view's columns in place and applies the
  inter-factor twiddle as its VMEM epilogue;
* contiguous-row pass → :func:`~repro.kernels.pencil.rows_natural_call`
  when the natural-order transpose is fused into its strided write, or the
  plain leaf kernel for pencil-order output.

Between passes the executor only reshapes (row-major views — no data
movement); there are **zero** standalone HBM ``swapaxes``/transpose or
twiddle ``cmul`` ops in the schedule, which is what makes the split regime
match the paper's §2.3.2 call-count discipline (and beat it: two round trips
cover every N ≤ 2³²).  The tests assert this over the jaxpr.

Responsibilities handled here so kernels stay minimal: batch flattening and
tile padding, LUT construction (host-cached, inverse scaling folded into W2 /
W; the inter-factor twiddle grids cached per (bins, phases) pair), interpret-
mode selection (auto on CPU), and per-pass chunk sizing against the VMEM
budget.  ``ops.fft``/``ops.ifft`` remain as plan-deriving conveniences.
"""

from __future__ import annotations

import functools
import os
from typing import Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core import plan as plan_lib
from repro.core import twiddle as tw
from repro.kernels.dft_matmul import dft_matmul_call
from repro.kernels.fft4step import fft4step_call
from repro.kernels import pencil

Planes = Tuple[jax.Array, jax.Array]

__all__ = [
    "execute_plan",
    "execute_program",
    "execute_program2d",
    "fft",
    "ifft",
    "should_interpret",
]


def should_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=256)
def _direct_luts(n: int, inverse: bool):
    wr, wi = tw.dft_matrix(n, inverse)
    if inverse:
        wr = wr / np.float32(n)  # fold 1/N into the LUT
        wi = wi / np.float32(n)
    return wr, wi


@functools.lru_cache(maxsize=256)
def _fused_luts(n1: int, n2: int, inverse: bool):
    w1r, w1i = tw.dft_matrix(n1, inverse)
    tr, ti = tw.twiddle_grid(n1, n2, inverse)
    w2r, w2i = tw.dft_matrix(n2, inverse)
    if inverse:
        s = np.float32(1.0 / (n1 * n2))
        w2r, w2i = w2r * s, w2i * s
    return w1r, w1i, tr, ti, w2r, w2i


@functools.lru_cache(maxsize=64)
def _pass_twiddle_luts(n_bins: int, n_phases: int, inverse: bool):
    """Host-cached inter-factor twiddle grid for a program pass's epilogue
    (served to the kernel chunk-by-chunk through its BlockSpec)."""
    return tw.pass_twiddle(n_bins, n_phases, inverse)


def _transform_luts(p: plan_lib.Pass, inverse: bool):
    if p.kind == "direct":
        return _direct_luts(p.n, inverse)
    return _fused_luts(p.n1, p.n2, inverse)


def _bluestein_luts(p: plan_lib.Pass, inverse: bool):
    """The LUT tuple of one Bluestein pass stage, host-cached piecewise.

    The chirp planes and B̂ spectrum come from the interned
    :mod:`repro.core.twiddle` caches (computed once per (n, pad,
    direction), like every twiddle table); the fused ``fwd``/``inv``
    stages additionally carry the pad-length transform's own LUTs.  The
    INNER conv direction is fixed — forward then inverse — regardless of
    ``inverse``, which only selects the chirp tables.
    """
    n, m_pad = p.n, p.n1
    if p.stage == "pre":
        ar, ai = tw.bluestein_chirp(n, inverse)
        return (ar.reshape(1, n), ai.reshape(1, n))
    if p.stage == "mul":
        br, bi = tw.bluestein_spectrum(n, m_pad, inverse)
        return (br.reshape(1, m_pad), bi.reshape(1, m_pad))
    if p.stage == "post":
        pr, pi = tw.bluestein_postchirp(n, inverse)
        return (pr.reshape(1, n), pi.reshape(1, n))
    inner = plan_lib._leaf_pass(m_pad)
    if p.stage == "fwd":
        ar, ai = tw.bluestein_chirp(n, inverse)
        inner_luts = (
            _direct_luts(m_pad, False)
            if inner.kind == "direct"
            else _fused_luts(inner.n1, inner.n2, False)
        )
        br, bi = tw.bluestein_spectrum(n, m_pad, inverse)
        return (
            ar.reshape(1, n), ai.reshape(1, n),
            *inner_luts,
            br.reshape(1, m_pad), bi.reshape(1, m_pad),
        )
    if p.stage != "inv":
        raise ValueError(f"unknown bluestein stage {p.stage!r}")
    inner_luts = (
        _direct_luts(m_pad, True)
        if inner.kind == "direct"
        else _fused_luts(inner.n1, inner.n2, True)
    )
    pr, pi = tw.bluestein_postchirp(n, inverse)
    return (*inner_luts, pr.reshape(1, n), pi.reshape(1, n))


def _bluestein_pass(
    xr, xi, p: plan_lib.Pass, inverse, interpret, bt, gpu: bool = False
) -> Planes:
    """One Bluestein program pass (any stage) as a single pallas_call."""
    from repro.kernels import bluestein as bk

    n, m_pad = p.n, p.n1
    xr, xi, b, pad = _pad_batch(xr, xi, bt)
    luts = _bluestein_luts(p, inverse)
    kw = dict(n=n, m_pad=m_pad, batch_tile=bt, interpret=interpret, gpu=gpu)
    if p.stage in ("fwd", "inv"):
        inner = plan_lib._leaf_pass(m_pad)
        call = bk.bluestein_fwd_call if p.stage == "fwd" else bk.bluestein_inv_call
        yr, yi = call(
            xr, xi, luts, inner_kind=inner.kind, in1=inner.n1, in2=inner.n2, **kw
        )
    else:
        yr, yi = bk.bluestein_elem_call(xr, xi, luts, stage=p.stage, **kw)
    return (yr, yi) if pad == 0 else (yr[:b], yi[:b])


def _pad_batch(xr, xi, bt):
    b = xr.shape[0]
    pad = (-b) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    return xr, xi, b, pad


def _tile_for(p: plan_lib.Pass, batch_tiles: Mapping[int, int] | None) -> int:
    if batch_tiles is not None and p.n in batch_tiles:
        return batch_tiles[p.n]
    return plan_lib.pick_batch_tile(p)


def _leaf_kernel(
    xr, xi, p: plan_lib.Pass, inverse, interpret, batch_tiles, natural_order=True
) -> Planes:
    """Single-pallas_call transform of the last axis (2-D input)."""
    if p.kind == "bluestein":
        return _bluestein_pass(xr, xi, p, inverse, interpret, _tile_for(p, batch_tiles))
    if p.n == 1:
        return xr, xi
    bt = _tile_for(p, batch_tiles)
    xr, xi, b, pad = _pad_batch(xr, xi, bt)
    if p.kind == "direct":
        wr, wi = _direct_luts(p.n, inverse)
        yr, yi = dft_matmul_call(
            xr, xi, jnp.asarray(wr), jnp.asarray(wi), batch_tile=bt, interpret=interpret
        )
    else:
        w1r, w1i, tr, ti, w2r, w2i = _fused_luts(p.n1, p.n2, inverse)
        yr, yi = fft4step_call(
            xr,
            xi,
            jnp.asarray(w1r),
            jnp.asarray(w1i),
            jnp.asarray(tr),
            jnp.asarray(ti),
            jnp.asarray(w2r),
            jnp.asarray(w2i),
            batch_tile=bt,
            natural_order=natural_order,
            interpret=interpret,
        )
    # The identity slice would still cost a jaxpr eqn — keep unpadded
    # schedules at pallas_call + reshape only.
    return (yr, yi) if pad == 0 else (yr[:b], yi[:b])


def _apply_pass(
    xr, xi, p: plan_lib.Pass, fs, inverse, interpret, batch_tiles, chunk=None,
    degradations=None, index=None,
) -> Planes:
    """One row-axis program pass over (B, n) split planes.  ``chunk``
    overrides the VMEM-heuristic grid-step width (the tuner's hook).

    Kernel passes run under :func:`repro.core.faults.run_leaf`: a leaf
    that fails to trace/compile is retried once, then the (pallas, kind)
    pair is quarantined and the pass demotes to the traced-XLA fallback,
    recorded on ``degradations``.  The no-fault jaxpr is untouched."""
    # A pass may pin its own direction (the Bluestein inner conv is always
    # forward-then-inverse regardless of the outer transform's direction).
    inverse = p.inverse if p.inverse is not None else inverse
    b, n = xr.shape
    if p.kind == "reorder":
        # Digit-reversal relayout — only programs with ≥ 3 factors
        # (N > 2³²) reach this; plain XLA transpose, one HBM round trip.
        perm = (0,) + tuple(range(len(fs), 0, -1))
        xr = xr.reshape(b, *fs).transpose(perm).reshape(b, n)
        xi = xi.reshape(b, *fs).transpose(perm).reshape(b, n)
        return xr, xi
    return faults.run_leaf(
        "pallas",
        p.kind,
        lambda: _pass_kernel(xr, xi, p, inverse, interpret, batch_tiles, chunk),
        lambda: _row_pass_xla(xr, xi, p, inverse),
        degradations=degradations,
        index=index,
    )


def _row_pass_xla(xr, xi, p: plan_lib.Pass, inverse) -> Planes:
    """Traced-XLA execution of one row pass — the degradation target.

    Reuses the GPU backend's generic per-pass fallback (same LUT tables,
    same scaling convention), imported lazily: ``fft_gpu`` imports this
    module at load time.
    """
    from repro.kernels import fft_gpu

    return fft_gpu._xla_pass(xr, xi, p, [], inverse)


def _pass_kernel(
    xr, xi, p: plan_lib.Pass, inverse, interpret, batch_tiles, chunk
) -> Planes:
    """The pallas execution of one non-reorder row pass (direction already
    resolved by :func:`_apply_pass`)."""
    b, n = xr.shape
    pencils, stride, f = p.view_in
    if pencils == 1:
        # Whole-signal pass: the ≤ FUSED_MAX one-call regime.
        return _leaf_kernel(
            xr, xi, p, inverse, interpret, batch_tiles,
            natural_order=p.order == "natural",
        )
    luts = _transform_luts(p, inverse)
    width = stride if stride > 1 else pencils
    chunk = _fit_chunk(chunk, width, p) if chunk else plan_lib.pick_pass_chunk(p)
    if stride == 1:
        if p.view_out != p.view_in:
            # Row pass with the natural-order transpose fused into its
            # strided write: (b, p, f) → (b, f, p) flattens naturally.
            xr3 = xr.reshape(b, pencils, f)
            xi3 = xi.reshape(b, pencils, f)
            yr3, yi3 = pencil.rows_natural_call(
                xr3, xi3, luts, kind=p.kind, n1=p.n1, n2=p.n2,
                chunk=chunk, interpret=interpret,
            )
            return yr3.reshape(b, n), yi3.reshape(b, n)
        # Pencil-order row pass: contiguous rows, plain leaf kernel.
        rr = xr.reshape(b * pencils, f)
        ri = xi.reshape(b * pencils, f)
        rr, ri = _leaf_kernel(rr, ri, p, inverse, interpret, batch_tiles)
        return rr.reshape(b, n), ri.reshape(b, n)
    # Strided-column pass (+ fused inter-factor twiddle epilogue).
    groups = pencils // stride
    xr3 = xr.reshape(b * groups, f, stride)
    xi3 = xi.reshape(b * groups, f, stride)
    twiddle = None
    if p.twiddle_after is not None:
        twiddle = _pass_twiddle_luts(*p.twiddle_after, inverse)
    xr3, xi3 = pencil.cols_pass_call(
        xr3, xi3, luts, twiddle, kind=p.kind, n1=p.n1, n2=p.n2,
        chunk=chunk, interpret=interpret,
    )
    return xr3.reshape(b, n), xi3.reshape(b, n)


def image_chunk(p: plan_lib.Pass, w: int) -> int:
    """Column-pass chunk for an image of width ``w``.  Ragged widths (the
    m+1 half-spectrum bins of rfft2): a chunk near the width would nearly
    double the pass (pow2-floored chunk + 1 ragged column → a whole extra
    chunk of padding), so shrink until the padding is under half a chunk —
    but not below one 128-lane tile."""
    chunk = plan_lib.pick_pass_chunk(p, width=w)
    while chunk > 128 and (-w) % chunk >= chunk // 2:
        chunk //= 2
    return chunk


def _fit_chunk(c: int, w: int, p: plan_lib.Pass) -> int:
    """Clamp a (possibly tuned) chunk to the width and the VMEM budget —
    a cache entry tuned for one shape must not break another."""
    c = max(1, min(c, 1 << (max(w, 1).bit_length() - 1)))
    while c > 1 and plan_lib._pass_chunk_bytes(p, c) > plan_lib.VMEM_BUDGET:
        c //= 2
    return c


def _cols_image_pass(
    xr, xi, p: plan_lib.Pass, inverse, interpret, chunk=None,
    degradations=None, index=None,
) -> Planes:
    """Column pass of a 2-D program, with the same retry → quarantine →
    traced-XLA degradation protocol as the row passes (see
    :func:`_apply_pass`)."""
    return faults.run_leaf(
        "pallas",
        p.kind,
        lambda: _cols_image_kernel(xr, xi, p, inverse, interpret, chunk),
        lambda: _cols_image_xla(xr, xi, p, inverse),
        degradations=degradations,
        index=index,
    )


def _cols_image_xla(xr, xi, p: plan_lib.Pass, inverse) -> Planes:
    """Traced-XLA execution of an axis -2 column pass (degradation target):
    materialize the width transpose, run the generic 1-D fallback over the
    column axis, transpose back."""
    from repro.kernels import fft_gpu

    b, rows, w = xr.shape
    pencils, stride, f = p.view_in if p.view_in else (1, 1, p.n)
    xt_r = jnp.swapaxes(xr, -1, -2).reshape(b * w, rows)
    xt_i = jnp.swapaxes(xi, -1, -2).reshape(b * w, rows)
    if pencils == 1 or f == rows:
        # Whole-column transform (incl. the distributed driver's synthetic
        # (q, q, n) pass): one natural-order row transform of length rows.
        luts = _transform_luts(p, inverse)
        yr, yi = fft_gpu._row_transform_xla(
            xt_r, xt_i, p, luts, natural=p.order == "natural"
        )
    else:
        # Strip-mined column factor: the re-tagged 1-D split program of the
        # n2 axis applies verbatim on the transposed (B·w, n2) view.
        yr, yi = fft_gpu._xla_pass(xt_r, xt_i, p, [], inverse)
    yr = yr.reshape(b, w, rows).swapaxes(-1, -2)
    yi = yi.reshape(b, w, rows).swapaxes(-1, -2)
    return yr, yi


def _cols_image_kernel(xr, xi, p: plan_lib.Pass, inverse, interpret, chunk=None) -> Planes:
    """Column pass of a 2-D program: transform axis -2 of the (B, n2, w)
    image view through the strided-pencil kernels, sweeping the image width
    chunk-by-chunk (``chunk`` overrides the VMEM-heuristic width — the
    tuner's hook).  Non-power-of-two widths (the m+1 bins of an rfft2
    half-spectrum) pad up to a chunk multiple around the call.

    Fused-regime columns (``view_in == (1, 1, n2)``) are one in-place
    whole-column pass.  Strip-mined columns (``n2 > FUSED_MAX``) arrive as
    the re-tagged 1-D program of the n2 axis: the strided factor runs
    through :func:`~repro.kernels.pencil.cols_pass_call` on the
    ``(B, f, stride·w)`` view with its inter-factor twiddle broadcast
    across the width in VMEM, and the final contiguous factor through
    :func:`~repro.kernels.pencil.cols_natural_call`, which fuses the
    n2-axis digit transpose into its strided write — zero standalone HBM
    transposes either way."""
    b, rows, w = xr.shape
    pencils, stride, f = p.view_in if p.view_in else (1, 1, p.n)
    luts = _transform_luts(p, inverse)
    chunk = _fit_chunk(chunk, w, p) if chunk else image_chunk(p, w)
    pad = (-w) % chunk
    if pad:
        xr = jnp.pad(xr, ((0, 0), (0, 0), (0, pad)))
        xi = jnp.pad(xi, ((0, 0), (0, 0), (0, pad)))
    wp = w + pad
    if pencils == 1 or f == rows:
        # Whole-column pass: the transform spans the full -2 axis — the
        # fused-regime n2 ≤ FUSED_MAX case, or the distributed driver's
        # synthetic (q, q, n) plan pass over a width-q slab.
        yr, yi = pencil.cols_pass_call(
            xr, xi, luts, kind=p.kind, n1=p.n1, n2=p.n2,
            chunk=chunk, interpret=interpret,
        )
    elif stride > 1:
        # Strided column factor: n2-index = t·stride + r, transform over t.
        # The (r, image-width) pair rides along as the kernel's pencil
        # columns; the twiddle phase depends only on r, so the (f, stride)
        # grid is served one column per chunk and width-broadcast in VMEM.
        assert pencils == stride, (p.view_in, "≥3-factor columns are gated")
        x3r = xr.reshape(b, f, stride * wp)
        x3i = xi.reshape(b, f, stride * wp)
        twiddle = None
        if p.twiddle_after is not None:
            twiddle = _pass_twiddle_luts(*p.twiddle_after, inverse)
        yr, yi = pencil.cols_pass_call(
            x3r, x3i, luts, twiddle, kind=p.kind, n1=p.n1, n2=p.n2,
            chunk=chunk, interpret=interpret, tw_every=wp,
        )
        yr = yr.reshape(b, rows, wp)
        yi = yi.reshape(b, rows, wp)
    else:
        # Final contiguous factor, natural-order digit transpose fused
        # into the write: (B, P, f, wp) → (B, f, P, wp).
        if p.view_out == p.view_in:
            raise NotImplementedError(
                "pencil-order strip-mined column programs are not compiled"
            )
        x4r = xr.reshape(b, pencils, f, wp)
        x4i = xi.reshape(b, pencils, f, wp)
        yr, yi = pencil.cols_natural_call(
            x4r, x4i, luts, kind=p.kind, n1=p.n1, n2=p.n2,
            chunk=chunk, interpret=interpret,
        )
        yr = yr.reshape(b, rows, wp)
        yi = yi.reshape(b, rows, wp)
    if pad:
        yr, yi = yr[..., :w], yi[..., :w]
    return yr, yi


def execute_program(
    xr: jax.Array,
    xi: jax.Array,
    passes: Sequence[plan_lib.Pass],
    *,
    inverse: bool = False,
    interpret: bool | None = None,
    batch_tiles: Mapping[int, int] | None = None,
    chunks: Mapping[int, int] | None = None,
    degradations: list | None = None,
) -> Planes:
    """Walk a linearized pass program over 2-D (B, n) split planes.

    One ``pallas_call`` per pass; the only ops between passes are row-major
    reshapes (views, no HBM traffic).  ``chunks`` (pass index → grid-step
    width) carries the tuner's per-pass picks; unlisted passes fall back to
    the VMEM-budget heuristic.  ``degradations`` (a plan's ledger) collects
    any leaf demoted to the traced-XLA fallback.
    """
    if interpret is None:
        interpret = should_interpret()
    fs = [q.n for q in passes if q.kind != "reorder"]
    for i, p in enumerate(passes):
        xr, xi = _apply_pass(
            xr, xi, p, fs, inverse, interpret, batch_tiles,
            chunk=chunks.get(i) if chunks else None,
            degradations=degradations, index=i,
        )
    return xr, xi


def execute_program2d(
    xr: jax.Array,
    xi: jax.Array,
    passes: Sequence[plan_lib.Pass],
    *,
    inverse: bool = False,
    interpret: bool | None = None,
    batch_tiles: Mapping[int, int] | None = None,
    chunks: Mapping[int, int] | None = None,
    degradations: list | None = None,
) -> Planes:
    """Walk a mixed-axis pass program over 3-D (B, n2, n) image planes.

    ``axis=-1`` passes run the 1-D machinery over the ``(B·n2, n)`` row
    view; ``axis=-2`` passes transform the columns of the ``(B, n2, n)``
    view through the strided-pencil kernels — in place for fused-regime
    column lengths, strip-mined (multi-factor, width-swept) beyond.  The
    row→column handoff is a free row-major reshape — zero materialized
    transposes, which is what makes a planned ``fft2`` exactly rows+cols
    kernel calls.  ``chunks`` maps pass index → tuned grid-step width.
    """
    if interpret is None:
        interpret = should_interpret()
    fs = [q.n for q in passes if q.kind != "reorder" and q.axis == -1]
    for i, p in enumerate(passes):
        # Re-read per pass: a Bluestein row program changes the row width
        # mid-program (n → pad → n).
        b, rows, n = xr.shape
        chunk = chunks.get(i) if chunks else None
        if p.axis == -2:
            xr, xi = _cols_image_pass(
                xr, xi, p, inverse, interpret, chunk=chunk,
                degradations=degradations, index=i,
            )
            continue
        xr2, xi2 = _apply_pass(
            xr.reshape(b * rows, n), xi.reshape(b * rows, n),
            p, fs, inverse, interpret, batch_tiles, chunk=chunk,
            degradations=degradations, index=i,
        )
        w = xr2.shape[-1]
        xr, xi = xr2.reshape(b, rows, w), xi2.reshape(b, rows, w)
    return xr, xi


def _cols_plan_pass(fft_plan: plan_lib.FFTPlan, stride: int) -> plan_lib.Pass:
    """A synthetic strided-column pass running the whole plan's transform
    down the -2 axis of an (..., n, stride) view — the distributed pencil
    driver's local column transform, no materialized swapaxes."""
    leaf = fft_plan.passes[0]
    return plan_lib.Pass(
        kind=leaf.kind,
        n=fft_plan.n,
        n1=leaf.n1,
        n2=leaf.n2,
        view_in=(stride, stride, fft_plan.n),
        view_out=(stride, stride, fft_plan.n),
        order="natural",
    )


def execute_plan(
    xr: jax.Array,
    xi: jax.Array,
    fft_plan: plan_lib.FFTPlan,
    *,
    inverse: bool = False,
    interpret: bool | None = None,
    batch_tiles: Mapping[int, int] | None = None,
    order: str = "natural",
    axis: int = -1,
    chunks: Mapping[int, int] | None = None,
    degradations: list | None = None,
) -> Planes:
    """Execute a pre-computed :class:`~repro.core.plan.FFTPlan` with the
    Pallas kernels over ``axis`` (-1 or -2; any leading batch dims).

    ``batch_tiles`` (leaf length → tile) and ``chunks`` (pass index →
    grid-step width) let a :class:`PlannedFFT` carry its negotiated or
    tuned sizes; unlisted entries fall back to the VMEM-budget pick.
    ``order='pencil'`` leaves the spectrum in k₁-major pencil layout (the
    fft→pointwise→ifft fast path).  ``axis=-2`` transforms the second-to-last
    axis in place via the strided-column kernel when the plan is single-pass
    (the distributed pencil driver's case), falling back to a transpose
    sandwich otherwise.  A multi-axis plan (``fft_plan.n2`` set) consumes a
    3-D (..., n2, n) image and walks its joint program with
    :func:`execute_program2d`.
    """
    if interpret is None:
        interpret = should_interpret()
    if fft_plan.n2 is not None:
        if axis != -1:
            raise faults.PlanError(
                "multi-axis plans always transform the last two axes"
            )
        rows, n = xr.shape[-2:]
        if (rows, n) != (fft_plan.n2, fft_plan.n):
            raise faults.PlanError(
                f"plan is for ({fft_plan.n2}, {fft_plan.n}) images, got ({rows}, {n})"
            )
        lead = xr.shape[:-2]
        b = int(np.prod(lead)) if lead else 1
        yr, yi = execute_program2d(
            xr.reshape(b, rows, n),
            xi.reshape(b, rows, n),
            fft_plan.passes,
            inverse=inverse,
            interpret=interpret,
            batch_tiles=batch_tiles,
            chunks=chunks,
            degradations=degradations,
        )
        return yr.reshape(*lead, rows, n), yi.reshape(*lead, rows, n)
    if axis == -2:
        n, q = xr.shape[-2:]
        if n != fft_plan.n:
            raise faults.PlanError(f"plan is for n={fft_plan.n}, axis -2 has n={n}")
        lead = xr.shape[:-2]
        b = int(np.prod(lead)) if lead else 1
        if len(fft_plan.passes) == 1 and fft_plan.n > 1:
            p = _cols_plan_pass(fft_plan, q)
            yr, yi = _cols_image_pass(
                xr.reshape(b, n, q), xi.reshape(b, n, q), p, inverse, interpret,
                degradations=degradations,
            )
            return yr.reshape(*lead, n, q), yi.reshape(*lead, n, q)
        xr, xi = jnp.swapaxes(xr, -1, -2), jnp.swapaxes(xi, -1, -2)
        yr, yi = execute_plan(
            xr, xi, fft_plan, inverse=inverse, interpret=interpret,
            batch_tiles=batch_tiles, order=order, chunks=chunks,
            degradations=degradations,
        )
        return jnp.swapaxes(yr, -1, -2), jnp.swapaxes(yi, -1, -2)
    if axis != -1:
        raise faults.PlanError(f"execute_plan handles axis -1 or -2, got {axis}")
    n = xr.shape[-1]
    if n != fft_plan.n:
        raise faults.PlanError(f"plan is for n={fft_plan.n}, input has n={n}")
    passes = (
        fft_plan.passes
        if order == "natural"
        else plan_lib.compile_passes(fft_plan.n, order=order)
    )
    lead = xr.shape[:-1]
    b = int(np.prod(lead)) if lead else 1
    yr, yi = execute_program(
        xr.reshape(b, n),
        xi.reshape(b, n),
        passes,
        inverse=inverse,
        interpret=interpret,
        batch_tiles=batch_tiles,
        chunks=chunks,
        degradations=degradations,
    )
    # Inverse scaling is folded into each pass's transform LUT (1/f each);
    # the factors multiply so the total is exactly 1/n.
    return yr.reshape(*lead, n), yi.reshape(*lead, n)


def fft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    inverse: bool = False,
    interpret: bool | None = None,
) -> Planes:
    """Plan-deriving convenience: plans ``n`` and calls :func:`execute_plan`.

    Non-power-of-two lengths route through the planner's Bluestein leaf.
    """
    n = xr.shape[-1]
    return execute_plan(
        xr, xi, plan_lib.plan_fft(n), inverse=inverse, interpret=interpret
    )


def ifft(xr, xi, *, interpret: bool | None = None) -> Planes:
    return fft(xr, xi, inverse=True, interpret=interpret)
