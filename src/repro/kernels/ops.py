"""Jit-ready wrappers around the Pallas FFT kernels.

``ops.fft`` follows :mod:`repro.core.plan` exactly:

* N ≤ DIRECT_MAX           → one :func:`dft_matmul_call`
* DIRECT_MAX < N ≤ FUSED_MAX → one :func:`fft4step_call` (one HBM round trip)
* larger N                 → ops-level split levels (the paper's 2-call /
  3-call regimes): reshape → column pass (kernel) → twiddle → row pass
  (kernel) → natural-order transpose, recursing on factors.

Responsibilities handled here so kernels stay minimal: batch flattening and
tile padding, LUT construction (host-cached, inverse scaling folded into W2 /
W), interpret-mode selection (auto on CPU), and plan-consistent recursion.
"""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core import twiddle as tw
from repro.core.fft_xla import cmul
from repro.kernels.dft_matmul import dft_matmul_call
from repro.kernels.fft4step import fft4step_call

Planes = Tuple[jax.Array, jax.Array]

__all__ = ["fft", "ifft", "should_interpret"]


def should_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=256)
def _direct_luts(n: int, inverse: bool):
    wr, wi = tw.dft_matrix(n, inverse)
    if inverse:
        wr = wr / np.float32(n)  # fold 1/N into the LUT
        wi = wi / np.float32(n)
    return wr, wi


@functools.lru_cache(maxsize=256)
def _fused_luts(n1: int, n2: int, inverse: bool):
    w1r, w1i = tw.dft_matrix(n1, inverse)
    tr, ti = tw.twiddle_grid(n1, n2, inverse)
    w2r, w2i = tw.dft_matrix(n2, inverse)
    if inverse:
        s = np.float32(1.0 / (n1 * n2))
        w2r, w2i = w2r * s, w2i * s
    return w1r, w1i, tr, ti, w2r, w2i


def _pad_batch(xr, xi, bt):
    b = xr.shape[0]
    pad = (-b) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    return xr, xi, b


def _leaf_kernel(xr, xi, n, inverse, interpret) -> Planes:
    """Single-pallas_call transform of the last axis (2-D input)."""
    if n == 1:
        return xr, xi
    if n <= plan_lib.DIRECT_MAX:
        p = plan_lib.Pass(kind="direct", n=n)
        bt = plan_lib.pick_batch_tile(p)
        xr, xi, b = _pad_batch(xr, xi, bt)
        wr, wi = _direct_luts(n, inverse)
        yr, yi = dft_matmul_call(
            xr, xi, jnp.asarray(wr), jnp.asarray(wi), batch_tile=bt, interpret=interpret
        )
        return yr[:b], yi[:b]
    n1, n2 = plan_lib.balanced_split(n)
    p = plan_lib.Pass(kind="fused4", n=n, n1=n1, n2=n2)
    bt = plan_lib.pick_batch_tile(p)
    xr, xi, b = _pad_batch(xr, xi, bt)
    w1r, w1i, tr, ti, w2r, w2i = _fused_luts(n1, n2, inverse)
    yr, yi = fft4step_call(
        xr,
        xi,
        jnp.asarray(w1r),
        jnp.asarray(w1i),
        jnp.asarray(tr),
        jnp.asarray(ti),
        jnp.asarray(w2r),
        jnp.asarray(w2i),
        batch_tile=bt,
        interpret=interpret,
    )
    return yr[:b], yi[:b]


def _transform(xr, xi, n, inverse, interpret) -> Planes:
    """Transform last axis of 2-D (B, n) input, recursing per the plan."""
    if n <= plan_lib.FUSED_MAX:
        return _leaf_kernel(xr, xi, n, inverse, interpret)
    # Split level — one extra HBM round trip (paper's 2nd/3rd kernel call).
    n1, n2 = plan_lib.balanced_split(n, cap=plan_lib.FUSED_MAX)
    b = xr.shape[0]
    xr = xr.reshape(b, n1, n2)
    xi = xi.reshape(b, n1, n2)
    # Column pass: transform over n1.  Fold the batch into rows so the leaf
    # kernel always sees (rows, n_leaf).
    xr = jnp.swapaxes(xr, -1, -2).reshape(b * n2, n1)
    xi = jnp.swapaxes(xi, -1, -2).reshape(b * n2, n1)
    xr, xi = _transform(xr, xi, n1, inverse, interpret)
    # Twiddle in (n2, n1) layout (traced: too large to embed).
    tr, ti = tw.traced_twiddle(n2, n1, inverse)
    xr = xr.reshape(b, n2, n1)
    xi = xi.reshape(b, n2, n1)
    xr, xi = cmul(xr, xi, tr, ti)
    # Row pass: transform over n2.
    xr = jnp.swapaxes(xr, -1, -2).reshape(b * n1, n2)
    xi = jnp.swapaxes(xi, -1, -2).reshape(b * n1, n2)
    xr, xi = _transform(xr, xi, n2, inverse, interpret)
    # Natural order: X[k1 + n1·k2] = C[k1, k2] → flatten Cᵀ.
    xr = jnp.swapaxes(xr.reshape(b, n1, n2), -1, -2).reshape(b, n1 * n2)
    xi = jnp.swapaxes(xi.reshape(b, n1, n2), -1, -2).reshape(b, n1 * n2)
    return xr, xi


def fft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    inverse: bool = False,
    interpret: bool | None = None,
) -> Planes:
    """Pallas-backed FFT over the last axis (any leading batch dims)."""
    if interpret is None:
        interpret = should_interpret()
    n = xr.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length must be a power of two, got {n}")
    lead = xr.shape[:-1]
    b = int(np.prod(lead)) if lead else 1
    yr, yi = _transform(xr.reshape(b, n), xi.reshape(b, n), n, inverse, interpret)
    # Inverse scaling is folded into the leaf LUTs (1/n_leaf each); the split
    # levels multiply the partial scalings so the total is exactly 1/n.
    return yr.reshape(*lead, n), yi.reshape(*lead, n)


def ifft(xr, xi, *, interpret: bool | None = None) -> Planes:
    return fft(xr, xi, inverse=True, interpret=interpret)
