"""Strided-pencil Pallas kernels — the pass-program executors for the split
regime (N > FUSED_MAX).

The planner (``repro.core.plan.compile_passes``) linearizes a split-regime
transform into passes over *pencil views* of the flat buffer.  The two pass
shapes map onto two kernels, and all the glue the old recursion routed
through HBM (``swapaxes`` re-tilings, the inter-factor twiddle ``cmul``, the
natural-order transpose) happens inside their VMEM bodies:

``cols_pass_call``
    Transform along the **middle** axis of a ``(R, f, s)`` view — i.e. the
    strided columns of the ``(b, n1, n2)`` signal view, read and written in
    place through BlockSpecs that index ``(1, f, chunk)`` sub-blocks.  No
    materialized HBM ``swapaxes``: the (f, chunk) tile is transposed in VMEM,
    pushed through the shared tile engines (:func:`~repro.kernels.dft_matmul.
    dft_tile` for f ≤ 1024, :func:`~repro.kernels.fft4step.four_step_tile`
    beyond), transposed back, and multiplied by its chunk of the inter-factor
    twiddle grid (a host-cached LUT served chunk-by-chunk through its own
    BlockSpec — the paper's texture table, §2.3.1).

``rows_natural_call``
    Transform along the **last** axis of a ``(B, p, f)`` view and write each
    (chunk, f) result tile *transposed* into the ``(B, f, p)`` output view —
    the four-step natural-order transpose folded into the final pass's
    strided write (output BlockSpec ``(1, f, chunk)`` at column ``chunk``),
    costing zero standalone HBM transpose.

``rfft_recomb_call`` / ``irfft_recomb_call``
    The Hermitian even/odd recombination of the real-FFT packing as a single
    epilogue pass (one HBM round trip) instead of the ~10-op traced XLA glue:
    the whole half-spectrum row is VMEM-resident, so the Z[-k] reversal is an
    in-register ``flip``+``roll``.

Grid dimensions are ``parallel`` everywhere (no cross-step carries).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fft_xla import cmul, irfft_recomb, rfft_recomb
from repro.kernels.dft_matmul import dft_tile
from repro.kernels.fft4step import four_step_tile
from repro.kernels.pallas_compat import compiler_params

__all__ = [
    "cols_pass_call",
    "cols_natural_call",
    "rows_natural_call",
    "rfft_recomb_call",
    "irfft_recomb_call",
]


def _tile_transform(xr, xi, luts, kind: str, n1: int, n2: int):
    """Dispatch a (bt, f) VMEM tile to the shared direct/four-step engines."""
    if kind == "direct":
        wr, wi = luts
        return dft_tile(xr, xi, wr, wi)
    w1r, w1i, tr, ti, w2r, w2i = luts
    return four_step_tile(xr, xi, w1r, w1i, tr, ti, w2r, w2i, n1, n2, True)


def _lut_specs(kind: str, f: int, n1: int, n2: int, index_map):
    if kind == "direct":
        return [pl.BlockSpec((f, f), index_map)] * 2
    return (
        [pl.BlockSpec((n1, n1), index_map)] * 2
        + [pl.BlockSpec((n1, n2), index_map)] * 2
        + [pl.BlockSpec((n2, n2), index_map)] * 2
    )


def _as_ops(luts):
    return [jnp.asarray(a) for a in luts]


def _make_cols_kernel(kind: str, n1: int, n2: int, n_luts: int, has_tw: bool):
    def kernel(x_r, x_i, *rest):
        luts = [r[...] for r in rest[:n_luts]]
        if has_tw:
            t_r, t_i = rest[n_luts], rest[n_luts + 1]
        o_r, o_i = rest[-2], rest[-1]
        f, c = x_r.shape[1], x_r.shape[2]
        # (1, f, c) block → (c, f): the chunk's c pencils become tile rows.
        xr = x_r[...].reshape(f, c).swapaxes(0, 1)
        xi = x_i[...].reshape(f, c).swapaxes(0, 1)
        yr, yi = _tile_transform(xr, xi, luts, kind, n1, n2)
        yr = yr.swapaxes(0, 1)  # back to (f, c): bin-major, pencil columns
        yi = yi.swapaxes(0, 1)
        if has_tw:
            # Inter-factor twiddle epilogue: bin k of pencil p ⊙ T[k, p].
            yr, yi = cmul(yr, yi, t_r[...], t_i[...])
        o_r[...] = yr.reshape(1, f, c)
        o_i[...] = yi.reshape(1, f, c)

    return kernel


def cols_pass_call(
    xr: jax.Array,
    xi: jax.Array,
    luts,
    twiddle=None,
    *,
    kind: str,
    n1: int = 0,
    n2: int = 0,
    chunk: int,
    interpret: bool = False,
    tw_every: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Strided-column transform pass: x (R, f, s), FFT of length f down the
    middle axis, written in place (same layout).  ``twiddle`` is the (f, s)
    inter-factor grid (split planes) applied as the VMEM epilogue.

    ``tw_every`` is the width-broadcast mode of the strip-mined column
    passes of a 2-D program: the last axis is (pencil-phase, image-width)
    flattened, ``s = s_tw · tw_every`` with a ``(f, s_tw)`` twiddle grid,
    and every flat position inside one width run shares the phase — so the
    kernel is served a single ``(f, 1)`` twiddle column per chunk
    (``chunk`` must divide ``tw_every``) and broadcasts it across the
    chunk's image columns in VMEM instead of materialising the grid at
    image width in HBM."""
    r, f, s = xr.shape
    assert s % chunk == 0, (s, chunk)
    grid = (r, s // chunk)
    sig = pl.BlockSpec((1, f, chunk), lambda i, j: (i, 0, j))
    in_specs = [sig, sig] + _lut_specs(kind, f, n1, n2, lambda i, j: (0, 0))
    operands = [xr, xi] + _as_ops(luts)
    has_tw = twiddle is not None
    if has_tw and tw_every is not None:
        assert tw_every % chunk == 0, (tw_every, chunk)
        assert s % tw_every == 0, (s, tw_every)
        # One phase column per chunk, broadcast across the chunk in VMEM.
        tw_spec = pl.BlockSpec((f, 1), lambda i, j: (0, (j * chunk) // tw_every))
        in_specs += [tw_spec, tw_spec]
        operands += _as_ops(twiddle)
    elif has_tw:
        tw_spec = pl.BlockSpec((f, chunk), lambda i, j: (0, j))
        in_specs += [tw_spec, tw_spec]
        operands += _as_ops(twiddle)
    out_shape = [
        jax.ShapeDtypeStruct((r, f, s), jnp.float32),
        jax.ShapeDtypeStruct((r, f, s), jnp.float32),
    ]
    fn = pl.pallas_call(
        _make_cols_kernel(kind, n1, n2, len(luts), has_tw),
        grid=grid,
        in_specs=in_specs,
        out_specs=[sig, sig],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")
        ),
    )
    return tuple(fn(*operands))


def _make_rows_kernel(kind: str, n1: int, n2: int, n_luts: int):
    def kernel(x_r, x_i, *rest):
        luts = [r[...] for r in rest[:n_luts]]
        o_r, o_i = rest[-2], rest[-1]
        c, f = x_r.shape[1], x_r.shape[2]
        xr = x_r[...].reshape(c, f)
        xi = x_i[...].reshape(c, f)
        yr, yi = _tile_transform(xr, xi, luts, kind, n1, n2)
        # Natural-order transpose fused into the write: (c, f) → (f, c).
        o_r[...] = yr.swapaxes(0, 1).reshape(1, f, c)
        o_i[...] = yi.swapaxes(0, 1).reshape(1, f, c)

    return kernel


def rows_natural_call(
    xr: jax.Array,
    xi: jax.Array,
    luts,
    *,
    kind: str,
    n1: int = 0,
    n2: int = 0,
    chunk: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Contiguous-row transform pass with the natural-order transpose fused
    into its strided write: x (B, p, f) → y (B, f, p), where
    y[b, k, q] = FFT_f(x[b, q, :])[k]."""
    b, p, f = xr.shape
    assert p % chunk == 0, (p, chunk)
    grid = (b, p // chunk)
    in_sig = pl.BlockSpec((1, chunk, f), lambda i, j: (i, j, 0))
    out_sig = pl.BlockSpec((1, f, chunk), lambda i, j: (i, 0, j))
    in_specs = [in_sig, in_sig] + _lut_specs(kind, f, n1, n2, lambda i, j: (0, 0))
    operands = [xr, xi] + _as_ops(luts)
    out_shape = [
        jax.ShapeDtypeStruct((b, f, p), jnp.float32),
        jax.ShapeDtypeStruct((b, f, p), jnp.float32),
    ]
    fn = pl.pallas_call(
        _make_rows_kernel(kind, n1, n2, len(luts)),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_sig, out_sig],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")
        ),
    )
    return tuple(fn(*operands))


def _make_cols_natural_kernel(kind: str, n1: int, n2: int, n_luts: int):
    def kernel(x_r, x_i, *rest):
        luts = [r[...] for r in rest[:n_luts]]
        o_r, o_i = rest[-2], rest[-1]
        f, c = x_r.shape[2], x_r.shape[3]
        # (1, 1, f, c) block → (c, f): the chunk's image columns become rows.
        xr = x_r[...].reshape(f, c).swapaxes(0, 1)
        xi = x_i[...].reshape(f, c).swapaxes(0, 1)
        yr, yi = _tile_transform(xr, xi, luts, kind, n1, n2)
        # The n2-axis digit transpose lives in the BlockSpec indexing (the
        # in/out p and k axes are swapped); the tile itself writes bin-major.
        o_r[...] = yr.swapaxes(0, 1).reshape(1, f, 1, c)
        o_i[...] = yi.swapaxes(0, 1).reshape(1, f, 1, c)

    return kernel


def cols_natural_call(
    xr: jax.Array,
    xi: jax.Array,
    luts,
    *,
    kind: str,
    n1: int = 0,
    n2: int = 0,
    chunk: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Final strip-mined column pass with the natural-order digit transpose
    fused into its strided write: x (B, P, f, w) → y (B, f, P, w), where
    ``y[b, k, p, :] = FFT_f(x[b, p, :, :], axis=0)[k]`` — i.e. the length-f
    transform runs down the n2-axis factor while the image width ``w`` rides
    along in chunks, and output n2-position ``k·P + p`` lands natural order
    with zero standalone HBM transpose (the 2-D analogue of
    :func:`rows_natural_call`)."""
    b, p, f, w = xr.shape
    assert w % chunk == 0, (w, chunk)
    grid = (b, p, w // chunk)
    in_sig = pl.BlockSpec((1, 1, f, chunk), lambda i, q, j: (i, q, 0, j))
    out_sig = pl.BlockSpec((1, f, 1, chunk), lambda i, q, j: (i, 0, q, j))
    in_specs = [in_sig, in_sig] + _lut_specs(
        kind, f, n1, n2, lambda i, q, j: (0, 0)
    )
    operands = [xr, xi] + _as_ops(luts)
    out_shape = [
        jax.ShapeDtypeStruct((b, f, p, w), jnp.float32),
        jax.ShapeDtypeStruct((b, f, p, w), jnp.float32),
    ]
    fn = pl.pallas_call(
        _make_cols_natural_kernel(kind, n1, n2, len(luts)),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_sig, out_sig],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")
        ),
    )
    return tuple(fn(*operands))


# ---------------------------------------------------------------------------
# Hermitian recombination epilogue passes (rfft / irfft packing)
# ---------------------------------------------------------------------------


def _recomb_call(tile_fn, zr, zi, wr, wi, m_in, m_out, interpret):
    b = zr.shape[0]
    wr = jnp.asarray(wr, jnp.float32).reshape(1, -1)
    wi = jnp.asarray(wi, jnp.float32).reshape(1, -1)
    mw = wr.shape[-1]

    def kernel(z_r, z_i, w_r, w_i, o_r, o_i):
        yr, yi = tile_fn(z_r[...], z_i[...], w_r[...], w_i[...])
        o_r[...] = yr
        o_i[...] = yi

    sig_in = pl.BlockSpec((1, m_in), lambda i: (i, 0))
    sig_out = pl.BlockSpec((1, m_out), lambda i: (i, 0))
    w_spec = pl.BlockSpec((1, mw), lambda i: (0, 0))
    fn = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[sig_in, sig_in, w_spec, w_spec],
        out_specs=[sig_out, sig_out],
        out_shape=[
            jax.ShapeDtypeStruct((b, m_out), jnp.float32),
            jax.ShapeDtypeStruct((b, m_out), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compiler_params(dimension_semantics=("parallel",)),
    )
    return tuple(fn(zr, zi, wr, wi))


def rfft_recomb_call(zr, zi, wr, wi, *, interpret: bool = False):
    """Forward recombination pass: packed spectrum (B, m) → bins (B, m+1).

    One ``pallas_call`` executing :func:`repro.core.fft_xla.rfft_recomb` on
    VMEM-resident spectrum rows — the Z[-k] reversal is an in-register
    flip+roll, and the whole Hermitian epilogue costs one HBM round trip.
    """
    m = zr.shape[-1]
    return _recomb_call(rfft_recomb, zr, zi, wr, wi, m, m + 1, interpret)


def irfft_recomb_call(xr, xi, wr, wi, *, interpret: bool = False):
    """Inverse recombination pass: bins (B, m+1) → packed spectrum (B, m)."""
    m = xr.shape[-1] - 1
    return _recomb_call(irfft_recomb, xr, xi, wr, wi, m + 1, m, interpret)
