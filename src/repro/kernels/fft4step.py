"""Fused four-step FFT Pallas kernel — one HBM round trip for N ≤ 65536.

The paper's central optimisation (§2.3.2): rather than one kernel per
butterfly level (log₂N global round trips), divide the signal so that *all*
levels execute in on-chip memory, touching the slow tier once.  Fermi shared
memory → TPU VMEM; butterfly warps → MXU matmuls:

    view x as (n1, n2) row-major
    A = W1 · X                   column DFTs      (MXU GEMM 1)
    B = A ⊙ T                    twiddle          (VPU, fused)
    C = B · W2                   row DFTs         (MXU GEMM 2)
    Y = Cᵀ flattened             natural order    (VMEM-internal relayout)

The signal tile, both DFT matrices, the twiddle grid, the intermediate and
the output tile are co-resident in VMEM; the LUT operands are pinned to block
(0, 0) for every grid step so Mosaic hoists their copy out of the batch loop
(texture-memory analogue).  The batch grid dimension is ``parallel``.

In-kernel dataflow (all VMEM, no HBM traffic):
  x      (bt, n)   → view (bt, n1, n2) → transpose (n1, bt, n2)
  GEMM-1 (n1, n1) @ (n1, bt·n2)
  twiddle broadcast over bt
  GEMM-2 (n1·bt, n2) @ (n2, n2)
  out    (n1, bt, n2) → transpose (bt, n2, n1) → flatten (bt, n)

Both GEMMs are plain 2-D contractions with 128-aligned operand shapes for
n1, n2 ≥ 128 (N ≥ 16384); smaller factors pad sublanes but stay correct.
Inverse transforms use conjugated LUTs with 1/N folded into W2 — the
scaled table *is* the LUT, no extra pass (paper §2.3.1 spirit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import compiler_params

__all__ = ["fft4step_call"]


def _cgemm(ar, ai, br, bi):
    """Karatsuba complex GEMM on split planes: 3 real MXU GEMMs."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    k1 = dot(ar + ai, br)
    k2 = dot(ar, bi - br)
    k3 = dot(ai, br + bi)
    return k1 - k3, k1 + k2


def _make_kernel(n1: int, n2: int, natural_order: bool):
    def kernel(x_r, x_i, w1_r, w1_i, t_r, t_i, w2_r, w2_i, o_r, o_i):
        bt = x_r.shape[0]
        n = n1 * n2
        # (bt, n) → (n1, bt·n2): put the contracted factor on rows.
        xr = x_r[...].reshape(bt, n1, n2).transpose(1, 0, 2).reshape(n1, bt * n2)
        xi = x_i[...].reshape(bt, n1, n2).transpose(1, 0, 2).reshape(n1, bt * n2)
        # GEMM-1: column DFTs.  A = W1 @ X  ((n1,n1) @ (n1, bt·n2)).
        ar, ai = _cgemm(w1_r[...], w1_i[...], xr, xi)
        # Twiddle: A viewed (n1, bt, n2) ⊙ T[n1, 1, n2].
        ar = ar.reshape(n1, bt, n2)
        ai = ai.reshape(n1, bt, n2)
        tr = t_r[...][:, None, :]
        ti = t_i[...][:, None, :]
        br = ar * tr - ai * ti
        bi = ar * ti + ai * tr
        # GEMM-2: row DFTs.  C = B @ W2  ((n1·bt, n2) @ (n2, n2)).
        cr, ci = _cgemm(
            br.reshape(n1 * bt, n2), bi.reshape(n1 * bt, n2), w2_r[...], w2_i[...]
        )
        cr = cr.reshape(n1, bt, n2)
        ci = ci.reshape(n1, bt, n2)
        if natural_order:
            # Y[b, k2·n1 + k1] = C[k1, b, k2] — VMEM-internal relayout.
            o_r[...] = cr.transpose(1, 2, 0).reshape(bt, n)
            o_i[...] = ci.transpose(1, 2, 0).reshape(bt, n)
        else:
            # Pencil (k1-major) layout: caller composes/undoes ordering.
            o_r[...] = cr.transpose(1, 0, 2).reshape(bt, n)
            o_i[...] = ci.transpose(1, 0, 2).reshape(bt, n)

    return kernel


def fft4step_call(
    xr: jax.Array,
    xi: jax.Array,
    w1r: jax.Array,
    w1i: jax.Array,
    twr: jax.Array,
    twi: jax.Array,
    w2r: jax.Array,
    w2i: jax.Array,
    *,
    batch_tile: int,
    natural_order: bool = True,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused four-step FFT: x (B, n1·n2) split-complex; B % batch_tile == 0."""
    b, n = xr.shape
    n1 = w1r.shape[0]
    n2 = w2r.shape[0]
    assert n == n1 * n2, (n, n1, n2)
    assert b % batch_tile == 0, (b, batch_tile)
    grid = (b // batch_tile,)
    sig = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    lut1 = pl.BlockSpec((n1, n1), lambda i: (0, 0))
    lutt = pl.BlockSpec((n1, n2), lambda i: (0, 0))
    lut2 = pl.BlockSpec((n2, n2), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    ]
    fn = pl.pallas_call(
        _make_kernel(n1, n2, natural_order),
        grid=grid,
        in_specs=[sig, sig, lut1, lut1, lutt, lutt, lut2, lut2],
        out_specs=[sig, sig],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)
        ),
    )
    return tuple(fn(xr, xi, w1r, w1i, twr, twi, w2r, w2i))
