"""Fused four-step FFT Pallas kernel — one HBM round trip for N ≤ 65536.

The paper's central optimisation (§2.3.2): rather than one kernel per
butterfly level (log₂N global round trips), divide the signal so that *all*
levels execute in on-chip memory, touching the slow tier once.  Fermi shared
memory → TPU VMEM; butterfly warps → MXU matmuls:

    view x as (n1, n2) row-major
    A = W1 · X                   column DFTs      (MXU GEMM 1)
    B = A ⊙ T                    twiddle          (VPU, fused)
    C = B · W2                   row DFTs         (MXU GEMM 2)
    Y = Cᵀ flattened             natural order    (VMEM-internal relayout)

The signal tile, both DFT matrices, the twiddle grid, the intermediate and
the output tile are co-resident in VMEM; the LUT operands are pinned to block
(0, 0) for every grid step so Mosaic hoists their copy out of the batch loop
(texture-memory analogue).  The batch grid dimension is ``parallel``.

The whole VMEM dataflow lives in :func:`four_step_tile` so the pass-program
kernels (``repro.kernels.pencil``) embed the same four-step engine inside
their strided-column and transposed-write passes — the tile function is the
unit of fusion.  On top of the selectable output layout (``natural_order``),
``fft4step_call`` accepts a post-GEMM per-bin twiddle (``twiddle_after``)
applied in the epilogue before the write, so a multiplicative phase stage
(modulation, delay, inter-level twiddle of a follow-on factor) costs zero
extra HBM passes.

Both GEMMs are plain 2-D contractions with 128-aligned operand shapes for
n1, n2 ≥ 128 (N ≥ 16384); smaller factors pad sublanes but stay correct.
Inverse transforms use conjugated LUTs with 1/N folded into W2 — the
scaled table *is* the LUT, no extra pass (paper §2.3.1 spirit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fft_xla import cmul
from repro.kernels.pallas_compat import compiler_params

__all__ = ["fft4step_call", "four_step_tile", "cgemm_tile"]


def cgemm_tile(ar, ai, br, bi):
    """Karatsuba complex GEMM on split planes: 3 real MXU GEMMs."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    k1 = dot(ar + ai, br)
    k2 = dot(ar, bi - br)
    k3 = dot(ai, br + bi)
    return k1 - k3, k1 + k2


def four_step_tile(
    xr, xi, w1r, w1i, tr, ti, w2r, w2i, n1: int, n2: int, natural_order: bool = True
):
    """The four-step dataflow on a VMEM-resident (bt, n1·n2) tile.

    Pure jnp on arrays already in VMEM — callable from any Pallas kernel
    body (this file's batch kernel, the pencil pass kernels) or traced
    directly for reference.  Returns (yr, yi) of shape (bt, n1·n2), in
    natural or pencil (k1-major) order.
    """
    bt = xr.shape[0]
    n = n1 * n2
    # (bt, n) → (n1, bt·n2): put the contracted factor on rows.
    xr = xr.reshape(bt, n1, n2).transpose(1, 0, 2).reshape(n1, bt * n2)
    xi = xi.reshape(bt, n1, n2).transpose(1, 0, 2).reshape(n1, bt * n2)
    # GEMM-1: column DFTs.  A = W1 @ X  ((n1,n1) @ (n1, bt·n2)).
    ar, ai = cgemm_tile(w1r, w1i, xr, xi)
    # Twiddle: A viewed (n1, bt, n2) ⊙ T[n1, 1, n2].
    ar = ar.reshape(n1, bt, n2)
    ai = ai.reshape(n1, bt, n2)
    trb = tr[:, None, :]
    tib = ti[:, None, :]
    br = ar * trb - ai * tib
    bi = ar * tib + ai * trb
    # GEMM-2: row DFTs.  C = B @ W2  ((n1·bt, n2) @ (n2, n2)).
    cr, ci = cgemm_tile(
        br.reshape(n1 * bt, n2), bi.reshape(n1 * bt, n2), w2r, w2i
    )
    cr = cr.reshape(n1, bt, n2)
    ci = ci.reshape(n1, bt, n2)
    if natural_order:
        # Y[b, k2·n1 + k1] = C[k1, b, k2] — VMEM-internal relayout.
        return cr.transpose(1, 2, 0).reshape(bt, n), ci.transpose(1, 2, 0).reshape(bt, n)
    # Pencil (k1-major) layout: caller composes/undoes ordering.
    return cr.transpose(1, 0, 2).reshape(bt, n), ci.transpose(1, 0, 2).reshape(bt, n)


def _make_kernel(n1: int, n2: int, natural_order: bool, has_epilogue: bool):
    def kernel(x_r, x_i, w1_r, w1_i, t_r, t_i, w2_r, w2_i, *rest):
        if has_epilogue:
            e_r, e_i, o_r, o_i = rest
        else:
            o_r, o_i = rest
        yr, yi = four_step_tile(
            x_r[...], x_i[...],
            w1_r[...], w1_i[...], t_r[...], t_i[...], w2_r[...], w2_i[...],
            n1, n2, natural_order,
        )
        if has_epilogue:
            # Post-GEMM per-position twiddle: y[b, j] *= e[j] (split complex).
            yr, yi = cmul(yr, yi, e_r[...], e_i[...])
        o_r[...] = yr
        o_i[...] = yi

    return kernel


def fft4step_call(
    xr: jax.Array,
    xi: jax.Array,
    w1r: jax.Array,
    w1i: jax.Array,
    twr: jax.Array,
    twi: jax.Array,
    w2r: jax.Array,
    w2i: jax.Array,
    *,
    batch_tile: int,
    natural_order: bool = True,
    twiddle_after: tuple[jax.Array, jax.Array] | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused four-step FFT: x (B, n1·n2) split-complex; B % batch_tile == 0.

    ``twiddle_after`` — optional (real, imag) per-output-position phasors of
    shape (n,): multiplied into the result in the VMEM epilogue (after the
    ``natural_order`` relayout), so phase post-processing rides the same
    HBM round trip.  The pass program's *inter-factor* twiddle goes through
    ``kernels.pencil``'s column kernel instead (it is per-pencil-phase, not
    per-position); this call-level hook is the public surface for per-bin
    phase stages — modulation, delay, fftshift-by-phase-ramp.
    """
    b, n = xr.shape
    n1 = w1r.shape[0]
    n2 = w2r.shape[0]
    assert n == n1 * n2, (n, n1, n2)
    assert b % batch_tile == 0, (b, batch_tile)
    grid = (b // batch_tile,)
    sig = pl.BlockSpec((batch_tile, n), lambda i: (i, 0))
    lut1 = pl.BlockSpec((n1, n1), lambda i: (0, 0))
    lutt = pl.BlockSpec((n1, n2), lambda i: (0, 0))
    lut2 = pl.BlockSpec((n2, n2), lambda i: (0, 0))
    in_specs = [sig, sig, lut1, lut1, lutt, lutt, lut2, lut2]
    operands = [xr, xi, w1r, w1i, twr, twi, w2r, w2i]
    if twiddle_after is not None:
        er, ei = twiddle_after
        er = jnp.asarray(er, jnp.float32).reshape(1, n)
        ei = jnp.asarray(ei, jnp.float32).reshape(1, n)
        lute = pl.BlockSpec((1, n), lambda i: (0, 0))
        in_specs += [lute, lute]
        operands += [er, ei]
    out_shape = [
        jax.ShapeDtypeStruct((b, n), jnp.float32),
        jax.ShapeDtypeStruct((b, n), jnp.float32),
    ]
    fn = pl.pallas_call(
        _make_kernel(n1, n2, natural_order, twiddle_after is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[sig, sig],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)
        ),
    )
    return tuple(fn(*operands))
