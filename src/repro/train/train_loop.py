"""Train-step factory: grad accumulation, compression, clipping, schedules.

``make_train_step`` builds the jit-able function

    (train_state, batch) → (train_state, metrics)

with optional microbatching: the batch is split into ``microbatches`` along
dim 0 and gradients accumulate in a ``lax.scan`` — on real hardware XLA's
latency-hiding scheduler overlaps microbatch *i*'s gradient reduce-scatter
with microbatch *i+1*'s compute, which is the standard DP-overlap trick the
prompt's distributed-optimization requirement asks for (enabled by the
flags set in ``launch/train.py``).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.train import compression as comp_lib
from repro.train.optimizer import OptState, clip_by_global_norm, make_optimizer
from repro.train.schedule import make_schedule

__all__ = ["TrainState", "make_train_step", "init_train_state"]


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: OptState
    err_state: Any  # grad-compression error feedback (or ())


def init_train_state(key, cfg, train_cfg) -> TrainState:
    params, _ = model_lib.init_unzipped(key, cfg)
    opt_init, _ = make_optimizer(train_cfg)
    err = comp_lib.init_error_state(params) if train_cfg.grad_compression else ()
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=opt_init(params),
        err_state=err,
    )


def make_train_step(cfg, train_cfg):
    _, opt_update = make_optimizer(train_cfg)
    schedule = make_schedule(train_cfg)
    nmicro = max(1, train_cfg.microbatches)

    def loss_wrapper(params, batch):
        return model_lib.loss_fn(params, batch, cfg, train_cfg)

    grad_fn = jax.value_and_grad(loss_wrapper, has_aux=True)

    def single_grads(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulated_grads(params, batch):
        def reshape(x):
            b = x.shape[0]
            return x.reshape(nmicro, b // nmicro, *x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def body(carry, mb):
            acc, _ = carry
            grads, metrics = single_grads(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, metrics), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        dummy_metrics = {
            "loss": jnp.zeros(()), "ce": jnp.zeros(()),
            "aux": jnp.zeros(()), "tokens": jnp.zeros(()),
        }
        (acc, metrics), _ = jax.lax.scan(body, (zeros, dummy_metrics), micro)
        grads = jax.tree.map(lambda g: g / nmicro, acc)
        return grads, metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if nmicro > 1:
            grads, metrics = accumulated_grads(state.params, batch)
        else:
            grads, metrics = single_grads(state.params, batch)
        err_state = state.err_state
        if train_cfg.grad_compression:
            grads, err_state = comp_lib.compress_grads(grads, err_state)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        lr = schedule(state.step)
        new_params, new_opt = opt_update(grads, state.opt_state, state.params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return (
            TrainState(
                step=state.step + 1,
                params=new_params,
                opt_state=new_opt,
                err_state=err_state,
            ),
            metrics,
        )

    return train_step
