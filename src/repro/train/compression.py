"""Int8 gradient compression with error feedback.

Distributed-optimization trick for the cross-pod data-parallel all-reduce:
gradients are quantised to int8 with a per-tensor scale before the reduce,
and the quantisation residual is carried into the next step (error
feedback), which keeps SGD/Adam convergence unaffected to first order
(Seide et al. 2014; Karimireddy et al. 2019).

Under jit + GSPMD the psum of the int8-dequantised values is what crosses
the slow pod links; the residual state lives alongside the optimizer state
and is checkpointed with it.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_grads", "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, err_state):
    """Returns (decompressed grads to feed the optimizer, new error state).

    The dequantised value is what the all-reduce transmits; the residual
    (g + e − deq) is fed back next step.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
