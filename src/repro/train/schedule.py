"""LR schedules: linear warmup → cosine decay (the usual LM default)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["make_schedule"]


def make_schedule(train_cfg):
    peak = train_cfg.learning_rate
    warmup = max(1, train_cfg.warmup_steps)
    total = max(train_cfg.total_steps, warmup + 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        # warmup starts at peak/warmup (not 0): step 0 should train.
        warm = peak * (step + 1.0) / warmup
        progress = jnp.clip((step - warmup) / (total - warmup), 0.0, 1.0)
        cos = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup, warm, cos)

    return lr
