"""Self-contained optimizers (no optax dependency): AdamW, Adafactor, SGD.

Each optimizer is an (init, update) pair over plain pytrees.

Adafactor matters at assignment scale: arctic-480b's Adam state (8 bytes/
param of fp32 moments) cannot fit 256×16 GB chips alongside bf16 params and
activations; factored second moments cut optimizer state to O(rows + cols).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["make_optimizer", "OptState", "global_norm", "clip_by_global_norm"]


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------


def _adamw(train_cfg):
    b1, b2, eps, wd = train_cfg.b1, train_cfg.b2, 1e-8, train_cfg.weight_decay

    def init(params):
        # m and v must be distinct buffers (donation aliases them otherwise).
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), inner={"m": m, "v": v})

    def update(grads, state, params, lr):
        t = state.step + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state.inner["m"],
            grads,
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.inner["v"],
            grads,
        )
        bc1 = 1 - b1**tf
        bc2 = 1 - b2**tf

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            step = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, OptState(step=t, inner={"m": m, "v": v})

    return init, update


# --------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# --------------------------------------------------------------------------


def _adafactor(train_cfg):
    eps = 1e-30
    clip_thr = 1.0
    wd = train_cfg.weight_decay
    d2 = train_cfg.b2  # decay for the running stats

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return OptState(
            step=jnp.zeros((), jnp.int32), inner=jax.tree.map(
                one, params, is_leaf=lambda x: hasattr(x, "shape")
            )
        )

    def update(grads, state, params, lr):
        t = state.step + 1
        tf = t.astype(jnp.float32)
        beta = 1.0 - tf ** -0.8  # Adafactor's step-dependent decay
        beta = jnp.minimum(beta, d2)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                # V ≈ (vr ⊗ vc) / mean(vr)  (Shazeer & Stern eq. 4)
                u = g * jax.lax.rsqrt(
                    (vr[..., None] * vc[..., None, :])
                    / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps)
                    + eps
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS ≤ 1)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_thr)
            newp = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state.inner)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_inner = tdef.unflatten([o[1] for o in out])
        return new_params, OptState(step=t, inner=new_inner)

    return init, update


def _sgd(train_cfg):
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), inner=())

    def update(grads, state, params, lr):
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params,
            grads,
        )
        return new_params, OptState(step=state.step + 1, inner=())

    return init, update


def make_optimizer(train_cfg):
    if train_cfg.optimizer == "adamw":
        return _adamw(train_cfg)
    if train_cfg.optimizer == "adafactor":
        return _adafactor(train_cfg)
    if train_cfg.optimizer == "sgd":
        return _sgd(train_cfg)
    raise ValueError(f"unknown optimizer {train_cfg.optimizer!r}")
