"""Deterministic synthetic LM data pipeline — shardable and checkpointable.

Real clusters stream tokenised corpora; offline we generate a deterministic
pseudo-corpus whose statistics exercise the same code paths (power-law token
distribution, document boundaries, loss masks).  Key properties the trainer
relies on:

* **Determinism**: batch *i* is a pure function of (seed, i) — restart-safe.
* **Shardability**: each data-parallel host slices its rows of batch *i*
  without coordination (``host_batch_slice``).
* **Checkpointable state**: the iterator state is a single integer (the
  step), stored in the checkpoint and restored on resume — replay after a
  failure produces bit-identical batches.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2  # power-law exponent for token frequencies
    doc_len_mean: int = 512


class SyntheticLM:
    """Deterministic batch generator with O(1) state (the step counter)."""

    def __init__(self, dcfg: DataConfig, start_step: int = 0):
        self.cfg = dcfg
        self.step = start_step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, dcfg: DataConfig, state: dict) -> "SyntheticLM":
        assert state["seed"] == dcfg.seed, "data seed mismatch on restore"
        return cls(dcfg, start_step=int(state["step"]))

    def batch_at(self, step: int) -> dict:
        return make_batch(self.cfg, step)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b


def _zipf_tokens(rng: np.random.Generator, cfg: DataConfig, shape) -> np.ndarray:
    # Inverse-CDF sampling of a bounded zipf over [4, vocab) (0-3 reserved).
    u = rng.random(shape)
    ranks = np.power(u, -1.0 / (cfg.zipf_a - 1.0))
    ranks = np.minimum(ranks, float(cfg.vocab_size))  # clip pre-cast (inf-safe)
    toks = np.clip(ranks.astype(np.int64), 1, cfg.vocab_size - 5) + 3
    return toks.astype(np.int32)


def make_batch(cfg: DataConfig, step: int) -> dict:
    """Pure function of (cfg.seed, step) → {'tokens','targets','loss_mask'}."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s = cfg.global_batch, cfg.seq_len
    toks = _zipf_tokens(rng, cfg, (b, s + 1))
    # Insert document boundaries (token 2 = EOD) at geometric intervals and
    # mask loss right after them (next-token unpredictable across docs).
    eod_mask = rng.random((b, s + 1)) < (1.0 / cfg.doc_len_mean)
    toks = np.where(eod_mask, 2, toks)
    tokens = toks[:, :-1]
    targets = toks[:, 1:]
    loss_mask = (targets != 2).astype(np.float32)
    return {
        "tokens": tokens,
        "targets": targets.astype(np.int32),
        "loss_mask": loss_mask,
    }


def host_batch_slice(batch: dict, host_index: int, num_hosts: int) -> dict:
    """Rows owned by one data-parallel host (deterministic, coordination-free)."""

    def one(x):
        b = x.shape[0]
        per = b // num_hosts
        return x[host_index * per : (host_index + 1) * per]

    return {k: one(v) for k, v in batch.items()}
