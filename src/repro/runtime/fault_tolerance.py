"""Fault-tolerance runtime: step watchdog, retry wrapper, straggler stats.

On a 1000+-node pod the failure modes are (a) hard node loss — handled by
checkpoint/restart + elastic re-mesh (see checkpoint.manager), (b) hangs /
stragglers — handled here:

* :class:`StepWatchdog` — a monitor thread that fires a callback if a step
  exceeds ``timeout``; the launcher's default callback logs, snapshots, and
  raises in the main thread so the supervisor restarts from the last
  checkpoint (crash-only design).
* :func:`with_retries` — retries transient device errors with backoff and
  re-initialisation hooks.
* :class:`StragglerStats` — EWMA of step times; flags steps slower than
  ``k·ewma`` (on real pods: feeds the controller that re-shards around slow
  hosts; offline: surfaces in metrics/logs).
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

__all__ = ["StepWatchdog", "with_retries", "StragglerStats"]


class StepWatchdog:
    def __init__(self, timeout_s: float, on_timeout: Optional[Callable[[], None]] = None):
        self.timeout = timeout_s
        self.on_timeout = on_timeout or (lambda: None)
        self._deadline: Optional[float] = None
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.fired = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def arm(self):
        with self._lock:
            self._deadline = time.monotonic() + self.timeout

    def disarm(self):
        with self._lock:
            self._deadline = None

    def _run(self):
        while not self._stop.wait(min(self.timeout / 4, 1.0)):
            with self._lock:
                dl = self._deadline
            if dl is not None and time.monotonic() > dl:
                self.fired = True
                self._deadline = None
                self.on_timeout()

    def close(self):
        self._stop.set()


def with_retries(fn, *, retries: int = 3, backoff_s: float = 1.0, on_retry=None):
    """Run ``fn()`` retrying transient failures with linear backoff."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except (RuntimeError, OSError) as e:  # XLA device errors surface as RuntimeError
            last = e
            if attempt == retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            time.sleep(backoff_s * (attempt + 1))
    raise last  # unreachable


class StragglerStats:
    """EWMA step-time tracker with straggler flagging."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: Optional[float] = None
        self.flagged = 0
        self.total = 0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.total += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged += 1
        # EWMA excludes extreme outliers so one hang doesn't poison the mean.
        if dt < 4 * self.ewma:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow

    def summary(self) -> dict:
        return {"ewma_s": self.ewma, "stragglers": self.flagged, "steps": self.total}
