"""Reduced configs: same family/block structure, laptop-scale dimensions.

Used by the per-arch smoke tests (one CPU forward/train step asserting
shapes + no NaNs).  The FULL configs are only ever exercised through the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.models.stack import find_unit

__all__ = ["make_reduced"]


def make_reduced(cfg: ModelConfig, *, units: int = 2) -> ModelConfig:
    """Shrink every dimension while preserving the block pattern family."""
    if cfg.family == "fft":
        return cfg
    pattern = cfg.pattern()
    unit = find_unit(pattern)
    reps = min(units, len(pattern) // len(unit))
    new_pattern = tuple(unit) * reps

    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    # keep the GQA group structure when the full config has one
    if cfg.num_kv_heads < cfg.num_heads:
        kv = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    d_model = 64
    changes = dict(
        num_layers=len(new_pattern) if not cfg.block_pattern else cfg.num_layers,
        block_pattern=new_pattern,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.ssm_heads else 0,
        chunk_size=8,
        sliding_window=8 if cfg.sliding_window else None,
        spectral_filter_len=16,
        frontend_len=4 if cfg.frontend_len else 0,
        mrope_sections=(4, 2, 2) if cfg.rope_kind == "mrope" else cfg.mrope_sections,
        attn_chunk=8,
        attn_chunk_threshold=64,
        loss_chunk=16,
        scan_layers=cfg.scan_layers,
        param_dtype="float32",
    )
    return dataclasses.replace(cfg, **changes)
