"""qwen2-vl-72b: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE (sections 16/24/24 over the 64-dim rotary half), dynamic-resolution
vision.  [arXiv:2409.12191; hf]  Backbone only: the ViT frontend is a STUB —
input_specs() provides precomputed patch embeddings for the first
``frontend_len`` positions plus (B, 3, S) M-RoPE position ids.
long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_theta=1_000_000.0,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision",
    frontend_len=1024,
    kv_cache_dtype="int8",
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
