"""xlstm-125m: 12L d_model=768 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (xLSTM, arXiv:2405.04517; unverified).  d_ff=0 →
the blocks carry their own projections (mLSTM: expand-2 up/down; sLSTM
block gets a 2·D gated FFN).  Pattern: (mLSTM, mLSTM, sLSTM) × 4.
long_500k: RUN — recurrent state, O(1) per decoded token.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_heads=4,
    ssm_expand=2,
    chunk_size=256,
    block_pattern=("mlstm", "mlstm", "slstm") * 4,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
