"""yi-6b: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

llama-arch GQA.  [arXiv:2403.04652; hf]
long_500k: SKIPPED — pure full attention (see DESIGN §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
