"""arctic-480b: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.

MoE: 128 experts, top-2, with a dense residual MLP in parallel (arctic's
dense+MoE hybrid).  [hf:Snowflake/snowflake-arctic-base]
long_500k: SKIPPED — full attention.  Trains with adafactor + fsdp (480B
params would not fit per-chip optimizer state otherwise; see launch/train).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    top_k=2,
    moe_dense_residual=True,
    param_dtype="bfloat16",
    kv_cache_dtype="int8",
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
