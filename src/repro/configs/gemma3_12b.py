"""gemma3-12b: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local(sliding-window 1024):global attention, 128k-class context.
[hf:google/gemma-3-*-pt; assignment tier: unverified — assignment numbers
are authoritative here.]  head_dim=256 (gemma3 uses wide heads).
long_500k: RUN — 40/48 layers are SWA-bounded; the 8 global layers decode
linearly per token with an SP-sharded KV cache (see DESIGN §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    act="gelu",
    sliding_window=1024,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    final_logit_softcap=30.0,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
