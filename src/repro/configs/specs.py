"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

The dry-run lowers against these — weak-type-correct, shardable, zero
allocation.  For decode shapes the specs include the full KV/SSM cache
pytree (built with ``jax.eval_shape`` over ``model.cache_init``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["input_specs", "decode_state_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model-input ShapeDtypeStructs for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {}
    if cfg.frontend == "audio":
        # EnCodec frontend stub: precomputed frame embeddings.
        specs["frame_embeds"] = _sds((b, s, cfg.d_model), cfg.compute_dtype)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
    if cfg.frontend == "vision":
        fl = min(cfg.frontend_len, s)
        specs["vision_embeds"] = _sds((b, fl, cfg.d_model), cfg.compute_dtype)
        specs["mrope_positions"] = _sds((b, 3, s), jnp.int32)
    if shape.kind == "train":
        specs["targets"] = _sds((b, s), jnp.int32)
    return specs


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """(token_specs, cache_specs, t_spec) for a serve_step lowering."""
    b, s = shape.global_batch, shape.seq_len
    from repro.models import model as model_lib

    caches = jax.eval_shape(
        functools.partial(model_lib.cache_init, cfg, b, s, dtype=dtype)
    )
    if cfg.frontend == "audio":
        tok = _sds((b,), jnp.int32)  # previous token ids (embeds via table)
    else:
        tok = _sds((b,), jnp.int32)
    t = _sds((), jnp.int32)
    return tok, caches, t
