"""Config system: model / parallelism / training / shape definitions.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(arch_id)`` resolves them by registry name.
Input shapes are ``ShapeConfig`` entries shared across the LM family
(train_4k / prefill_32k / decode_32k / long_500k per the assignment).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

__all__ = [
    "ModelConfig",
    "ParallelConfig",
    "TrainConfig",
    "ShapeConfig",
    "LM_SHAPES",
    "get_config",
    "list_archs",
    "shapes_for",
    "register",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity ------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    # --- trunk ---------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 → d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- attention -----------------------------------------------------
    sliding_window: Optional[int] = None  # window size for local layers
    local_global_ratio: int = 0  # e.g. 5 → pattern [local]*5 + [global]
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    rope_kind: str = "standard"  # standard | mrope
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    # --- MoE -----------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # --- SSM / recurrent -----------------------------------------------
    ssm_state: int = 0          # Mamba2 d_state
    ssm_heads: int = 0          # Mamba2 / mLSTM heads (0 → num_heads)
    ssm_expand: int = 2         # Mamba2 expansion
    conv_width: int = 4         # Mamba2 short conv
    chunk_size: int = 256       # chunked linear-recurrence block length
    shared_attn_every: int = 0  # zamba2: shared transformer block cadence
    # --- block pattern (overrides the derived one when non-empty) -------
    block_pattern: Tuple[str, ...] = ()
    # --- modality frontend stubs ----------------------------------------
    frontend: Optional[str] = None  # audio | vision
    frontend_len: int = 0  # prefix positions fed by precomputed embeddings
    # --- paper integration ----------------------------------------------
    use_spectral_mixer: bool = False  # swap attention for FFT long-conv
    spectral_filter_len: int = 1024
    # Spectral decode state: "stream" carries the overlap-save tail + a
    # chunk accumulator and flushes through the cached block plan once per
    # chunk (amortized FFT decode); "ring" is the O(Lf·D)-per-token direct
    # dot (the exactness oracle).  spectral_decode_chunk=0 → sized from the
    # filter (max(8, next_pow2(Lf)/4)).
    spectral_decode_mode: str = "stream"  # stream | ring
    spectral_decode_chunk: int = 0
    # --- numerics / execution -------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024      # q-block size for chunked attention
    attn_chunk_threshold: int = 2048  # S above this uses chunked attention
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (quantized decode cache)
    decode_cache_mode: str = "carry"  # carry | ys (scan cache passing; §Perf)
    loss_chunk: int = 512       # vocab-loss sequence chunking

    # --- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or self.num_heads

    def pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds (the scan stack consumes this)."""
        if self.block_pattern:
            return self.block_pattern
        if self.family in ("dense", "audio", "vlm", "moe"):
            kind = "moe" if self.family == "moe" else "attn"
            if self.use_spectral_mixer:
                # paper-integration ablation: alternate FFT long-conv mixing
                # with attention (Hyena-style hybrid).
                assert self.num_layers % 2 == 0, self.num_layers
                return ("spectral", kind) * (self.num_layers // 2)
            if self.local_global_ratio:
                unit = ["attn_local"] * self.local_global_ratio + ["attn"]
                reps = self.num_layers // len(unit)
                assert reps * len(unit) == self.num_layers, (
                    self.num_layers,
                    len(unit),
                )
                return tuple(unit) * reps
            if self.sliding_window and not self.local_global_ratio:
                return ("attn_local",) * self.num_layers
            return (kind,) * self.num_layers
        raise ValueError(
            f"family {self.family!r} must set block_pattern explicitly"
        )


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How logical axes map onto the mesh (see repro.sharding.logical)."""

    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None  # present on the multi-pod mesh
    fsdp: bool = False              # shard params over the data axis too
    sequence_parallel: bool = False  # shard long KV caches over data
    remat_policy: str = "minimal"   # minimal | full | none
    # Decode-time layout for FSDP-sharded weights: keep weights stationary
    # (embed over data) and replicate the tiny one-token activations instead
    # of all-gathering every weight matrix each step (§Perf hillclimb 2).
    decode_weight_stationary: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"  # adamw | adafactor | sgd
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    batch_size: int = 8
    seq_len: int = 512
    microbatches: int = 1        # gradient accumulation / overlap
    grad_compression: bool = False  # int8 + error feedback
    z_loss: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, str] = {
    "gemma3-12b": "repro.configs.gemma3_12b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1p8b",
    "yi-6b": "repro.configs.yi_6b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3p8b",
    "arctic-480b": "repro.configs.arctic_480b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "musicgen-large": "repro.configs.musicgen_large",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "fftbench": "repro.configs.fftbench",
}

_EXTRA: dict[str, ModelConfig] = {}


def register(name: str, cfg: ModelConfig) -> None:
    _EXTRA[name] = cfg


def list_archs() -> list[str]:
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch in _EXTRA:
        return _EXTRA[arch]
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(_REGISTRY[arch])
    return mod.CONFIG


def shapes_for(arch: str) -> list[ShapeConfig]:
    """The assignment's shape cells for this arch (long_500k gated)."""
    mod = importlib.import_module(_REGISTRY[arch])
    names = getattr(mod, "SHAPES", ["train_4k", "prefill_32k", "decode_32k"])
    return [LM_SHAPES[n] for n in names if n in LM_SHAPES]
