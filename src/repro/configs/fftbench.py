"""fftbench — the paper's own workload as a first-class config.

Batched 1-D complex FFTs at the Table-1 sizes plus the SAR-representative
2-D workload (range/azimuth transforms over a 4096x8192 scene).  The
dry-run lowers the distributed pencil FFT (repro.core.distributed) over the
production mesh for these shapes; benchmarks/bench_table1.py measures the
single-device path against numpy (FFTW stand-in) and jnp.fft (CUFFT
stand-in).
"""

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class FFTShape:
    name: str
    n: int           # transform length (1-D) or rows for 2-D
    batch: int
    kind: str        # fft1d | fft2d | fftconv
    n2: int = 0      # cols for 2-D


CONFIG = ModelConfig(name="fftbench", family="fft")

# Table-1 sizes (paper) + pod-scale sizes the distributed layer targets.
FFT_SHAPES = [
    FFTShape("table1_4096", 4096, 4096, "fft1d"),
    FFTShape("table1_16384", 16384, 1024, "fft1d"),
    FFTShape("table1_65536", 65536, 256, "fft1d"),
    FFTShape("pod_1m", 2**20, 64, "fft1d"),
    FFTShape("pod_16m", 2**24, 32, "fft1d"),
    FFTShape("sar_4kx8k", 4096, 32, "fft2d", n2=8192),
    FFTShape("conv_512k", 2**19, 32, "fftconv"),
]

SHAPES = []  # LM shapes don't apply; dry-run uses FFT_SHAPES.
