"""phi4-mini-3.8b: 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.

RoPE + SwiGLU + GQA.  [arXiv:2412.08905; hf]
long_500k: SKIPPED — pure full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10000.0,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
