"""musicgen-large: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens.  [arXiv:2306.05284; hf]
Backbone only per the assignment: the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, S, d_model); the
4-codebook interleaving is reduced to a single 2048-token stream (DESIGN §5).
long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
