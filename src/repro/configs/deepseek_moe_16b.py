"""deepseek-moe-16b: 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400.

Fine-grained MoE: 64 routed experts top-6 + 2 shared experts.
[arXiv:2401.06066; hf]  long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
