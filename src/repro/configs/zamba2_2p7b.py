"""zamba2-2.7b: 54 Mamba2 layers, d_model=2560, ssm_state=64, + shared
attention blocks (32H, kv=32, d_ff=10240 MLP) every 6 Mamba2 layers.

[arXiv:2411.15242; hf]  Deviation noted in DESIGN §5: Zamba2's per-invocation
LoRA on the shared block is simplified to plain weight sharing.  Mamba2
inner dim 5120 → 80 heads of P=64.  long_500k: RUN — SSM state is O(1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=80,
    ssm_expand=2,
    chunk_size=256,
    shared_attn_every=6,
    block_pattern=(("mamba2",) * 6 + ("shared_attn",)) * 9,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
