"""h2o-danube-1.8b: 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

llama+mistral mix with sliding-window attention (mistral-style, 4096).
[arXiv:2401.16818; hf]  long_500k: RUN — SWA bounds the KV cache, decode is
sub-quadratic (O(window) per token).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
