"""Stateful serving sessions over the three-phase decode engine.

:class:`ServeSession` is the host-side orchestration layer: it owns a
fixed pool of batch slots (one :class:`~repro.serving.engine.DecodeState`),
admits requests into free slots (prefill → insert), and advances the whole
pool with compiled ``lax.scan`` generate calls.  Only admission runs
Python-per-request; token generation never leaves the compiled step
function, and every spectral flush inside it reuses the overlap-save plan
cached at trace time (``core.fft.plan_log()`` shows zero new plans once
the session is warm — benchmarks assert this).

Per-phase wall-clock is accumulated in ``session.phase_s`` (maxtext
decode-microbenchmark style: prefill / insert / generate timed
separately).  :func:`sweep_once` is the single measurement path shared by
``benchmarks/bench_serve.py`` and the ``repro.launch.serve`` CLI, so the
numbers they print are the same numbers.
"""

from __future__ import annotations

import collections
import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.serving.engine import DecodeState, Engine

__all__ = ["ServeSession", "sweep_once"]


class ServeSession:
    """A slot pool serving requests through prefill / insert / generate.

    Usage::

        sess = ServeSession(engine, slots=4, max_len=128)
        s0 = sess.submit([5, 17, 3, 20])   # prefill + insert (slot 0)
        s1 = sess.submit(other_prompt)     # joins the running batch
        sess.run(32)                       # one compiled scan, all slots
        sess.output(s0)                    # generated ids incl. first token

    Robustness (all opt-in, defaults preserve the original behavior):

    * **deadlines** — ``submit(..., deadline_s=1.0)`` (or a session-wide
      ``default_deadline_s``) stamps the request with a wall-clock budget;
      :meth:`run` reaps expired slots before and after the scan
      (``Engine.release`` freezes them exactly like a natural EOS) and
      counts them under ``expired``.
    * **admission queue** — with ``queue_cap > 0`` a full pool queues up to
      that many requests (FIFO, drained into slots freed by :meth:`run`)
      and returns a negative *ticket*; :meth:`output` resolves tickets once
      admitted.  Beyond the cap — or with the default ``queue_cap=0`` —
      submission raises a typed :class:`~repro.core.faults.ServeError`
      (explicit backpressure, never silent dropping).
    * **prefill retry** — transient prefill failures (the
      ``serve.prefill`` fault site, or any ``RuntimeError``/``OSError``)
      are retried up to ``prefill_retries`` times with exponential backoff
      before the error propagates.
    * :meth:`health` — a host-side snapshot of slots, queue depth, fault
      counters and kernel degradations for monitoring.
    """

    def __init__(
        self,
        engine: Engine,
        *,
        slots: int,
        max_len: int,
        seed: int = 0,
        queue_cap: int = 0,
        default_deadline_s: Optional[float] = None,
        prefill_retries: int = 2,
        retry_backoff_s: float = 0.05,
    ):
        self.engine = engine
        self.slots = slots
        self.max_len = max_len
        self.queue_cap = queue_cap
        self.default_deadline_s = default_deadline_s
        self.prefill_retries = prefill_retries
        self.retry_backoff_s = retry_backoff_s
        self.state: DecodeState = engine.init_state(slots, max_len)
        self._key = jax.random.PRNGKey(seed + 1)  # prefill sampling stream
        self._out: List[List[int]] = [[] for _ in range(slots)]
        self._live = [False] * slots  # host mirror of per-slot "still emitting"
        self._deadline: List[Optional[float]] = [None] * slots  # monotonic
        self._pending: collections.deque = collections.deque()
        self._next_ticket = -1
        self._ticket_slot: dict = {}  # ticket -> slot once admitted
        self.phase_s = {"prefill": 0.0, "insert": 0.0, "generate": 0.0}
        self.counts = {
            "requests": 0,
            "steps": 0,
            "tokens": 0,
            "rejected": 0,
            "expired": 0,
            "retries": 0,
            "queued": 0,
        }

    def free_slots(self) -> List[int]:
        return [i for i in range(self.slots) if not self._live[i]]

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        prompt,
        slot: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> int:
        """Prefill ``prompt`` (S,) and insert it into a free slot (or the
        given one).  Returns the slot index; the sampled first token is
        already part of :meth:`output`.  With a full pool and
        ``queue_cap > 0`` the request queues instead and a negative ticket
        is returned; beyond the cap a :class:`ServeError` is raised."""
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        if slot is None:
            free = self.free_slots()
            if not free:
                if len(self._pending) < self.queue_cap:
                    ticket = self._next_ticket
                    self._next_ticket -= 1
                    expiry = (
                        time.monotonic() + deadline_s
                        if deadline_s is not None
                        else None
                    )
                    self._pending.append((ticket, prompt, expiry))
                    self.counts["queued"] += 1
                    return ticket
                self.counts["rejected"] += 1
                raise faults.ServeError(
                    "no free slot and admission queue is full; run() until "
                    "a slot finishes or raise queue_cap",
                    site="serve.submit",
                    slots=self.slots,
                    queue_cap=self.queue_cap,
                )
            slot = free[0]
        expiry = time.monotonic() + deadline_s if deadline_s is not None else None
        return self._admit(prompt, slot, expiry)

    def _admit(self, prompt, slot: int, expiry: Optional[float]) -> int:
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        if prompt.shape[1] > self.max_len:
            raise faults.ServeError(
                f"prompt length {prompt.shape[1]} > max_len {self.max_len}"
            )
        self._key, sub = jax.random.split(self._key)

        t0 = time.perf_counter()
        pres = self._prefill_with_retry(prompt, sub)
        jax.block_until_ready(pres)
        t1 = time.perf_counter()
        self.state = self.engine.insert(self.state, pres, slot)
        jax.block_until_ready(self.state.done)
        t2 = time.perf_counter()

        self.phase_s["prefill"] += t1 - t0
        self.phase_s["insert"] += t2 - t1
        self.counts["requests"] += 1
        first = int(pres.token[0])
        self._out[slot] = [first]
        self._live[slot] = first != self.engine.scfg.eos_id
        self._deadline[slot] = expiry
        self.counts["tokens"] += 1
        return slot

    def _prefill_with_retry(self, prompt, key):
        """Transient prefill faults get ``prefill_retries`` more attempts
        with exponential backoff; a persistent fault propagates typed."""
        attempts = 1 + max(self.prefill_retries, 0)
        for i in range(attempts):
            try:
                return self.engine.prefill(prompt, max_len=self.max_len, key=key)
            except (RuntimeError, OSError):
                if i == attempts - 1:
                    raise
                self.counts["retries"] += 1
                time.sleep(self.retry_backoff_s * (2 ** i))

    def _reap(self) -> None:
        """Release slots whose deadline passed (frozen like a natural EOS)
        and drop expired queued requests."""
        now = time.monotonic()
        for b in range(self.slots):
            dl = self._deadline[b]
            if self._live[b] and dl is not None and now > dl:
                self.state = self.engine.release(self.state, b)
                self._live[b] = False
                self._deadline[b] = None
                self.counts["expired"] += 1
        while self._pending and (
            self._pending[0][2] is not None and now > self._pending[0][2]
        ):
            self._pending.popleft()
            self.counts["expired"] += 1

    def _drain(self) -> None:
        """Admit queued requests into whatever slots are free."""
        while self._pending and self.free_slots():
            ticket, prompt, expiry = self._pending.popleft()
            slot = self.free_slots()[0]
            self._admit(prompt, slot, expiry)
            self._ticket_slot[ticket] = slot

    # -- generation --------------------------------------------------------

    def run(self, steps: int):
        """Advance every slot ``steps`` tokens in ONE compiled scan.
        Returns the raw (slots, steps) emission matrix (``eos_id`` filler
        for slots that are done).  Expired slots are reaped and queued
        requests drained both before and after the scan."""
        self._reap()
        self._drain()
        t0 = time.perf_counter()
        self.state, toks = self.engine.decode(self.state, steps)
        toks.block_until_ready()
        self.phase_s["generate"] += time.perf_counter() - t0
        self.counts["steps"] += steps

        eos = self.engine.scfg.eos_id
        host = jax.device_get(toks)
        for b in range(self.slots):
            for s in range(steps):
                if not self._live[b]:
                    break
                t = int(host[b, s])
                self._out[b].append(t)
                self.counts["tokens"] += 1
                if t == eos:
                    self._live[b] = False
        self._reap()
        self._drain()
        return toks

    def output(self, handle: int) -> List[int]:
        """Generated ids for a slot index or queue ticket (first sampled
        token onward, EOS included when emitted)."""
        if handle < 0:
            if handle not in self._ticket_slot:
                raise faults.ServeError(
                    f"ticket {handle} is still queued; run() to drain it"
                )
            handle = self._ticket_slot[handle]
        return list(self._out[handle])

    def stats(self) -> dict:
        gen = self.phase_s["generate"]
        return {
            **{f"{k}_s": round(v, 6) for k, v in self.phase_s.items()},
            **self.counts,
            "tok_per_s": round(self.counts["tokens"] / gen, 2) if gen > 0 else None,
        }

    def health(self) -> dict:
        """A monitoring snapshot: slot occupancy, queue depth, session
        counters, kernel quarantine/degradations, and fault-injection
        counters (empty unless faults were armed)."""
        live = sum(self._live)
        return {
            "slots": self.slots,
            "live": live,
            "free": self.slots - live,
            "queue_depth": len(self._pending),
            "queue_cap": self.queue_cap,
            "counts": dict(self.counts),
            "quarantined": [list(q) for q in faults.quarantined()],
            "degradations": [dict(d) for d in faults.degradation_log()],
            "fault_counters": faults.fault_counters(),
        }


def sweep_once(
    engine: Engine,
    *,
    batch: int,
    prompt_len: int,
    max_new: int,
    warmup: int = 1,
    seed: int = 0,
) -> dict:
    """One measured serving sweep: ``batch`` requests of ``prompt_len``
    tokens admitted one by one (prefill + insert), then ``max_new - 1``
    scan steps.  ``warmup`` untimed passes absorb compilation.  Returns a
    flat dict of per-phase seconds and throughput — the row format of
    ``BENCH_serve.json`` and of the CLI's table."""
    max_len = prompt_len + max_new
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed), (batch, prompt_len), 4, engine.cfg.vocab_size
    )

    def one_pass():
        sess = ServeSession(engine, slots=batch, max_len=max_len, seed=seed)
        for b in range(batch):
            sess.submit(prompts[b], slot=b)
        if max_new > 1:
            sess.run(max_new - 1)
        return sess

    for _ in range(warmup):
        one_pass()
    sess = one_pass()

    st = sess.stats()
    gen = st["generate_s"]
    total = st["prefill_s"] + st["insert_s"] + gen
    decoded = batch * max(max_new - 1, 0)
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "prefill_s": st["prefill_s"],
        "insert_s": st["insert_s"],
        "generate_s": gen,
        "prefill_s_per_req": round(st["prefill_s"] / batch, 6),
        "insert_s_per_req": round(st["insert_s"] / batch, 6),
        "decode_tok_per_s": round(decoded / gen, 2) if gen > 0 else None,
        "e2e_tok_per_s": round(batch * max_new / total, 2) if total > 0 else None,
    }
