"""Stateful serving sessions over the three-phase decode engine.

:class:`ServeSession` is the host-side orchestration layer: it owns a
fixed pool of batch slots (one :class:`~repro.serving.engine.DecodeState`),
admits requests into free slots (prefill → insert), and advances the whole
pool with compiled ``lax.scan`` generate calls.  Only admission runs
Python-per-request; token generation never leaves the compiled step
function, and every spectral flush inside it reuses the overlap-save plan
cached at trace time (``core.fft.plan_log()`` shows zero new plans once
the session is warm — benchmarks assert this).

Per-phase wall-clock is accumulated in ``session.phase_s`` (maxtext
decode-microbenchmark style: prefill / insert / generate timed
separately).  :func:`sweep_once` is the single measurement path shared by
``benchmarks/bench_serve.py`` and the ``repro.launch.serve`` CLI, so the
numbers they print are the same numbers.
"""

from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.serving.engine import DecodeState, Engine

__all__ = ["ServeSession", "sweep_once"]


class ServeSession:
    """A slot pool serving requests through prefill / insert / generate.

    Usage::

        sess = ServeSession(engine, slots=4, max_len=128)
        s0 = sess.submit([5, 17, 3, 20])   # prefill + insert (slot 0)
        s1 = sess.submit(other_prompt)     # joins the running batch
        sess.run(32)                       # one compiled scan, all slots
        sess.output(s0)                    # generated ids incl. first token
    """

    def __init__(self, engine: Engine, *, slots: int, max_len: int, seed: int = 0):
        self.engine = engine
        self.slots = slots
        self.max_len = max_len
        self.state: DecodeState = engine.init_state(slots, max_len)
        self._key = jax.random.PRNGKey(seed + 1)  # prefill sampling stream
        self._out: List[List[int]] = [[] for _ in range(slots)]
        self._live = [False] * slots  # host mirror of per-slot "still emitting"
        self.phase_s = {"prefill": 0.0, "insert": 0.0, "generate": 0.0}
        self.counts = {"requests": 0, "steps": 0, "tokens": 0}

    def free_slots(self) -> List[int]:
        return [i for i in range(self.slots) if not self._live[i]]

    def submit(self, prompt, slot: Optional[int] = None) -> int:
        """Prefill ``prompt`` (S,) and insert it into a free slot (or the
        given one).  Returns the slot index; the sampled first token is
        already part of :meth:`output`."""
        if slot is None:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free slot; run() until one finishes")
            slot = free[0]
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        if prompt.shape[1] > self.max_len:
            raise ValueError(f"prompt length {prompt.shape[1]} > max_len {self.max_len}")
        self._key, sub = jax.random.split(self._key)

        t0 = time.perf_counter()
        pres = self.engine.prefill(prompt, max_len=self.max_len, key=sub)
        jax.block_until_ready(pres)
        t1 = time.perf_counter()
        self.state = self.engine.insert(self.state, pres, slot)
        jax.block_until_ready(self.state.done)
        t2 = time.perf_counter()

        self.phase_s["prefill"] += t1 - t0
        self.phase_s["insert"] += t2 - t1
        self.counts["requests"] += 1
        first = int(pres.token[0])
        self._out[slot] = [first]
        self._live[slot] = first != self.engine.scfg.eos_id
        self.counts["tokens"] += 1
        return slot

    def run(self, steps: int):
        """Advance every slot ``steps`` tokens in ONE compiled scan.
        Returns the raw (slots, steps) emission matrix (``eos_id`` filler
        for slots that are done)."""
        t0 = time.perf_counter()
        self.state, toks = self.engine.decode(self.state, steps)
        toks.block_until_ready()
        self.phase_s["generate"] += time.perf_counter() - t0
        self.counts["steps"] += steps

        eos = self.engine.scfg.eos_id
        host = jax.device_get(toks)
        for b in range(self.slots):
            for s in range(steps):
                if not self._live[b]:
                    break
                t = int(host[b, s])
                self._out[b].append(t)
                self.counts["tokens"] += 1
                if t == eos:
                    self._live[b] = False
        return toks

    def output(self, slot: int) -> List[int]:
        """Generated ids for ``slot`` (first sampled token onward, EOS
        included when emitted)."""
        return list(self._out[slot])

    def stats(self) -> dict:
        gen = self.phase_s["generate"]
        return {
            **{f"{k}_s": round(v, 6) for k, v in self.phase_s.items()},
            **self.counts,
            "tok_per_s": round(self.counts["tokens"] / gen, 2) if gen > 0 else None,
        }


def sweep_once(
    engine: Engine,
    *,
    batch: int,
    prompt_len: int,
    max_new: int,
    warmup: int = 1,
    seed: int = 0,
) -> dict:
    """One measured serving sweep: ``batch`` requests of ``prompt_len``
    tokens admitted one by one (prefill + insert), then ``max_new - 1``
    scan steps.  ``warmup`` untimed passes absorb compilation.  Returns a
    flat dict of per-phase seconds and throughput — the row format of
    ``BENCH_serve.json`` and of the CLI's table."""
    max_len = prompt_len + max_new
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed), (batch, prompt_len), 4, engine.cfg.vocab_size
    )

    def one_pass():
        sess = ServeSession(engine, slots=batch, max_len=max_len, seed=seed)
        for b in range(batch):
            sess.submit(prompts[b], slot=b)
        if max_new > 1:
            sess.run(max_new - 1)
        return sess

    for _ in range(warmup):
        one_pass()
    sess = one_pass()

    st = sess.stats()
    gen = st["generate_s"]
    total = st["prefill_s"] + st["insert_s"] + gen
    decoded = batch * max(max_new - 1, 0)
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "prefill_s": st["prefill_s"],
        "insert_s": st["insert_s"],
        "generate_s": gen,
        "prefill_s_per_req": round(st["prefill_s"] / batch, 6),
        "insert_s_per_req": round(st["insert_s"] / batch, 6),
        "decode_tok_per_s": round(decoded / gen, 2) if gen > 0 else None,
        "e2e_tok_per_s": round(batch * max_new / total, 2) if total > 0 else None,
    }
