"""Batched decode engine: jitted prefill / insert / generate phases.

The serving core under :mod:`repro.serving.spectral_serve`.  Three compiled
phases over an explicit :class:`DecodeState` (continuous-batching-lite):

* **prefill** — run the prompt once, convert the caches to decode layout
  (``prepare_decode_caches`` runs inside the jit) and sample the first
  token: a :class:`PrefillResult` for one request.
* **insert** — splice a prefilled request into a slot of a *running*
  batch state: KV caches are written at the slot's batch row, spectral
  stream caches are re-phased to the running window
  (:func:`repro.models.layers.spectral.spectral_stream_rephase`), and the
  slot's token/length/done rows are reset.  Each slot keeps its OWN
  timeline — ``decode_step`` takes the (B,) length vector as per-slot
  positions — so no position shifting is needed.
* **generate** — ONE ``lax.scan`` over steps with a single compiled step
  function: decode, sample, per-slot EOS masking.  Finished slots emit
  ``eos_id`` and their caches/lengths/last-token are frozen (the step still
  computes them — batch lockstep — but the results are discarded), so a
  finished slot's state is bit-identical until something is inserted over
  it.  No per-token Python, no retracing, and zero new FFT plans after the
  first trace — every spectral flush reuses the cached overlap-save plan.

``Engine.generate`` keeps the original whole-batch convenience API on top
of the three phases.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.models import model as model_lib
from repro.models import stack as stack_lib
from repro.models.layers import spectral as spec_lib
from repro.serving.sampling import sample

__all__ = ["ServeConfig", "Engine", "DecodeState", "PrefillResult"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    eos_id: int = 3
    seed: int = 0


class PrefillResult(NamedTuple):
    """One prefilled request, ready to insert: decode-layout caches (batch
    = the request's own batch, usually 1), first sampled token and prompt
    length per row."""

    caches: Any
    token: jax.Array   # (B,) int32
    length: jax.Array  # (B,) int32 — next position to write


class DecodeState(NamedTuple):
    """The running batch: one row per serving slot."""

    caches: Any
    tokens: jax.Array   # (B,) int32 — last token per slot (next step's input)
    lengths: jax.Array  # (B,) int32 — per-slot next write position
    done: jax.Array     # (B,) bool — finished (or never-filled) slots
    key: jax.Array      # sampling PRNG key


def _select_rows(done, old, new):
    """Per-leaf freeze: keep ``old``'s batch rows where ``done``.  Cache
    leaves are stacked (repeats, batch, ...); leaves without a batch axis
    (the spectral stream phase, ring counters) advance globally."""
    if getattr(new, "ndim", 0) >= 2 and new.shape[1] == done.shape[0]:
        m = done.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(m, old, new)
    return new


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.unit = stack_lib.find_unit(cfg.pattern())
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("max_len",))
        self._insert = jax.jit(self._insert_fn)
        self._generate = jax.jit(self._generate_fn, static_argnames=("steps",))
        self._release = jax.jit(self._release_fn)

    def _sample(self, key, logits):
        return sample(
            key,
            logits,
            temperature=self.scfg.temperature,
            top_k=self.scfg.top_k,
            top_p=self.scfg.top_p,
        )

    # -- prefill phase -----------------------------------------------------

    def _prefill_fn(self, params, prompts, key, *, max_len):
        b, s = prompts.shape
        logits, caches = model_lib.prefill(params, {"tokens": prompts}, self.cfg)
        caches = model_lib.prepare_decode_caches(caches, self.cfg, s, max_len)
        token = self._sample(key, logits)
        return PrefillResult(
            caches=caches,
            token=token.astype(jnp.int32),
            length=jnp.full((b,), s, jnp.int32),
        )

    def prefill(self, prompts, *, max_len: int, key) -> PrefillResult:
        """Run one request's prompt (B, S) → :class:`PrefillResult` whose
        caches are laid out for a ``max_len``-slot decode state."""
        faults.maybe_fail("serve.prefill", max_len=max_len)
        return self._prefill(self.params, jnp.asarray(prompts, jnp.int32), key,
                             max_len=max_len)

    # -- batch state -------------------------------------------------------

    def init_state(self, batch: int, max_len: int, key=None) -> DecodeState:
        """An empty ``batch``-slot decode state (every slot done)."""
        dtype = jnp.dtype(self.cfg.compute_dtype)
        caches = model_lib.cache_init(self.cfg, batch, max_len, dtype=dtype)
        return DecodeState(
            caches=caches,
            tokens=jnp.zeros((batch,), jnp.int32),
            lengths=jnp.zeros((batch,), jnp.int32),
            done=jnp.ones((batch,), bool),
            key=key if key is not None else jax.random.PRNGKey(self.scfg.seed),
        )

    # -- insert phase ------------------------------------------------------

    def _insert_fn(self, params, state, pres, slot):
        nslots = state.tokens.shape[0]

        def write(buf, new):
            if (
                getattr(buf, "ndim", 0) >= 2
                and getattr(new, "ndim", 0) == buf.ndim
                and buf.shape[0] == new.shape[0]
                and buf.shape[1] == nslots
                and new.shape[1] <= nslots
                and buf.shape[2:] == new.shape[2:]
            ):
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), slot, axis=1
                )
            return buf  # batchless leaves (phase / ring counters): keep live

        caches = []
        for i, (kind, live, new) in enumerate(
            zip(self.unit, state.caches, pres.caches)
        ):
            if isinstance(live, spec_lib.SpectralStreamCache):
                # Re-align the fresh request to the running window phase;
                # filt is stacked over repeats like the cache → vmap.
                phase = live.phase.reshape(-1)[0]
                filt = params["stack"]["unit"][f"b{i}"]["mixer"]["filt"]
                new = jax.vmap(
                    lambda f, c: spec_lib.spectral_stream_rephase(
                        f, c, phase, cfg=self.cfg
                    )
                )(filt, new)
            caches.append(jax.tree.map(write, live, new))

        def put(vec, val):
            return jax.lax.dynamic_update_slice(vec, val.astype(vec.dtype), (slot,))

        return DecodeState(
            caches=caches,
            tokens=put(state.tokens, pres.token),
            lengths=put(state.lengths, pres.length),
            done=put(state.done, pres.token == self.scfg.eos_id),
            key=state.key,
        )

    def insert(self, state: DecodeState, pres: PrefillResult, slot) -> DecodeState:
        """Splice ``pres`` (batch 1 — or k consecutive slots) into ``state``
        starting at ``slot``.  Requires stream-mode spectral caches: the
        ring layout's shared step counter cannot represent per-slot
        timelines."""
        faults.maybe_fail("serve.insert")
        for live in state.caches:
            if isinstance(live, spec_lib.SpectralCache):
                raise faults.ServeError(
                    "insert needs spectral_decode_mode='stream' (the ring "
                    "cache keeps one global step counter and cannot join a "
                    "running batch)"
                )
        return self._insert(self.params, state, pres, jnp.asarray(slot, jnp.int32))

    # -- generate phase ----------------------------------------------------

    def _generate_fn(self, params, state, *, steps):
        eos = self.scfg.eos_id

        def step(st, _):
            logits, new_caches = model_lib.decode_step(
                params, st.tokens, st.caches, st.lengths, self.cfg
            )
            key, sub = jax.random.split(st.key)
            nxt = self._sample(sub, logits)
            emit = jnp.where(st.done, jnp.int32(eos), nxt).astype(jnp.int32)
            caches = jax.tree.map(
                lambda old, new: _select_rows(st.done, old, new),
                st.caches,
                new_caches,
            )
            lengths = st.lengths + jnp.where(st.done, 0, 1).astype(jnp.int32)
            tokens = jnp.where(st.done, st.tokens, emit)
            return (
                DecodeState(caches, tokens, lengths, st.done | (emit == eos), key),
                emit,
            )

        state, toks = jax.lax.scan(step, state, None, length=steps)
        return state, jnp.moveaxis(toks, 0, 1)  # (B, steps)

    def decode(self, state: DecodeState, steps: int):
        """Run ``steps`` decode steps as one compiled scan.  Returns
        (new_state, tokens (B, steps) int32 — ``eos_id`` for done slots)."""
        faults.maybe_fail("serve.generate", steps=steps)
        return self._generate(self.params, state, steps=steps)

    # -- slot release ------------------------------------------------------

    def _release_fn(self, state, slot):
        done = jax.lax.dynamic_update_slice(
            state.done, jnp.ones((1,), bool), (slot,)
        )
        return state._replace(done=done)

    def release(self, state: DecodeState, slot) -> DecodeState:
        """Mark ``slot`` done (deadline reaping / cancellation): its caches
        freeze and the scan emits ``eos_id`` filler until something is
        inserted over it — exactly the state a naturally-finished slot is
        left in."""
        return self._release(state, jnp.asarray(slot, jnp.int32))

    # -- whole-batch convenience (the original API) ------------------------

    def generate(self, prompts: jax.Array, *, max_new: Optional[int] = None):
        """prompts: (B, S) int32 → (B, max_new) int32 generated tokens."""
        b, s = prompts.shape
        max_new = max_new or self.scfg.max_new
        key = jax.random.PRNGKey(self.scfg.seed)
        key, sub = jax.random.split(key)
        pres = self.prefill(prompts, max_len=s + max_new, key=sub)
        first = pres.token
        if max_new == 1:
            return first[:, None]
        state = DecodeState(
            caches=pres.caches,
            tokens=first,
            lengths=pres.length,
            done=first == self.scfg.eos_id,
            key=key,
        )
        _, toks = self.decode(state, max_new - 1)
        return jnp.concatenate([first[:, None], toks], axis=1)
