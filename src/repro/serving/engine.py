"""Batched decode engine: prefill → jitted token loop with KV/SSM caches.

A deliberately small but real serving path: batch of prompts in, prefill
once (building caches), then a jit-compiled ``decode_fn`` generates tokens
until ``max_new`` (per-sequence EOS masking included).  The decode step is
the function the dry-run lowers for the ``decode_*`` shape cells.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.serving.sampling import sample

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = 3
    seed: int = 0


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, params, tokens, caches, t, key):
        logits, caches = model_lib.decode_step(params, tokens, caches, t, self.cfg)
        key, sub = jax.random.split(key)
        nxt = sample(
            sub, logits, temperature=self.scfg.temperature, top_k=self.scfg.top_k
        )
        return nxt, caches, key

    def generate(self, prompts: jax.Array, *, max_new: Optional[int] = None):
        """prompts: (B, S) int32 → (B, max_new) int32 generated tokens."""
        b, s = prompts.shape
        max_new = max_new or self.scfg.max_new
        batch = {"tokens": prompts}
        logits, caches = model_lib.prefill(self.params, batch, self.cfg)
        caches = model_lib.prepare_decode_caches(caches, self.cfg, s, s + max_new)
        key = jax.random.PRNGKey(self.scfg.seed)
        key, sub = jax.random.split(key)
        nxt = sample(sub, logits, temperature=self.scfg.temperature, top_k=self.scfg.top_k)
        out = [nxt]
        done = nxt == self.scfg.eos_id
        for i in range(max_new - 1):
            t = jnp.asarray(s + i, jnp.int32)
            nxt, caches, key = self._decode(self.params, nxt, caches, t, key)
            nxt = jnp.where(done, self.scfg.eos_id, nxt)
            done = done | (nxt == self.scfg.eos_id)
            out.append(nxt)
        return jnp.stack(out, axis=1)
