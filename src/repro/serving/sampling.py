"""Sampling: greedy / temperature / top-k over final logits."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample"]


def sample(key, logits, *, temperature: float = 0.0, top_k: int = 0):
    """logits: (B, V) float32 → (B,) int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
