"""Sampling: greedy / temperature / top-k / top-p over final logits.

``top_k`` and ``top_p`` share one mechanism: compute a per-row cutoff logit
and mask everything strictly below it to −∞ (:func:`_mask_below`).  top-k's
cutoff is the k-th largest logit; top-p's (nucleus) is the smallest logit
whose inclusion is still needed to reach cumulative probability ``top_p``
(so at least one token always survives).  Both filters compose: k first,
then p over what k kept.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample"]

NEG_INF = -1e30


def _mask_below(logits, cutoff):
    """Mask logits strictly below the per-row ``cutoff`` (..., 1) to −∞."""
    return jnp.where(logits < cutoff, NEG_INF, logits)


def _nucleus_cutoff(logits, top_p: float):
    """Per-row nucleus cutoff: keep the smallest set of top tokens whose
    probability mass reaches ``top_p``.  A token is kept when the mass of
    strictly-better tokens is still < top_p — the argmax always qualifies."""
    sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    mass_before = jnp.cumsum(probs, axis=-1) - probs
    kept = (mass_before < top_p).sum(axis=-1)  # ≥ 1 per row
    return jnp.take_along_axis(sorted_desc, kept[..., None] - 1, axis=-1)


def sample(key, logits, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 0.0):
    """logits: (B, V) float32 → (B,) int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = _mask_below(logits, vals[..., -1:])
    if top_p and top_p < 1.0:
        logits = _mask_below(logits, _nucleus_cutoff(logits, top_p))
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
