"""Roofline-seeded autotuner — measured plan tuning with a persistent cache.

The paper's performance claim rests on dividing the data "reasonably
according to the size of data"; until now every such division in this
reproduction was a fixed constant (``OS_FACTOR=8`` overlap-save blocks, the
VMEM-budget pass chunk, the ``FUSED_MAX`` crossover).  Adámek et al. (GPU
overlap-and-save) and Bergach et al. (model-guided FFT mapping) both show
those constants leave several-fold throughput on the table across shapes.
This module turns each of them into a searched decision:

1. a :class:`TuningSpace` enumerates the candidate configs of one decision —
   overlap-save block sizes for a ``(L, Lh)`` convolution, or whole plan
   configs (fused-vs-split crossover, per-pass chunk width, leaf batch
   tile) for an :class:`~repro.core.fft.FFTSpec`;
2. the roofline model prunes the space
   (:func:`repro.analysis.roofline.prune_candidates`): only candidates
   within ~20% of the modeled-minimum HBM bytes, and whose per-grid-step
   working set fits :data:`~repro.core.limits.VMEM_BUDGET`, survive;
3. ``tune="measure"`` times the survivors on device (min-of-reps,
   ``block_until_ready``) and records the winner in a **persistent JSON
   cache** keyed by ``(device_kind, backend, spec)`` — so the search runs
   once per device and shape, ever; ``tune="model"`` skips measurement and
   takes the modeled pick — the zero-measurement default, which keeps the
   fixed heuristic on modeled ties but DOES deviate when the model finds a
   schedule with strictly fewer HBM bytes (e.g. swapping a direct leaf
   whose n² DFT matrix dominates the stream for a fused four-step engine);
   ``tune="off"`` bypasses the tuner entirely and is the exact historical
   behavior.

Consumers — :func:`repro.core.fft.plan`,
:func:`repro.core.overlap.fft_conv_os` / :class:`~repro.core.overlap.
StreamingConv`, and :func:`repro.core.distributed.pconv_os_sharded` — pass
``tune=`` through; the default mode comes from the ``REPRO_FFT_TUNE``
environment variable (``model`` when unset).  The cache file lives at
``REPRO_TUNING_CACHE`` (default ``~/.cache/repro-fft/tuning.json``).

Every on-device timing is appended to :func:`measure_log`, which is how the
tests assert cache hits perform **zero** measurements.
"""

from __future__ import annotations

import json
import os
import time
import warnings
from typing import Callable, Optional

from repro.core import faults

__all__ = [
    "TUNE_MODES",
    "CACHE_SCHEMA_VERSION",
    "resolve_mode",
    "TuningSpace",
    "TuningCache",
    "cache",
    "cache_path",
    "seed_cache",
    "device_key",
    "plan_config",
    "backend_pick",
    "tuned_block",
    "modeled_block",
    "pencil_config",
    "measure_log",
    "clear_measure_log",
]

TUNE_MODES = ("off", "model", "measure")

#: On-disk cache file schema.  Bump when the file layout changes; a file
#: with any other version is quarantined as foreign rather than guessed at.
CACHE_SCHEMA_VERSION = 1

#: Modeled-bytes tolerance of the roofline pruning: candidates more than
#: 20% above the modeled-minimum HBM traffic are never worth measuring.
PRUNE_TOL = 0.2

#: Timing discipline for the measurement pass.
MEASURE_REPS = 5
MEASURE_WARMUP = 2

#: A candidate must beat the fixed heuristic by this fraction to dethrone
#: it: within the margin the measurement is noise, and keeping the default
#: preserves "tuned is never slower than fixed" across noisy re-runs.
DEFAULT_MARGIN = 0.10

#: Survivors are timed in this many interleaved rounds (min across rounds),
#: so slow machine drift lands on every candidate instead of whichever was
#: measured last.
MEASURE_ROUNDS = 2


def resolve_mode(tune: Optional[str]) -> str:
    """Resolve a ``tune=`` argument: explicit value, else ``REPRO_FFT_TUNE``,
    else ``"model"`` (the zero-measurement modeled pick)."""
    if tune is None:
        tune = os.environ.get("REPRO_FFT_TUNE") or "model"
    if tune not in TUNE_MODES:
        raise faults.PlanError(f"tune must be one of {TUNE_MODES}, got {tune!r}")
    return tune


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def cache_path() -> str:
    """Resolved per-operation so tests can redirect via the environment."""
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro-fft", "tuning.json"
    )


_SEED_CACHE: Optional[dict] = None


def seed_cache() -> dict:
    """The read-only seed tuning cache shipped as package data
    (``repro/data/tuning_seed.json``) — measured winners for common
    (device_kind, spec) pairs, layered *beneath* the user cache so a spec
    present in the seed plans tuned out of the box with zero first-request
    measurement.  Missing or unreadable package data degrades to empty."""
    global _SEED_CACHE
    if _SEED_CACHE is None:
        data: dict = {}
        try:
            from importlib import resources

            text = (
                resources.files("repro.data")
                .joinpath("tuning_seed.json")
                .read_text()
            )
            loaded = json.loads(text)
            if isinstance(loaded, dict):
                data = loaded
        except Exception:  # pragma: no cover - package-data-less installs
            data = {}
        _SEED_CACHE = data
    return _SEED_CACHE


def device_key() -> str:
    """First device's kind — the hardware half of every cache key (a config
    tuned on one accelerator generation must not leak onto another)."""
    import jax  # local: keep module import cheap

    try:
        return jax.devices()[0].device_kind.replace("|", "_")
    except Exception:  # pragma: no cover - backendless builds
        return jax.default_backend()


class TuningCache:
    """The persistent winner store: a versioned JSON file
    (``{"version": CACHE_SCHEMA_VERSION, "entries": {...}}``) whose entries
    map ``device|backend|decision|spec`` keys to
    ``{"config": ..., "mode": ...}``.

    Reads are lazy and memoized per path.  Writes re-read the file, merge,
    and replace it atomically (temp file + ``os.replace``), so concurrent
    processes sharing one cache append winners instead of clobbering each
    other's, and a reader can never observe a half-written file.  An
    unwritable cache directory degrades to memory-only rather than failing
    the transform.

    Robustness: a corrupted, truncated, or foreign-schema cache file is
    quarantined to a ``.corrupt`` sibling with a warning and the cache
    rebuilds from the packaged seed (:func:`seed_cache` layers beneath
    every :meth:`get`) — seeded specs keep planning with zero measurements.
    Pre-versioning flat files are still readable and upgrade to the
    versioned schema on the next write.  The ``tuning.cache_read`` /
    ``tuning.cache_write`` fault sites cover both paths."""

    def __init__(self):
        self._mem: dict = {}
        self._loaded_path: Optional[str] = None

    @staticmethod
    def _quarantine_corrupt(path: str, reason: str) -> None:
        corrupt = path + ".corrupt"
        try:
            os.replace(path, corrupt)
            moved = f"quarantined to {corrupt}"
        except OSError:
            moved = "could not quarantine the file"
        warnings.warn(
            f"tuning cache {path} is unusable ({reason}); {moved}; "
            f"rebuilding from the packaged seed",
            RuntimeWarning,
            stacklevel=3,
        )

    @staticmethod
    def _validate_schema(data, path: str) -> dict:
        """Entries of a loaded cache document, or {} after quarantining a
        foreign-schema file."""
        if (
            isinstance(data, dict)
            and data.get("version") == CACHE_SCHEMA_VERSION
            and isinstance(data.get("entries"), dict)
        ):
            return data["entries"]
        if (
            isinstance(data, dict)
            and "version" not in data
            and all(
                isinstance(v, dict) and "config" in v for v in data.values()
            )
        ):
            # Pre-versioning flat schema: readable as-is, upgraded on the
            # next put().
            return data
        TuningCache._quarantine_corrupt(
            path, f"foreign schema (version {data.get('version') if isinstance(data, dict) else type(data).__name__!r})"
        )
        return {}

    @staticmethod
    def _read_file(path: str) -> dict:
        if not os.path.exists(path):
            return {}
        try:
            faults.maybe_fail("tuning.cache_read", path=path)
            with open(path) as f:
                data = json.load(f)
        except faults.TuningCacheError:
            # Injected read fault: behave like an unreadable file — memory +
            # seed keep serving, nothing is quarantined (the file is fine).
            return {}
        except (json.JSONDecodeError, OSError) as err:
            TuningCache._quarantine_corrupt(path, f"{type(err).__name__}: {err}")
            return {}
        return TuningCache._validate_schema(data, path)

    def _load(self) -> dict:
        path = cache_path()
        if self._loaded_path != path:
            self._loaded_path = path
            self._mem = self._read_file(path)
        return self._mem

    def get(self, key: str) -> Optional[dict]:
        hit = self._load().get(key)
        if hit is not None:
            return hit
        # User-cache miss: fall through to the shipped read-only seed, so
        # common (device_kind, spec) pairs are tuned out of the box.  A
        # later put() of the same key shadows the seed (user cache wins).
        return seed_cache().get(key)

    def put(self, key: str, entry: dict) -> None:
        mem = self._load()
        mem[key] = entry
        path = cache_path()
        try:
            faults.maybe_fail("tuning.cache_write", path=path)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            # Merge-on-write: another process may have persisted winners
            # since our load; union them (our new entry wins its own key).
            merged = {**self._read_file(path), **mem}
            doc = {"version": CACHE_SCHEMA_VERSION, "entries": merged}
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
            self._mem = merged
        except (OSError, faults.TuningCacheError):
            pass  # memory-only fallback

    def clear(self) -> None:
        """Drop the in-memory view AND the persisted file (tests)."""
        self._mem = {}
        self._loaded_path = None
        path = cache_path()
        try:
            if os.path.exists(path):
                os.remove(path)
        except OSError:
            pass


#: Process-wide cache instance every decision goes through.
cache = TuningCache()


# ---------------------------------------------------------------------------
# Measurement log (how tests assert "zero measurements on a cache hit")
# ---------------------------------------------------------------------------

_MEASURE_LOG: list = []


def measure_log() -> tuple:
    """Every on-device timing taken this process: (decision, key, config)."""
    return tuple(_MEASURE_LOG)


def clear_measure_log() -> None:
    _MEASURE_LOG.clear()


def _time(fn, reps: int = MEASURE_REPS, warmup: int = MEASURE_WARMUP) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# TuningSpace
# ---------------------------------------------------------------------------


class TuningSpace:
    """The candidate configs of ONE tunable decision.

    ``candidates`` is an ordered list of ``(config, modeled_bytes,
    vmem_bytes)`` triples — the fixed heuristic's pick FIRST, so modeled
    ties resolve to the historical behavior.  ``measure_fn(config)`` runs
    one on-device trial and returns seconds.
    """

    def __init__(
        self,
        decision: str,
        key: str,
        candidates: list,
        measure_fn: Optional[Callable] = None,
        budget: Optional[int] = None,
    ):
        if not candidates:
            raise ValueError(f"empty tuning space for {decision} {key}")
        self.decision = decision
        self.key = key
        self.candidates = candidates
        self.measure_fn = measure_fn
        #: Fast-tier working-set budget the feasibility pruning binds against
        #: (None → the TPU ``VMEM_BUDGET`` default inside prune_candidates).
        self.budget = budget

    # -- construction ------------------------------------------------------

    @classmethod
    def for_os_block(
        cls,
        L: int,
        Lh: int,
        batch: int,
        backend: Optional[str],
        chunk: Optional[int] = None,
    ):
        """Overlap-save block sizes for a ``(batch, L) ⊛ (Lh,)`` convolution.

        Candidates: every power of two from the fixed heuristic's floor
        (``2·next_pow2(Lh)`` — at least half of each block valid) up to
        :data:`~repro.core.limits.FUSED_MAX`, heuristic default first.
        Modeled bytes come from :func:`repro.analysis.roofline.conv_report`
        (framing redundancy + plan traffic per block), which is exactly the
        trade the block size moves: small blocks re-transform more overlap,
        large blocks pay bigger per-block programs.

        ``chunk`` keys the decision to a *streaming call grain* (serving
        decode, strip ingest): the modeled signal becomes one chunked call
        (``Lh − 1`` carried tail + ``chunk`` fresh samples) and measurement
        times :class:`repro.core.overlap.StreamingConv` chunk calls instead
        of one long ingest — a block sized for a million-sample ingest
        wastes its unfilled step every call when chunks are short.
        """
        from repro.analysis import roofline as rl
        from repro.core import overlap as ov
        from repro.core.limits import FUSED_MAX, next_pow2
        from repro.core import plan as plan_lib

        default = ov.pick_block(Lh)
        blocks = [default]
        b = max(2 * next_pow2(Lh), 2)
        while b <= FUSED_MAX:
            if b != default and b > Lh - 1:
                blocks.append(b)
            b *= 2
        L_call = (chunk + Lh - 1) if chunk else L  # per-call signal length
        cands = []
        for blk in blocks:
            modeled = rl.conv_report(L_call, Lh, batch=batch, block=blk)
            leaf = plan_lib._leaf_pass(max(blk // 2, 1))
            vmem = plan_lib.vmem_bytes(leaf, plan_lib.pick_batch_tile(leaf))
            cands.append(
                ({"block": blk}, modeled["overlap_save"]["hbm_bytes"], vmem)
            )

        def measure(config):
            import jax
            import jax.numpy as jnp
            import numpy as np

            h = jnp.asarray(
                np.random.default_rng(1).standard_normal((Lh,)), jnp.float32
            )
            if chunk:
                sc = ov.StreamingConv(
                    h, block=config["block"], backend=backend, tune="off"
                )
                x = jnp.asarray(
                    np.random.default_rng(0).standard_normal((batch, chunk)),
                    jnp.float32,
                )
                state = sc.init_state((batch,))
                fn = jax.jit(sc.__call__)
                return _time(lambda: fn(x, state))
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal((batch, L)), jnp.float32
            )
            fn = jax.jit(
                lambda a, b: ov.fft_conv_os(
                    a, b, block=config["block"], backend=backend, tune="off"
                )
            )
            return _time(lambda: fn(x, h))

        key = f"{backend or 'auto'}|os_block|L={L},Lh={Lh},batch={batch}"
        if chunk:
            key += f",chunk={chunk}"
        return cls("os_block", key, cands, measure)

    @classmethod
    def for_plan(cls, spec, backend_name: str):
        """Whole plan configs for an FFTSpec: the fused-vs-split crossover
        (``fused_max``), the leaf engine boundary (``direct_max`` — direct
        DFT matmul vs fused four-step for boundary leaves), a per-pass
        chunk-width scale, and a leaf batch-tile scale — the heuristic
        config first.

        Chunk and tile scalings do not move the modeled HBM bytes (the
        bytes are the signal + LUT streams, not the grid decomposition), so
        the roofline keeps them all and only measurement separates them;
        ``fused_max`` / ``direct_max`` alternatives DO move modeled bytes
        (an extra factor is an extra image round trip; a direct leaf
        streams its n² DFT matrix) and are pruned hard — ``tune="model"``
        keeps the historical plan on ties and deviates only where the
        model's HBM-byte account is strictly cheaper.

        Candidate enumeration and feasibility bind against the *resolved*
        device budget (:func:`repro.core.limits.memory_budget`): VMEM on
        TPU/CPU, per-SM shared memory on CUDA-class devices — where the
        ``pallas_gpu`` backend additionally swaps in the GPU working-set
        model (LUTs staged through the GEMM pipeline, not resident).
        """
        from repro.core import limits, plan as plan_lib
        from repro.core.limits import DIRECT_MAX, FUSED_MAX

        n, n2 = spec.n, getattr(spec, "n2", None)
        budget = limits.memory_budget()
        gpu = backend_name == "pallas_gpu"
        if gpu:
            pick_tile = lambda p: plan_lib.pick_batch_tile_gpu(p, budget)  # noqa: E731
            tile_bytes = plan_lib.gpu_smem_bytes
        else:
            pick_tile = lambda p: plan_lib.pick_batch_tile(p, budget)  # noqa: E731
            tile_bytes = plan_lib.vmem_bytes

        def build(fused_max, direct_max=DIRECT_MAX, pad=None):
            if n2 is not None:
                return plan_lib.plan_fft2(n, n2, fused_max, direct_max)
            return plan_lib.plan_fft(n, fused_max, direct_max, pad=pad)

        def config_for(
            fused_max, chunk_shift, tile_shift, direct_max=DIRECT_MAX, pad=None
        ):
            plan = build(fused_max, direct_max, pad)
            chunks = {}
            for i, p in enumerate(plan.passes):
                if p.kind == "reorder":
                    continue
                if p.axis == -2:
                    # Column passes sweep the image width (n row bins).
                    base = plan_lib.pick_pass_chunk(p, budget=budget, width=n)
                elif p.view_in and p.view_in[0] == 1:
                    continue  # whole-signal pass: batch-tiled, not chunked
                else:
                    base = plan_lib.pick_pass_chunk(p, budget=budget)
                chunks[str(i)] = max(1, base >> chunk_shift)
            tiles = {}
            for p in plan.leaf_passes:
                base = pick_tile(p)
                tiles[str(p.n)] = max(1, base >> tile_shift)
            cfg = {
                "fused_max": fused_max,
                "direct_max": direct_max,
                "chunks": chunks,
                "batch_tiles": tiles,
            }
            if pad is not None:
                cfg["bluestein_pad"] = pad
            return cfg

        def modeled(fused_max, direct_max=DIRECT_MAX, pad=None):
            plan = build(fused_max, direct_max, pad)
            shape2d = (n2, n) if n2 is not None else None
            return plan_lib.program_hbm_bytes(
                plan.passes, spec.batch_hint or 1, shape2d
            )

        def vmem_of(config):
            plan = build(
                config["fused_max"],
                config.get("direct_max", DIRECT_MAX),
                config.get("bluestein_pad"),
            )
            worst = 0
            for i, p in enumerate(plan.passes):
                if p.kind == "reorder":
                    continue
                c = config["chunks"].get(str(i))
                if c is not None:
                    if not gpu:  # chunked passes are the gpu xla fallback's
                        worst = max(worst, plan_lib._pass_chunk_bytes(p, c))
                else:
                    t = config["batch_tiles"].get(str(p.n))
                    if t is not None:
                        worst = max(worst, tile_bytes(p, t))
            return worst

        # Crossover and engine alternatives — only those that actually
        # change the compiled program are worth carrying.
        fms = [(FUSED_MAX, DIRECT_MAX)]
        for fm in (FUSED_MAX // 2, FUSED_MAX // 4):
            if fm <= DIRECT_MAX:
                continue
            # A smaller crossover can push a tall image's column program
            # past the strip-mined gate — skip such alternates outright.
            if n2 is not None and not plan_lib.joint2d_supported(n2, fm):
                continue
            if build(fm).passes != build(FUSED_MAX).passes:
                fms.append((fm, DIRECT_MAX))
        for dm in (DIRECT_MAX // 2, DIRECT_MAX // 4):
            if build(FUSED_MAX, dm).passes != build(FUSED_MAX).passes:
                fms.append((FUSED_MAX, dm))
        # Chirp pad-length alternatives for non-pow2 (Bluestein) 1-D specs:
        # the minimal next_pow2(2n-1) pad first, its doubling second (a
        # doubled pad can re-factorise the inner conv more favourably; the
        # model usually prunes it — extra signal bytes — but measurement
        # gets to disagree).
        pads = [None]
        if n2 is None and n & (n - 1):
            m0 = limits.bluestein_pad(n)
            pads = [m0, 2 * m0]
        cands, seen = [], set()
        for pad in pads:
            for fm, dm in fms:
                for chunk_shift, tile_shift in ((0, 0), (1, 0), (2, 0), (0, 1)):
                    cfg = config_for(fm, chunk_shift, tile_shift, dm, pad)
                    sig = json.dumps(cfg, sort_keys=True)
                    if sig in seen:
                        continue
                    seen.add(sig)
                    cands.append((cfg, modeled(fm, dm, pad), vmem_of(cfg)))

        def measure(config):
            import jax
            import jax.numpy as jnp
            import numpy as np

            plan = build(
                config["fused_max"],
                config.get("direct_max", DIRECT_MAX),
                config.get("bluestein_pad"),
            )
            chunks = {int(k): v for k, v in config["chunks"].items()}
            tiles = {int(k): v for k, v in config["batch_tiles"].items()}
            b = spec.batch_hint or 2
            rng = np.random.default_rng(0)
            shape = (b, n2, n) if n2 is not None else (b, n)
            xr = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            if gpu:
                from repro.kernels import fft_gpu

                fn = jax.jit(
                    lambda a: fft_gpu.execute_plan_gpu(a, a, plan, batch_tiles=tiles)
                )
            else:
                from repro.kernels import ops as kernel_ops

                fn = jax.jit(
                    lambda a: kernel_ops.execute_plan(
                        a, a, plan, batch_tiles=tiles, chunks=chunks
                    )
                )
            return _time(lambda: fn(xr))

        size = f"n={n}" + (f",n2={n2}" if n2 is not None else "")
        key = (
            f"{backend_name}|plan|{spec.kind}|{size}|"
            f"batch={spec.batch_hint or 0}"
        )
        return cls("plan", key, cands, measure, budget=budget)

    @classmethod
    def for_backend(cls, spec, platform: str):
        """The pallas↔xla backend crossover for one 1-D complex spec on a
        GPU-class device — the registry's negotiation picks the Triton-shaped
        backend wherever it prefers the platform; this space decides whether
        that is actually a win *for this spec on this device*.

        Modeled costs are global-memory bytes: the claimed pass program's
        account (:func:`repro.analysis.roofline.gpu_program_report` — fused
        leaves touch the signal once, unclaimed passes pay the fallback's
        transposes) against the plain-XLA four-step account
        (:func:`repro.analysis.roofline.xla_gpu_fft_bytes` — per level, two
        GEMM round trips + twiddle + transpose).  ``tune="measure"`` times
        both backends' planned calls and caches the winner per device_kind.
        The pallas_gpu candidate leads, so modeled ties keep the negotiated
        pick.
        """
        from repro.analysis import roofline as rl
        from repro.core import limits, plan as plan_lib
        from repro.kernels import fft_gpu

        n = spec.n
        batch = spec.batch_hint or 1
        fft_plan = plan_lib.plan_fft(n)
        gpu_rep = rl.gpu_program_report(
            fft_plan.passes, fft_gpu.gpu_claims, batch=batch
        )
        cands = [
            ({"backend": "pallas_gpu"}, gpu_rep["modeled_global_bytes"], 0),
            ({"backend": "xla"}, rl.xla_gpu_fft_bytes(n, batch), 0),
        ]

        def measure(config):
            import jax
            import jax.numpy as jnp
            import numpy as np
            from repro.core import fft as F  # lazy: avoids cycle

            planned = F.plan(spec, backend=config["backend"], tune="off")
            rng = np.random.default_rng(0)
            b = spec.batch_hint or 2
            xr = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
            fn = jax.jit(lambda a: planned.apply_planes(a, a))
            return _time(lambda: fn(xr))

        key = (
            f"{platform}|backend_xover|{spec.kind}|n={n}|"
            f"batch={spec.batch_hint or 0}"
        )
        return cls(
            "backend_xover", key, cands, measure, budget=limits.memory_budget()
        )

    @classmethod
    def for_pencil(
        cls,
        n: int,
        d: int,
        batch: int = 1,
        backend: Optional[str] = None,
        natural_order: bool = True,
    ):
        """The distributed pencil FFT's decisions, as ONE joint space:
        ``pencil_factors`` balance (every power-of-two n1·n2 = n with both
        factors divisible by ``d``), the all-to-all chunk count ``K`` the
        two inner transposes are strip-mined into (K | q, so every chunk
        is a whole number of columns per device), and whether the
        split-complex pair is packed into one stacked collective per
        transpose — the distributed analogue of the rfft even/odd packing,
        halving the collective count for the same wire bytes.

        Candidate costs are :func:`repro.analysis.roofline.pencil_report`
        ``modeled_s`` — *seconds*, not HBM bytes, because this decision
        trades interconnect time against local HBM time and only a common
        unit can rank them.  ``prune_candidates`` is unit-agnostic (it
        compares scalars), so the same pruning applies.

        This space deliberately has **no measure_fn**: the pencil path runs
        inside ``shard_map`` across the hosts of a multi-process mesh, and
        a per-host measurement (or cache hit) could pick different configs
        on different hosts and desynchronize the SPMD program — the
        ``pconv_os_sharded`` precedent.  :func:`pencil_config` therefore
        never measures and never touches the persistent cache.
        """
        from repro.analysis import roofline as rl
        from repro.core import distributed as dist  # lazy: avoids cycle
        from repro.core import plan as plan_lib

        base = dist.pencil_factors(n, d)
        splits = []
        n1 = 1
        while n1 <= n:
            n2 = n // n1
            if n1 * n2 == n and n1 % d == 0 and n2 % d == 0:
                splits.append((n1, n2))
            n1 *= 2
        if base in splits:  # heuristic (balanced) factorization first
            splits.remove(base)
        splits.insert(0, base)

        def vmem_of(n1, n2):
            worst = 0
            for m in (n1, n2):
                for leaf in plan_lib.plan_fft(m).leaf_passes:
                    worst = max(
                        worst,
                        plan_lib.vmem_bytes(leaf, plan_lib.pick_batch_tile(leaf)),
                    )
            return worst

        cands = []
        for n1, n2 in splits:
            q = n2 // d
            vmem = vmem_of(n1, n2)
            for pack in (True, False):
                for K in (1, 2, 4, 8):
                    if K > 1 and (not pack or K > q or q % K):
                        continue
                    rep = rl.pencil_report(
                        n, d, batch,
                        n1=n1, n2=n2, pack=pack, chunks=K,
                        natural_order=natural_order,
                    )
                    cfg = {"n1": n1, "n2": n2, "pack": pack, "a2a_chunks": K}
                    cands.append((cfg, rep["modeled_s"], vmem))
        # Heuristic-first convention: (balanced, packed, K=1) leads so
        # modeled ties keep the simplest schedule.
        cands.sort(
            key=lambda c: (
                (c[0]["n1"], c[0]["n2"]) != base,
                not c[0]["pack"],
                c[0]["a2a_chunks"],
            )
        )
        key = (
            f"{backend or 'auto'}|pencil|n={n},d={d},batch={batch},"
            f"natural={int(natural_order)}"
        )
        return cls("pencil", key, cands, measure_fn=None)

    # -- decision ----------------------------------------------------------

    def decide(self, mode: str) -> dict:
        """Run the tuner's decision procedure at ``mode``; returns a config.

        off     → the fixed heuristic (first candidate), no cache traffic.
        model   → roofline-pruned modeled minimum; cached.
        measure → cache hit returns instantly; otherwise time the pruned
                  survivors — the fixed heuristic always among them, so the
                  measured winner is never slower than the heuristic — and
                  cache the winner.  A ``model``-mode cache entry is
                  upgraded (re-measured) the first time measure runs.
        """
        from repro.analysis.roofline import prune_candidates

        if mode == "off":
            return self.candidates[0][0]
        key = f"{device_key()}|{self.key}"
        hit = cache.get(key)
        if hit is not None and (mode == "model" or hit.get("mode") == "measure"):
            return hit["config"]
        survivors = prune_candidates(
            self.candidates, tol=PRUNE_TOL, vmem_budget=self.budget
        )
        if mode == "measure" and self.measure_fn is not None:
            default = self.candidates[0]
            if all(s is not default for s in survivors):
                # The model may prune the fixed heuristic; measurement must
                # still beat it on the clock, not just on modeled bytes.
                survivors = [default] + survivors
            times = [float("inf")] * len(survivors)
            for _round in range(MEASURE_ROUNDS):
                for i, (config, _bytes, _vmem) in enumerate(survivors):
                    times[i] = min(times[i], self.measure_fn(config))
                    _MEASURE_LOG.append(
                        (self.decision, key, json.dumps(config, sort_keys=True))
                    )
            best = min(range(len(survivors)), key=times.__getitem__)
            pick = survivors[best][0]
            t_default = next(
                (times[i] for i, s in enumerate(survivors) if s is default), None
            )
            if t_default is not None and t_default <= times[best] * (1 + DEFAULT_MARGIN):
                pick = default[0]  # within noise of the heuristic: keep it
        else:
            pick = survivors[0][0]
            mode = "model"
        cache.put(key, {"config": pick, "mode": mode})
        return pick


# ---------------------------------------------------------------------------
# Decision entry points (what plan() / the conv engines call)
# ---------------------------------------------------------------------------


def tuned_block(
    L: int,
    Lh: int,
    batch: int = 1,
    backend: Optional[str] = None,
    tune: Optional[str] = None,
    chunk: Optional[int] = None,
) -> int:
    """The overlap-save block size for a ``(batch, L) ⊛ (Lh,)`` convolution
    under the resolved tune mode (``off`` → the ``OS_FACTOR`` heuristic).
    ``chunk`` keys the decision (and its measurement) to a streaming call
    grain — see :meth:`TuningSpace.for_os_block`."""
    mode = resolve_mode(tune)
    space = TuningSpace.for_os_block(L, Lh, batch, backend, chunk=chunk)
    return int(space.decide(mode)["block"])


def modeled_block(
    L: int,
    Lh: int,
    batch: int = 1,
    backend: Optional[str] = None,
    chunk: Optional[int] = None,
) -> int:
    """The pure roofline block pick, bypassing cache AND measurement: a
    deterministic function of the shape alone.  SPMD callers
    (:func:`repro.core.distributed.pconv_os_sharded`) use this so every
    host of a multi-process mesh derives the identical block — a per-host
    cache hit or measurement could diverge and desynchronize the
    ``shard_map`` program's shapes.  ``chunk`` keys the decision to a
    streaming call grain exactly as :func:`tuned_block`'s does
    (:class:`~repro.core.overlap.StreamingConv.chunk_hint` under
    sharding), still cache-free."""
    from repro.analysis.roofline import prune_candidates

    space = TuningSpace.for_os_block(L, Lh, batch, backend, chunk=chunk)
    return int(prune_candidates(space.candidates, tol=PRUNE_TOL)[0][0]["block"])


def pencil_config(
    n: int,
    d: int,
    batch: int = 1,
    backend: Optional[str] = None,
    tune: Optional[str] = None,
    natural_order: bool = True,
) -> dict:
    """The distributed pencil FFT's tuned decisions — factor balance, a2a
    chunk count K, split-complex packing — for a length-``n`` transform
    over ``d`` devices.

    CACHE-FREE AND MEASUREMENT-FREE BY CONSTRUCTION: the pick is a pure
    function of ``(n, d, batch, backend, mode)`` so every host of a
    multi-process SPMD mesh derives the identical config with no cache
    file and no on-device timing (``measure_log()`` stays empty).
    ``tune="measure"`` therefore clamps to the modeled pick here — to
    deviate, pass explicit overrides (``factors=``/``chunks=``/``pack=``)
    to :func:`repro.core.distributed.plan_pencil` on every host.

    ``"off"`` is the historical schedule: balanced factors, serial
    transposes (K=1) — packed, since stacking the pair is a pure win the
    satellite made unconditional.
    """
    from repro.analysis.roofline import prune_candidates

    mode = resolve_mode(tune)
    if d <= 1:
        from repro.core import distributed as dist  # lazy: avoids cycle

        n1, n2 = dist.pencil_factors(n, max(d, 1))
        return {"n1": n1, "n2": n2, "pack": True, "a2a_chunks": 1}
    space = TuningSpace.for_pencil(n, d, batch, backend, natural_order)
    if mode == "off":
        return space.candidates[0][0]
    return dict(prune_candidates(space.candidates, tol=PRUNE_TOL)[0][0])


def plan_config(spec, backend_name: str, tune: Optional[str] = None) -> Optional[dict]:
    """The tuned plan config for ``spec`` on ``backend_name`` (None for
    ``off`` — all heuristics — and for backends that do not consume the
    pass program's grid decomposition)."""
    mode = resolve_mode(tune)
    if mode == "off":
        return None
    if backend_name == "pallas_gpu":
        # The Triton-shaped executor is 1-D only (2-D specs compose per-axis
        # child plans, which re-enter here with their 1-D specs).
        if getattr(spec, "n2", None) is not None:
            return None
    elif backend_name != "pallas":
        # Only the pallas executors consume chunks/tiles; other backends
        # re-derive their own schedule, so there is nothing to tune yet.
        return None
    space = TuningSpace.for_plan(spec, backend_name)
    return space.decide(mode)


def backend_pick(spec, platform: str, tune: Optional[str] = None) -> Optional[str]:
    """The tuned pallas↔xla crossover pick for a plan whose negotiated
    backend carries per-pass claims (i.e. ``pallas_gpu``), or ``None`` to
    keep the negotiated backend.

    ``off`` never overrides (no cache traffic, no measurement); ``model``
    compares the claimed program's modeled global-memory bytes against the
    plain-XLA four-step account; ``measure`` times both planned calls once
    per ``(device_kind, spec)`` and caches the winner.  Only 1-D complex
    specs participate — everything else keeps negotiation's answer.
    """
    mode = resolve_mode(tune)
    if mode == "off":
        return None
    if spec.kind not in ("fft", "ifft") or getattr(spec, "n2", None) is not None:
        return None
    if spec.n & (spec.n - 1):
        # Non-pow2 (Bluestein) specs keep negotiation's answer: the XLA
        # yardstick models the pow2 four-step, not the chirp-conv program.
        return None
    space = TuningSpace.for_backend(spec, platform)
    return str(space.decide(mode)["backend"])
