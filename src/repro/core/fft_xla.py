"""Pure-JAX (XLA) FFT backends implementing the paper's algorithm.

Two formulations, both operating on split real/imag float32 planes over the
*last* axis:

* :func:`stockham_fft` — the paper's butterfly formulation (radix-2 Stockham
  autosort; no bit-reversal pass, contiguous loads at every stage — the
  vector-unit analogue of the paper's bank-conflict-free layout).  This is the
  reference algorithm and the CPU-friendly backend.
* :func:`four_step_fft` — Bailey's four-step ``(W1·X ⊙ T)·W2`` with the same
  factorisation policy as the Pallas kernels (``core.plan``).  On TPU the two
  GEMMs land on the MXU; on CPU this is also what the benchmark harness times
  as "our FFT" (same arithmetic as the fused kernel, one materialised pass per
  plan level).

Everything is shape-polymorphic over leading batch dims and jit-friendly
(all control flow is static on the transform length).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core import twiddle as tw

Planes = Tuple[jax.Array, jax.Array]

__all__ = [
    "stockham_fft",
    "four_step_fft",
    "bluestein_fft",
    "direct_dft",
    "cmul",
    "cmatmul",
    "rfft_recomb",
    "irfft_recomb",
]


def cmul(ar, ai, br, bi) -> Planes:
    """Elementwise complex multiply on split planes."""
    return ar * br - ai * bi, ar * bi + ai * br


def rfft_recomb(zr, zi, wr, wi) -> Planes:
    """Hermitian recombination of the rfft even/odd packing (forward).

    X[k] = E[k] + w[k]·O[k] for k < m, X[m] = E[0] - O[0], with
    E/O extracted from the packed m-point spectrum Z via the Z[(m-k) % m]
    reversal (flip+roll, no gather).  Pure jnp on the last axis — callable
    traced (the xla/stockham backends) or from inside a Pallas kernel body
    (``kernels.pencil.rfft_recomb_call``), so both tiers share one epilogue.
    ``wr/wi``: e^{∓2πik/n} phasors, length ≥ m.
    """
    zr_f = jnp.roll(jnp.flip(zr, -1), 1, -1)  # Z[(m - k) % m]
    zi_f = jnp.roll(jnp.flip(zi, -1), 1, -1)
    m = zr.shape[-1]
    er, ei = (zr + zr_f) * 0.5, (zi - zi_f) * 0.5
    or_, oi = (zi + zi_f) * 0.5, (zr_f - zr) * 0.5
    wr_m, wi_m = wr[..., :m], wi[..., :m]
    tr, ti = cmul(or_, oi, wr_m, wi_m)
    xr_out = jnp.concatenate([er + tr, er[..., 0:1] - or_[..., 0:1]], axis=-1)
    xi_out = jnp.concatenate([ei + ti, ei[..., 0:1] - oi[..., 0:1]], axis=-1)
    return xr_out, xi_out


def irfft_recomb(xr, xi, wr, wi) -> Planes:
    """Inverse of :func:`rfft_recomb`: n//2+1 bins → packed m-point spectrum.

    ``wr/wi``: e^{+2πik/n} phasors, length ≥ m.
    """
    m = xr.shape[-1] - 1
    xr_k, xi_k = xr[..., :m], xi[..., :m]
    xr_f = jnp.flip(xr[..., 1:], -1)  # X[m - k], k ∈ [0, m)
    xi_f = jnp.flip(xi[..., 1:], -1)
    er, ei = (xr_k + xr_f) * 0.5, (xi_k - xi_f) * 0.5
    dr, di = (xr_k - xr_f) * 0.5, (xi_k + xi_f) * 0.5
    wr_m, wi_m = wr[..., :m], wi[..., :m]
    or_, oi = cmul(dr, di, wr_m, wi_m)
    return er - oi, ei + or_


def cmatmul(ar, ai, br, bi, precision=jax.lax.Precision.HIGHEST) -> Planes:
    """Complex matmul on split planes: (ar+i·ai) @ (br+i·bi).

    3-multiplication Karatsuba variant: saves one real GEMM out of four —
    the matmul-form analogue of the paper shaving redundant twiddle work.
    k1 = br·(ar+ai); k2 = ar·(bi−br); k3 = ai·(br+bi)
    re = k1 − k3; im = k1 + k2.
    """
    dot = functools.partial(jnp.matmul, precision=precision)
    k1 = dot(ar + ai, br)
    k2 = dot(ar, bi - br)
    k3 = dot(ai, br + bi)
    return k1 - k3, k1 + k2


def _as_planes(x) -> Planes:
    if isinstance(x, (tuple, list)):
        xr, xi = x
        return jnp.asarray(xr, jnp.float32), jnp.asarray(xi, jnp.float32)
    x = jnp.asarray(x)
    if jnp.iscomplexobj(x):
        return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)
    return x.astype(jnp.float32), jnp.zeros_like(x, jnp.float32)


def stockham_fft(xr, xi, *, inverse: bool = False) -> Planes:
    """Radix-2 Stockham autosort FFT over the last axis (split planes)."""
    n = xr.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length must be a power of two, got {n}")
    if n == 1:
        return xr, xi
    batch = xr.shape[:-1]
    l, m = n // 2, 1
    while l >= 1:
        # View as (..., 2l, m): rows j and j+l form a butterfly pair.
        vr = xr.reshape(*batch, 2 * l, m)
        vi = xi.reshape(*batch, 2 * l, m)
        x0r, x1r = vr[..., :l, :], vr[..., l:, :]
        x0i, x1i = vi[..., :l, :], vi[..., l:, :]
        wr_np, wi_np = tw.stage_twiddle(l, inverse)
        wr = jnp.asarray(wr_np)[:, None]
        wi = jnp.asarray(wi_np)[:, None]
        s0r, s0i = x0r + x1r, x0i + x1i
        dr, di = x0r - x1r, x0i - x1i
        s1r, s1i = cmul(dr, di, wr, wi)
        # y[(2j+p)·m + k] ≡ (l, 2, m) row-major — Stockham auto-sorts.
        yr = jnp.stack([s0r, s1r], axis=-2)
        yi = jnp.stack([s0i, s1i], axis=-2)
        xr = yr.reshape(*batch, n)
        xi = yi.reshape(*batch, n)
        l //= 2
        m *= 2
    if inverse:
        inv = np.float32(1.0 / n)
        xr, xi = xr * inv, xi * inv
    return xr, xi


def direct_dft(xr, xi, *, inverse: bool = False, _scale: bool = True) -> Planes:
    """Whole-transform DFT matmul (the N ≤ DIRECT_MAX leaf)."""
    n = xr.shape[-1]
    wr_np, wi_np = tw.dft_matrix(n, inverse)
    wr, wi = jnp.asarray(wr_np), jnp.asarray(wi_np)
    yr, yi = cmatmul(xr, xi, wr, wi)
    if inverse and _scale:
        yr, yi = yr / n, yi / n
    return yr, yi


def _col_dft(xr, xi, n1: int, inverse: bool) -> Planes:
    """Direct DFT over axis -2 as a single contraction — no materialised
    transpose (XLA streams the dot in either layout).  §Perf: replacing the
    swapaxes+row-leaf pair with this cut the split-level HBM passes ~2×."""
    wr_np, wi_np = tw.dft_matrix(n1, inverse)
    wr, wi = jnp.asarray(wr_np), jnp.asarray(wi_np)
    dot = functools.partial(jnp.einsum, "jk,...jm->...km", precision=jax.lax.Precision.HIGHEST)
    k1 = dot(wr, xr + xi)
    k2 = dot(wi - wr, xr)
    k3 = dot(wr + wi, xi)
    return k1 - k3, k1 + k2


def _four_step_level(xr, xi, n1: int, n2: int, inverse: bool, leaf_fn) -> Planes:
    """One split level: columns(n1) → twiddle → rows(n2) → transpose.

    x: (..., n1, n2) viewed row-major from a length n1·n2 signal.
    Output: (..., n2, n1) so that flattening yields natural order
    (X[k1 + n1·k2] lives at [k2, k1]).
    """
    batch = xr.shape[:-2]
    # --- column transforms: FFT over axis -2 (length n1).
    if n1 <= plan_lib.DIRECT_MAX:
        # transpose-free: contract the column axis directly.
        xr, xi = _col_dft(xr, xi, n1, inverse)
        tr_np, ti_np = (
            tw.twiddle_grid(n1, n2, inverse)
            if n1 * n2 <= plan_lib.FUSED_MAX
            else (None, None)
        )
        if tr_np is not None:
            tr, ti = jnp.asarray(tr_np), jnp.asarray(ti_np)  # (n1, n2)
        else:
            tr, ti = tw.traced_twiddle(n1, n2, inverse)
        xr, xi = cmul(xr, xi, tr, ti)
    else:
        # recursive leaf needs a contiguous last axis: transpose, work,
        # apply the twiddle in transposed layout, transpose back.
        xr = jnp.swapaxes(xr, -1, -2)  # (..., n2, n1)
        xi = jnp.swapaxes(xi, -1, -2)
        xr, xi = leaf_fn(xr, xi, n1, inverse)
        if n1 * n2 <= plan_lib.FUSED_MAX:
            tr_np, ti_np = tw.twiddle_grid(n1, n2, inverse)
            tr = jnp.asarray(tr_np).T  # (n2, n1)
            ti = jnp.asarray(ti_np).T
        else:
            tr, ti = tw.traced_twiddle(n2, n1, inverse)  # already (n2, n1)
        xr, xi = cmul(xr, xi, tr, ti)
        xr = jnp.swapaxes(xr, -1, -2)  # (..., n1, n2)
        xi = jnp.swapaxes(xi, -1, -2)
    # --- row transforms: FFT over n2 (contiguous last axis).
    xr, xi = leaf_fn(xr, xi, n2, inverse)
    # --- natural order: X[k1 + n1 k2] = C[k1, k2] → flatten C^T.
    xr = jnp.swapaxes(xr, -1, -2)  # (..., n2, n1)
    xi = jnp.swapaxes(xi, -1, -2)
    return xr.reshape(*batch, n1 * n2), xi.reshape(*batch, n1 * n2)


def _leaf_dispatch(xr, xi, n: int, inverse: bool) -> Planes:
    """Transform the last axis of length n, recursing per the plan."""
    if n == 1:
        return xr, xi
    if n <= plan_lib.DIRECT_MAX:
        return direct_dft(xr, xi, inverse=inverse, _scale=False)
    p = plan_lib.plan_fft(n)
    if not p.levels:  # fused regime: single four-step level
        n1, n2 = plan_lib.balanced_split(n)
    else:
        n1, n2 = p.levels[0]
    batch = xr.shape[:-1]
    xr = xr.reshape(*batch, n1, n2)
    xi = xi.reshape(*batch, n1, n2)

    def leaf(ar, ai, m, inv):
        return _leaf_dispatch(ar, ai, m, inv)

    return _four_step_level(xr, xi, n1, n2, inverse, leaf)


def four_step_fft(xr, xi, *, inverse: bool = False) -> Planes:
    """Four-step FFT over the last axis, following ``core.plan`` exactly."""
    n = xr.shape[-1]
    if n & (n - 1):
        raise ValueError(f"length must be a power of two, got {n}")
    yr, yi = _leaf_dispatch(xr, xi, n, inverse)
    if inverse:
        inv = np.float32(1.0 / n)
        yr, yi = yr * inv, yi * inv
    return yr, yi


def bluestein_fft(
    xr, xi, *, inverse: bool = False, pad: int | None = None
) -> Planes:
    """Arbitrary-length DFT over the last axis via Bluestein's chirp conv.

    The traced (pure-XLA) realization of the same pipeline
    ``core.plan.compile_bluestein`` schedules for the Pallas kernels:
    chirp pre-multiply → zero-pad to ``M = next_pow2(2n−1)`` → forward
    :func:`four_step_fft` at M → multiply by the host-cached chirp spectrum
    B̂ → inverse four-step at M (its 1/M folded by the engine) → slice to
    ``n`` → chirp post-multiply (1/n folded for ``inverse``).  All LUTs come
    from the shared :mod:`repro.core.twiddle` caches, so the traced path
    and the kernels intern one set of chirp tables per (n, pad, direction).
    """
    n = xr.shape[-1]
    if not (n & (n - 1)):
        return four_step_fft(xr, xi, inverse=inverse)
    from repro.core.limits import bluestein_pad

    m_pad = bluestein_pad(n) if pad is None else pad
    ar, ai = tw.bluestein_chirp(n, inverse)
    br, bi = tw.bluestein_spectrum(n, m_pad, inverse)
    pr, pi = tw.bluestein_postchirp(n, inverse)
    yr, yi = cmul(xr, xi, jnp.asarray(ar), jnp.asarray(ai))
    widths = [(0, 0)] * (yr.ndim - 1) + [(0, m_pad - n)]
    yr, yi = jnp.pad(yr, widths), jnp.pad(yi, widths)
    fr, fi = four_step_fft(yr, yi)
    fr, fi = cmul(fr, fi, jnp.asarray(br), jnp.asarray(bi))
    gr, gi = four_step_fft(fr, fi, inverse=True)
    gr, gi = gr[..., :n], gi[..., :n]
    return cmul(gr, gi, jnp.asarray(pr), jnp.asarray(pi))
