"""FFT execution planning — the paper's kernel-call schedule, TPU-sized.

§2.3.2/§3 of the paper fix the number of *global-memory round trips* by data
volume: one kernel call for N ≤ 1024 (whole transform in shared memory), two
for N ≤ 32768, three or more beyond.  Here the fast tier is VMEM (~16 MB) and
the slow tier is HBM, so the same schedule becomes:

* ``direct``   — N ≤ DIRECT_MAX: one ``pallas_call``, a single DFT matmul
  (the whole signal, the DFT matrix and the result co-resident in VMEM).
* ``fused4``   — N ≤ FUSED_MAX: one ``pallas_call`` running Bailey's four-step
  ``(W_{N1}·X ⊙ T)·W_{N2}`` entirely in VMEM → **one** HBM round trip.
* ``split``    — larger N: factor N = N_outer · N_inner recursively; each
  level adds one HBM re-tiling pass, mirroring the paper's 2-call / 3-call
  regimes.

The plan is pure metadata (hashable, cached) so backends — the Pallas kernels,
the pure-XLA fallback, and the distributed pencil driver — share one
factorisation policy and the tests can assert the schedule itself.
"""

from __future__ import annotations

import dataclasses
import functools
import math

__all__ = [
    "DIRECT_MAX",
    "FUSED_MAX",
    "FFTPlan",
    "Pass",
    "plan_fft",
    "balanced_split",
    "vmem_bytes",
]

#: Largest N executed as a single direct DFT matmul (one (B,N)x(N,N) GEMM).
DIRECT_MAX = 1024

#: Largest N executed by the fused four-step kernel in one HBM round trip.
#: 65536 = 256·256 keeps the per-block working set (signal tile + two DFT
#: matrices + twiddle grid + scratch) under ~6 MB of VMEM — see vmem_bytes().
FUSED_MAX = 65536


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def balanced_split(n: int, cap: int | None = None) -> tuple[int, int]:
    """Split n = n1 * n2, powers of two, as square as possible, n1 >= n2.

    If ``cap`` is given, n2 is forced ≤ cap (used by the recursive splitter so
    the inner factor always lands in the fused-kernel regime).
    """
    if not _is_pow2(n):
        raise ValueError(f"FFT length must be a power of two, got {n}")
    lg = n.bit_length() - 1
    lg1 = (lg + 1) // 2
    n1, n2 = 1 << lg1, 1 << (lg - lg1)
    if cap is not None:
        while n2 > cap:
            n2 //= 2
            n1 *= 2
    return n1, n2


@dataclasses.dataclass(frozen=True)
class Pass:
    """One HBM round trip.

    kind: 'direct' | 'fused4' — what the single pallas_call does.
    n:    transform length handled by this pass.
    n1/n2: four-step factors (fused4 only; n1*n2 == n).
    """

    kind: str
    n: int
    n1: int = 0
    n2: int = 0


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """Factorisation of a length-``n`` transform into HBM round trips.

    ``levels`` lists the outer→inner split factors; ``leaf`` is the pass that
    executes each innermost transform.  ``hbm_round_trips`` is the figure the
    paper tabulates as "number of kernel calls".
    """

    n: int
    levels: tuple[tuple[int, int], ...]  # ((n_outer, n_inner), ...) recursion
    leaf_passes: tuple[Pass, ...]        # one leaf pass per distinct length

    @property
    def hbm_round_trips(self) -> int:
        # Each split level re-tiles through HBM once between the two child
        # transforms; a leaf is one trip.  For L levels of splitting the
        # total is L + 1 (1 → direct/fused, 2 → one split, ...).
        return len(self.levels) + 1

    @property
    def kernel_calls(self) -> int:
        """Paper Table-1 terminology: number of distinct kernel launches."""
        return self.hbm_round_trips

    def level_for(self, m: int) -> tuple[int, int] | None:
        """The (n_outer, n_inner) split for a length-``m`` sub-transform, or
        None when ``m`` is a leaf.  Split products are strictly decreasing
        (n, outer0, outer1, ...) so the lookup is unambiguous."""
        for n_outer, n_inner in self.levels:
            if n_outer * n_inner == m:
                return n_outer, n_inner
        return None

    def leaf_pass(self, m: int) -> Pass:
        """The leaf :class:`Pass` executing a length-``m`` sub-transform."""
        for p in self.leaf_passes:
            if p.n == m:
                return p
        raise KeyError(f"length {m} is not a leaf of the plan for n={self.n}")


def _leaf_pass(n: int) -> Pass:
    if n <= DIRECT_MAX:
        return Pass(kind="direct", n=n)
    n1, n2 = balanced_split(n)
    return Pass(kind="fused4", n=n, n1=n1, n2=n2)


@functools.lru_cache(maxsize=512)
def plan_fft(n: int, fused_max: int = FUSED_MAX) -> FFTPlan:
    """Plan a length-``n`` power-of-two complex FFT."""
    if not _is_pow2(n):
        raise ValueError(f"FFT length must be a power of two, got {n}")
    levels: list[tuple[int, int]] = []
    m = n
    while m > fused_max:
        # Keep the inner factor in the fused regime, outer as small as
        # possible: each level's twiddle grid and transpose cost scale with
        # the outer factor.
        n_outer, n_inner = balanced_split(m, cap=fused_max)
        levels.append((n_outer, n_inner))
        m = n_outer  # the outer transform may itself need splitting
        if n_inner <= fused_max and n_outer <= fused_max:
            break
    # Distinct leaf lengths (outer and inner of the last level, or n itself).
    if levels:
        leaf_lengths = {levels[-1][0], levels[-1][1]}
        for i in range(len(levels) - 1):
            leaf_lengths.add(levels[i][1])
    else:
        leaf_lengths = {n}
    leaves = tuple(sorted((_leaf_pass(m) for m in leaf_lengths), key=lambda p: p.n))
    return FFTPlan(n=n, levels=tuple(levels), leaf_passes=leaves)


def vmem_bytes(p: Pass, batch_tile: int) -> int:
    """Estimated VMEM working set of one grid step of a leaf pass.

    Split-complex float32 everywhere: signal tile in + out, DFT matrices,
    twiddle grid, one intermediate.  Used by the kernel launcher to pick the
    batch tile so the block fits comfortably in ~16 MB of VMEM (we budget
    half of it, leaving room for Mosaic's double buffering).
    """
    f32 = 4
    if p.kind == "direct":
        sig = batch_tile * p.n * 2 * f32
        mats = p.n * p.n * 2 * f32
        return 2 * sig + mats
    sig = batch_tile * p.n * 2 * f32             # x tile (= n1*n2 grid)
    mats = (p.n1 * p.n1 + p.n2 * p.n2) * 2 * f32  # W1, W2
    tw = p.n1 * p.n2 * 2 * f32                    # twiddle grid
    return 3 * sig + mats + tw                    # in, intermediate, out


def pick_batch_tile(p: Pass, budget: int = 8 * 1024 * 1024) -> int:
    """Largest power-of-two batch tile whose working set fits the budget."""
    bt = 512
    while bt > 1 and vmem_bytes(p, bt) > budget:
        bt //= 2
    return bt


def describe(n: int) -> str:
    """Human-readable schedule, e.g. for logging/EXPERIMENTS.md."""
    p = plan_fft(n)
    parts = [f"N={n}: {p.hbm_round_trips} HBM round trip(s)"]
    m = n
    for no, ni in p.levels:
        parts.append(f"split {m} -> {no} x {ni}")
        m = no
    for leaf in p.leaf_passes:
        if leaf.kind == "direct":
            parts.append(f"leaf direct DFT n={leaf.n}")
        else:
            parts.append(f"leaf fused four-step n={leaf.n} ({leaf.n1} x {leaf.n2})")
    return "; ".join(parts)
