"""FFT execution planning — the paper's kernel-call schedule, TPU-sized.

§2.3.2/§3 of the paper fix the number of *global-memory round trips* by data
volume: one kernel call for N ≤ 1024 (whole transform in shared memory), two
for N ≤ 32768, three or more beyond.  Here the fast tier is VMEM (~16 MB) and
the slow tier is HBM, so the same schedule becomes:

* ``direct``   — N ≤ DIRECT_MAX: one ``pallas_call``, a single DFT matmul
  (the whole signal, the DFT matrix and the result co-resident in VMEM).
* ``fused4``   — N ≤ FUSED_MAX: one ``pallas_call`` running Bailey's four-step
  ``(W_{N1}·X ⊙ T)·W_{N2}`` entirely in VMEM → **one** HBM round trip.
* ``split``    — larger N: factor N = f₀ · f₁ · … (each factor in the fused
  regime) and execute a **linearized pass program**: one HBM round trip per
  factor, mirroring — and for N ≤ 2³² beating — the paper's 2-call / 3-call
  regimes.

The split regime is compiled down to :attr:`FFTPlan.passes`, an ordered list
of :class:`Pass` records in which **all glue is fused into the kernels**:
each pass carries its input/output pencil views ``(pencils, stride, n)``, the
inter-factor twiddle it must apply as a VMEM epilogue (``twiddle_after``),
and the buffer ``order`` it leaves behind.  The executor
(``repro.kernels.ops.execute_program``) walks this list issuing exactly
``len(passes)`` ``pallas_call``s — no standalone HBM transpose, reshape
re-tiling, or twiddle ``cmul`` passes in between, which is the paper's §2.3.2
call-count discipline made literal.

Pencil view convention: per batch row, the flat length-N buffer decomposes
into ``pencils`` signals of length ``n``; pencil ``p`` occupies flat offsets
``off(p) + stride·t`` for ``t ∈ [0, n)`` with
``off(p) = (p // stride)·(stride·n) + (p % stride)``.  ``stride == 1`` is
contiguous rows; ``stride == pencils`` is the interleaved-column view of the
first factor.  The natural-order output of a two-factor program is itself a
column view — which is why the final reorder folds into the last kernel's
strided write instead of costing an HBM transpose.

The plan is pure metadata (hashable, cached) so backends — the Pallas kernels,
the pure-XLA fallback, and the distributed pencil driver — share one
factorisation policy and the tests can assert the schedule itself.
"""

from __future__ import annotations

import dataclasses
import functools
import math

from repro.core import faults
from repro.core.limits import (
    DIRECT_MAX,
    FUSED_MAX,
    VMEM_BUDGET,
    bluestein_pad,
    memory_budget,
)

__all__ = [
    "DIRECT_MAX",
    "FUSED_MAX",
    "VMEM_BUDGET",
    "FFTPlan",
    "Pass",
    "plan_fft",
    "plan_fft2",
    "compile_passes",
    "compile_passes2d",
    "compile_bluestein",
    "joint2d_supported",
    "program_factors",
    "balanced_split",
    "vmem_bytes",
    "pass_hbm_bytes",
    "pass_other",
    "program_hbm_bytes",
    "pick_pass_chunk",
    "describe",
    "describe_program",
]

# DIRECT_MAX / FUSED_MAX / VMEM_BUDGET are defined in repro.core.limits (the
# single source for every regime threshold) and re-exported here because the
# planner is where the rest of the codebase historically imported them from.


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def balanced_split(n: int, cap: int | None = None) -> tuple[int, int]:
    """Split n = n1 * n2, powers of two, as square as possible, n1 >= n2.

    If ``cap`` is given, n2 is forced ≤ cap (used by the recursive splitter so
    the inner factor always lands in the fused-kernel regime).
    """
    if not _is_pow2(n):
        raise faults.PlanError(f"FFT length must be a power of two, got {n}")
    lg = n.bit_length() - 1
    lg1 = (lg + 1) // 2
    n1, n2 = 1 << lg1, 1 << (lg - lg1)
    if cap is not None:
        while n2 > cap:
            n2 //= 2
            n1 *= 2
    return n1, n2


@dataclasses.dataclass(frozen=True)
class Pass:
    """One HBM round trip of the linearized pass program.

    kind: 'direct' | 'fused4' — the in-VMEM algorithm of the single
          pallas_call — or 'reorder', the digit-reversal relayout pass that
          only programs with ≥ 3 factors (N > 2³²) need for natural order.
    n:    per-pencil transform length handled by this pass.
    n1/n2: four-step factors (fused4 only; n1*n2 == n).
    view_in / view_out:
          ``(pencils, stride, n)`` pencil views of the flat per-row buffer
          (module docstring has the offset convention).  ``view_out`` differs
          from ``view_in`` exactly when the natural-order transpose is fused
          into this pass's strided write.
    twiddle_after:
          ``(n_bins, n_phases)`` — after transforming, bin ``k`` of pencil
          ``p`` is multiplied by ``W_{n_bins·n_phases}^{k·(p % n_phases)}``
          as a VMEM epilogue (None for the last pass).  The grid is a
          host-cached LUT served chunk-by-chunk through a BlockSpec.
    order: buffer ordering this pass leaves behind: 'natural' | 'pencil'.
    axis:  transform axis of a multi-axis (2-D image) program: ``-1`` for
          row passes over the contiguous last axis, ``-2`` for in-place
          strided-column passes down the image's second-to-last axis (views
          are relative to that axis's length; the image width rides along as
          extra pencil columns of the strided kernel).
    """

    kind: str
    n: int
    n1: int = 0
    n2: int = 0
    view_in: tuple = ()
    view_out: tuple = ()
    twiddle_after: tuple | None = None
    order: str = "pencil"
    axis: int = -1
    #: Bluestein chirp-conv leaves only: which piece of the chirp pipeline
    #: this pass executes.  Fused regime: ``"fwd"`` (chirp-pre + zero-pad +
    #: pad-length FFT + ⊙B̂, one call) then ``"inv"`` (pad-length IFFT +
    #: slice + chirp-post, one call).  Split regime (pad > FUSED_MAX):
    #: ``"pre"`` / ``"mul"`` / ``"post"`` elementwise chirp passes
    #: sandwiching the pad length's own compiled pow2 program.  For a
    #: bluestein pass ``n`` is the logical transform length and ``n1`` the
    #: conv pad length M.
    stage: str = ""
    #: Transform-direction override for the passes INSIDE a Bluestein conv:
    #: the inner pad-length FFT/IFFT pair always runs forward-then-inverse
    #: regardless of the outer transform's direction (which only flips the
    #: chirp LUTs).  ``None`` — every non-Bluestein program — defers to the
    #: executor's program-level ``inverse`` flag.
    inverse: bool | None = None


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """Factorisation of a length-``n`` transform into HBM round trips.

    ``passes`` is the compiled, ordered natural-order pass program — the
    HBM round-trip sequence the executor literally issues.  ``levels`` /
    ``leaf_passes`` remain as the recursion-shaped metadata the pure-XLA
    backend and the LUT warm-up still consume.  ``hbm_round_trips`` is the
    figure the paper tabulates as "number of kernel calls".

    ``n2`` marks a multi-axis program: the plan transforms an
    ``(..., n2, n)`` image and ``passes`` mixes ``axis=-1`` row passes with
    ``axis=-2`` column passes (see :func:`compile_passes2d`).
    """

    n: int
    levels: tuple[tuple[int, int], ...]  # ((n_outer, n_inner), ...) recursion
    leaf_passes: tuple[Pass, ...]        # one leaf pass per distinct length
    passes: tuple[Pass, ...] = ()        # linearized natural-order program
    n2: int | None = None                # second-to-last-axis length (2-D)

    @property
    def hbm_round_trips(self) -> int:
        # One HBM round trip per program pass.  Two factors cover every
        # N ≤ 2³² in two trips — one fewer than the paper's 3-call regime,
        # because the inter-factor twiddle and the natural-order transpose
        # are fused into the kernels instead of being standalone passes.
        return len(self.passes)

    @property
    def kernel_calls(self) -> int:
        """Paper Table-1 terminology: number of distinct kernel launches."""
        return self.hbm_round_trips

    def level_for(self, m: int) -> tuple[int, int] | None:
        """The (n_outer, n_inner) split for a length-``m`` sub-transform, or
        None when ``m`` is a leaf.  Split products are strictly decreasing
        (n, outer0, outer1, ...) so the lookup is unambiguous."""
        for n_outer, n_inner in self.levels:
            if n_outer * n_inner == m:
                return n_outer, n_inner
        return None

    def leaf_pass(self, m: int) -> Pass:
        """The leaf :class:`Pass` executing a length-``m`` sub-transform."""
        for p in self.leaf_passes:
            if p.n == m:
                return p
        raise KeyError(f"length {m} is not a leaf of the plan for n={self.n}")


def _leaf_pass(n: int, direct_max: int = DIRECT_MAX) -> Pass:
    """The leaf engine decision: a direct DFT matmul up to ``direct_max``
    (one GEMM, but an n² LUT), the fused four-step beyond (two √n-sized
    GEMMs + twiddle).  ``direct_max`` is the tuner's engine knob — lowering
    it trades the big DFT matrix stream for four-step arithmetic on leaves
    near the boundary.  Lengths below 8 stay direct (a four-step split
    would degenerate)."""
    if n <= max(direct_max, 8):
        return Pass(kind="direct", n=n)
    n1, n2 = balanced_split(n)
    return Pass(kind="fused4", n=n, n1=n1, n2=n2)


def program_factors(n: int, fused_max: int = FUSED_MAX) -> tuple[int, ...]:
    """Factorize n = f₀ · f₁ · … (outer first), every factor ≤ ``fused_max``.

    This is the recursion of the level tree flattened: the same splits, in
    execution order, so the linearized program and the legacy level metadata
    always agree on the factorisation policy.
    """
    if not _is_pow2(n):
        raise faults.PlanError(f"FFT length must be a power of two, got {n}")
    fs: list[int] = []
    m = n
    while m > fused_max:
        n_outer, n_inner = balanced_split(m, cap=fused_max)
        fs.append(n_inner)
        m = n_outer
    fs.append(m)
    fs.reverse()
    return tuple(fs)


@functools.lru_cache(maxsize=512)
def compile_passes(
    n: int,
    fused_max: int = FUSED_MAX,
    order: str = "natural",
    direct_max: int = DIRECT_MAX,
) -> tuple[Pass, ...]:
    """Compile the ordered pass program for a length-``n`` transform.

    One pass per factor.  Pass ``i`` transforms factor ``fᵢ`` over pencils of
    stride ``sᵢ = ∏_{k>i} f_k`` and applies the inter-factor twiddle
    ``W^{kᵢ·(p % sᵢ)}`` as its VMEM epilogue.  With two factors the final
    natural-order transpose is fused into the last pass's strided write
    (its ``view_out`` is the column view of the output buffer); with three
    or more factors (N > 2³²) natural order needs one explicit ``reorder``
    pass, and ``order='pencil'`` skips it for fft→pointwise→ifft pipelines.
    """
    if order not in ("natural", "pencil"):
        raise faults.PlanError(f"order must be 'natural' or 'pencil', got {order!r}")
    if not _is_pow2(n):
        # Non-pow2 lengths compile to the Bluestein chirp-conv program —
        # natural-order by construction (the post-chirp slice IS the
        # output), so the ``order`` request is moot.
        return compile_bluestein(n, None, fused_max, direct_max)
    fs = program_factors(n, fused_max)
    last = len(fs) - 1
    passes: list[Pass] = []
    stride = n
    for i, f in enumerate(fs):
        stride //= f
        leaf = _leaf_pass(f, direct_max)
        view_in = (n // f, stride, f)
        view_out = view_in
        pass_order = "pencil"
        if i == last:
            if order == "natural" and last == 1:
                # Fused natural-order write: out pencil k₀ at offset k₀,
                # stride f₀ — the column view of the output buffer.
                view_out = (fs[0], fs[0], f)
                pass_order = "natural"
            elif last == 0:
                # Single-factor program: the kernel orders internally and
                # program-level pencil layout degenerates to natural.
                pass_order = "natural"
        passes.append(
            Pass(
                kind=leaf.kind,
                n=f,
                n1=leaf.n1,
                n2=leaf.n2,
                view_in=view_in,
                view_out=view_out,
                twiddle_after=None if i == last else (f, stride),
                order=pass_order,
            )
        )
    if order == "natural" and last >= 2:
        # Digit-reversal relayout: only N > FUSED_MAX² programs pay it.
        flat = (1, 1, n)
        passes.append(
            Pass(kind="reorder", n=n, view_in=flat, view_out=flat, order="natural")
        )
    return tuple(passes)


@functools.lru_cache(maxsize=256)
def compile_bluestein(
    n: int,
    pad: int | None = None,
    fused_max: int = FUSED_MAX,
    direct_max: int = DIRECT_MAX,
) -> tuple[Pass, ...]:
    """Compile the Bluestein chirp-conv pass program for a non-pow2 ``n``.

    The transform is one circular convolution at pad length
    ``M = next_pow2(2n−1)`` (or a caller/tuner-chosen larger pow2 ``pad``)
    between the chirp-modulated signal and the conjugate chirp, bracketed
    by elementwise chirp multiplies:

    * ``M ≤ fused_max`` — TWO passes, the §2.3.2 call-count discipline kept:
      ``stage="fwd"`` fuses chirp-pre, the zero-pad and the forward pad-FFT
      ⊙ B̂ into one kernel; ``stage="inv"`` fuses the inverse pad-FFT, the
      slice back to ``n`` and the chirp-post into the second.
    * ``M > fused_max`` — the pad length's own pow2 split program runs the
      conv: ``pre`` → forward program of M → ``mul`` (⊙B̂) → inverse
      program of M → ``post``, with each inner pass's direction pinned via
      :attr:`Pass.inverse` (the outer fft/ifft choice only flips the chirp
      LUTs, never the conv).
    """
    if _is_pow2(n):
        raise faults.PlanError(f"n={n} is a power of two; use compile_passes")
    if n < 2:
        raise faults.PlanError(f"Bluestein lengths start at 2, got {n}")
    m_pad = bluestein_pad(n) if pad is None else pad
    if not _is_pow2(m_pad) or m_pad < 2 * n - 1:
        raise faults.PlanError(
            f"bluestein pad must be a power of two ≥ 2n-1 = {2 * n - 1}, "
            f"got {m_pad}"
        )
    if m_pad <= fused_max:
        return (
            Pass(
                kind="bluestein", n=n, n1=m_pad,
                view_in=(1, 1, n), view_out=(1, 1, m_pad),
                order="natural", stage="fwd",
            ),
            Pass(
                kind="bluestein", n=n, n1=m_pad,
                view_in=(1, 1, m_pad), view_out=(1, 1, n),
                order="natural", stage="inv",
            ),
        )
    inner = compile_passes(m_pad, fused_max, "natural", direct_max)
    if any(p.kind == "reorder" for p in inner):
        raise NotImplementedError(
            f"bluestein pads beyond fused_max² ({fused_max**2}) would need "
            f"a reordered inner program; pad={m_pad}"
        )
    flat_n = (1, 1, n)
    flat_m = (1, 1, m_pad)
    passes = [
        Pass(kind="bluestein", n=n, n1=m_pad, view_in=flat_n,
             view_out=flat_m, order="natural", stage="pre"),
    ]
    passes.extend(dataclasses.replace(p, inverse=False) for p in inner)
    passes.append(
        Pass(kind="bluestein", n=n, n1=m_pad, view_in=flat_m,
             view_out=flat_m, order="natural", stage="mul")
    )
    passes.extend(dataclasses.replace(p, inverse=True) for p in inner)
    passes.append(
        Pass(kind="bluestein", n=n, n1=m_pad, view_in=flat_m,
             view_out=flat_n, order="natural", stage="post")
    )
    return tuple(passes)


def joint2d_supported(n2: int, fused_max: int = FUSED_MAX) -> bool:
    """Whether an ``(..., n2, n)`` image compiles into ONE joint program:
    fused-regime columns, or strip-mined columns of at most two factors
    (``n2 ≤ fused_max²``).  Beyond that the column program would need a
    digit-reversal relayout down axis -2 and ``fft.plan()`` composes
    per-axis plans instead.  The explicit form of the
    :func:`compile_passes2d` gate, so callers can branch without catching
    its ``NotImplementedError``."""
    return _is_pow2(n2) and (
        n2 <= fused_max or len(program_factors(n2, fused_max)) <= 2
    )


@functools.lru_cache(maxsize=256)
def compile_passes2d(
    n: int, n2: int, fused_max: int = FUSED_MAX, direct_max: int = DIRECT_MAX
) -> tuple[Pass, ...]:
    """Compile the joint pass program of an ``(..., n2, n)`` 2-D transform.

    Row passes first — the 1-D program of the last axis, executed over
    ``batch × n2`` contiguous rows — then the column passes down axis -2.
    Fused-regime columns (``n2 ≤ fused_max``) are one in-place strided
    column pass: the whole image is the pencil view ``(b, n2, n)`` and the
    column kernel transforms its middle axis, so the row→column handoff
    never materialises an HBM transpose (the §2.3.2 discipline extended to
    the paper's image workload).

    Beyond the fused regime the columns are **strip-mined**: the 1-D split
    program of ``n2`` re-tagged ``axis=-2`` — strided multi-factor column
    passes whose pencil views decompose the n2 axis exactly like the 1-D
    flat buffer, with the image width riding along as extra pencil columns
    (swept chunk-by-chunk) and the inter-factor twiddle broadcast across
    the width inside the kernel.  Taller-than-``fused_max²`` images would
    additionally need a digit-reversal relayout down axis -2 and stay
    gated.
    """
    if not _is_pow2(n2):
        raise faults.PlanError(f"FFT length must be a power of two, got {n2}")
    passes = list(compile_passes(n, fused_max, "natural", direct_max))
    if n2 <= fused_max:
        if n2 > 1:
            leaf = _leaf_pass(n2, direct_max)
            passes.append(
                Pass(
                    kind=leaf.kind,
                    n=n2,
                    n1=leaf.n1,
                    n2=leaf.n2,
                    view_in=(1, 1, n2),
                    view_out=(1, 1, n2),
                    order="natural",
                    axis=-2,
                )
            )
        return tuple(passes)
    col_passes = compile_passes(n2, fused_max, "natural", direct_max)
    if any(p.kind == "reorder" for p in col_passes):
        raise NotImplementedError(
            f"strip-mined column programs cover n2 ≤ fused_max² "
            f"({fused_max**2}); n2={n2} would need a digit-reversal "
            f"relayout pass down axis -2.  fft.plan(FFTSpec(kind='fft2')) "
            f"composes per-axis plans instead for such images."
        )
    passes.extend(dataclasses.replace(p, axis=-2) for p in col_passes)
    return tuple(passes)


@functools.lru_cache(maxsize=512)
def plan_fft(
    n: int,
    fused_max: int = FUSED_MAX,
    direct_max: int = DIRECT_MAX,
    pad: int | None = None,
) -> FFTPlan:
    """Plan a length-``n`` complex FFT.

    Power-of-two lengths compile to the native direct/fused/split programs;
    any other ``n ≥ 2`` compiles to the Bluestein chirp-conv program
    (:func:`compile_bluestein`), with ``pad`` optionally overriding the
    conv pad length (the tuner's knob — pow2, ≥ 2n−1).
    """
    if n < 1:
        raise faults.PlanError(f"FFT length must be positive, got {n}")
    if not _is_pow2(n):
        passes = compile_bluestein(n, pad, fused_max, direct_max)
        m_pad = passes[0].n1
        leaves = [passes[0]]  # the chirp leaf: one entry per p.n == n
        if m_pad > fused_max:
            # Split-regime conv: the pad length's own leaves tile the
            # inner program's kernels.
            leaves.extend(plan_fft(m_pad, fused_max, direct_max).leaf_passes)
        return FFTPlan(
            n=n,
            levels=(),
            leaf_passes=tuple(sorted(leaves, key=lambda p: p.n)),
            passes=passes,
        )
    if pad is not None:
        raise faults.PlanError("pad applies only to non-power-of-two lengths")
    levels: list[tuple[int, int]] = []
    m = n
    while m > fused_max:
        # Keep the inner factor in the fused regime, outer as small as
        # possible: each level's twiddle grid and transpose cost scale with
        # the outer factor.
        n_outer, n_inner = balanced_split(m, cap=fused_max)
        levels.append((n_outer, n_inner))
        m = n_outer  # the outer transform may itself need splitting
        if n_inner <= fused_max and n_outer <= fused_max:
            break
    # Distinct leaf lengths (outer and inner of the last level, or n itself).
    if levels:
        leaf_lengths = {levels[-1][0], levels[-1][1]}
        for i in range(len(levels) - 1):
            leaf_lengths.add(levels[i][1])
    else:
        leaf_lengths = {n}
    leaves = tuple(
        sorted((_leaf_pass(m, direct_max) for m in leaf_lengths), key=lambda p: p.n)
    )
    return FFTPlan(
        n=n,
        levels=tuple(levels),
        leaf_passes=leaves,
        passes=compile_passes(n, fused_max, "natural", direct_max),
    )


@functools.lru_cache(maxsize=256)
def plan_fft2(
    n: int, n2: int, fused_max: int = FUSED_MAX, direct_max: int = DIRECT_MAX
) -> FFTPlan:
    """Plan an ``(..., n2, n)`` 2-D complex FFT as ONE linearized program.

    ``n`` is the last-axis (row) length, ``n2`` the second-to-last (column)
    length.  The returned plan's ``passes`` mix ``axis=-1`` row passes with
    the in-place ``axis=-2`` column pass — a single compiled schedule, no
    per-axis child plans and no transposes between the axes.
    """
    row_plan = plan_fft(n, fused_max, direct_max)
    # Keep the row plan's leaves verbatim (a non-pow2 row length's leaf is
    # the Bluestein chirp pass itself — not re-derivable from its length);
    # strip-mined columns contribute one leaf per column factor.
    leaf_map = {p.n: p for p in row_plan.leaf_passes}
    if n2 > 1:
        for m in program_factors(n2, fused_max):
            leaf_map.setdefault(m, _leaf_pass(m, direct_max))
    leaves = tuple(sorted(leaf_map.values(), key=lambda p: p.n))
    return FFTPlan(
        n=n,
        levels=row_plan.levels,
        leaf_passes=leaves,
        passes=compile_passes2d(n, n2, fused_max, direct_max),
        n2=n2,
    )


def vmem_bytes(p: Pass, batch_tile: int) -> int:
    """Estimated VMEM working set of one grid step of a leaf pass.

    Split-complex float32 everywhere: signal tile in + out, DFT matrices,
    twiddle grid, one intermediate.  Used by the kernel launcher to pick the
    batch tile so the block fits comfortably in ~16 MB of VMEM (we budget
    half of it, leaving room for Mosaic's double buffering).
    """
    f32 = 4
    if p.kind == "bluestein":
        # The chirp leaf's working set is pad-sized: the padded signal tile
        # in/mid/out, the inner pad-FFT's LUTs (fwd/inv stages only), and
        # the (1, n)/(1, M) chirp planes.
        m_pad = p.n1
        sig = batch_tile * m_pad * 2 * f32
        chirps = (p.n + m_pad) * 2 * f32
        mats = 0
        if p.stage in ("fwd", "inv"):
            inner = _leaf_pass(m_pad)
            if inner.kind == "direct":
                mats = m_pad * m_pad * 2 * f32
            else:
                mats = (
                    inner.n1 * inner.n1 + inner.n2 * inner.n2
                    + inner.n1 * inner.n2
                ) * 2 * f32
        return 3 * sig + mats + chirps
    if p.kind == "direct":
        sig = batch_tile * p.n * 2 * f32
        mats = p.n * p.n * 2 * f32
        return 2 * sig + mats
    sig = batch_tile * p.n * 2 * f32             # x tile (= n1*n2 grid)
    mats = (p.n1 * p.n1 + p.n2 * p.n2) * 2 * f32  # W1, W2
    tw = p.n1 * p.n2 * 2 * f32                    # twiddle grid
    return 3 * sig + mats + tw                    # in, intermediate, out


def pick_batch_tile(p: Pass, budget: int = VMEM_BUDGET) -> int:
    """Largest power-of-two batch tile whose working set fits the budget."""
    bt = 512
    while bt > 1 and vmem_bytes(p, bt) > budget:
        bt //= 2
    return bt


#: K-loop staging depth of the Triton GEMM pipeline: the leaf's LUT operands
#: stream through shared memory in (GPU_LUT_STAGE x tile) stripes rather than
#: residing whole, so only one stripe per operand is charged to the budget.
GPU_LUT_STAGE = 32


def gpu_smem_bytes(p: Pass, batch_tile: int) -> int:
    """Modeled per-program shared-memory working set of the GPU row leaf.

    Differs from :func:`vmem_bytes` in what counts as resident: on TPU the
    whole DFT matrix / twiddle grid sits in VMEM for the block; on a CUDA SM
    the signal tiles are resident but the LUT operands are software-pipelined
    through shared memory one :data:`GPU_LUT_STAGE`-deep stripe at a time
    (the Triton ``dot`` K loop).  Charging the full LUTs against a 48-228 KB
    budget would force every tile to 1 and misreport the paper's metric.
    """
    f32 = 4
    if p.kind == "bluestein":
        # Pad-sized tiles; the inner pad-FFT's LUTs pipeline in stripes and
        # the chirp planes are 1-row operands (charged whole, they're tiny
        # next to the signal tiles).
        m_pad = p.n1
        sig = batch_tile * m_pad * 2 * f32
        chirps = (p.n + m_pad) * 2 * f32
        stripes = 0
        if p.stage in ("fwd", "inv"):
            inner = _leaf_pass(m_pad)
            if inner.kind == "direct":
                stripes = GPU_LUT_STAGE * m_pad * 2 * f32
            else:
                stripes = GPU_LUT_STAGE * (inner.n1 + 2 * inner.n2) * 2 * f32
        return 3 * sig + stripes + chirps
    if p.kind == "direct":
        sig = batch_tile * p.n * 2 * f32
        stripe = GPU_LUT_STAGE * p.n * 2 * f32
        return 2 * sig + stripe                       # in, out + W stripe
    sig = batch_tile * p.n * 2 * f32
    stripes = GPU_LUT_STAGE * (p.n1 + p.n2) * 2 * f32  # W1, W2 stripes
    tw = GPU_LUT_STAGE * p.n2 * 2 * f32                # twiddle-grid stripe
    return 3 * sig + stripes + tw                      # in, mid, out


def pick_batch_tile_gpu(p: Pass, budget: int | None = None) -> int:
    """Largest power-of-two batch tile whose GPU shared-memory working set
    fits ``budget`` (default: the resolved :func:`~repro.core.limits.memory_budget`
    of the first visible device)."""
    if budget is None:
        budget = memory_budget()
    bt = 512
    while bt > 1 and gpu_smem_bytes(p, bt) > budget:
        bt //= 2
    return bt


def pass_hbm_bytes(p: Pass, batch: int = 1, other: int = 1) -> int:
    """Modeled HBM traffic of one program pass, split-complex float32.

    Signal read + signal write, plus the chunked twiddle LUT (streamed once
    per pass through its BlockSpec) and the transform LUTs (pinned to block
    (0, 0), so fetched from HBM once regardless of grid size).  This is the
    figure ``launch.dryrun`` / ``analysis.roofline`` report per pass so the
    round-trip count is observable, and what the tests assert.

    ``other`` is the multi-axis multiplier: the length of the image axis the
    pass does *not* transform (``n2`` for row passes, the row length ``n``
    for column passes — every 2-D pass streams the whole image).
    """
    f32 = 4
    if p.kind == "reorder":
        return 2 * batch * other * p.n * 2 * f32
    if p.kind == "bluestein":
        # In and out widths differ (n → M on the way in, M → n back out);
        # chirp planes stream once, and the fused fwd/inv stages carry the
        # inner pad-FFT's LUTs.
        n_in = p.view_in[2] if p.view_in else p.n
        n_out = p.view_out[2] if p.view_out else p.n
        sig = batch * other * (n_in + n_out) * 2 * f32
        luts = (p.n + p.n1) * 2 * f32
        if p.stage in ("fwd", "inv"):
            inner = _leaf_pass(p.n1)
            if inner.kind == "direct":
                luts += p.n1 * p.n1 * 2 * f32
            else:
                luts += (
                    inner.n1 * inner.n1 + inner.n2 * inner.n2
                    + inner.n1 * inner.n2
                ) * 2 * f32
        return sig + luts
    pencils, _stride, f = p.view_in if p.view_in else (1, 1, p.n)
    sig = batch * other * pencils * f * 2 * f32
    tw = 0
    if p.twiddle_after:
        tw = p.twiddle_after[0] * p.twiddle_after[1] * 2 * f32
    if p.kind == "direct":
        luts = p.n * p.n * 2 * f32
    else:
        luts = (p.n1 * p.n1 + p.n2 * p.n2 + p.n1 * p.n2) * 2 * f32
    return 2 * sig + tw + luts


def pass_other(p: Pass, plan: FFTPlan) -> int:
    """The non-transformed image-axis length a pass of ``plan`` streams —
    the ``other`` multiplier :func:`pass_hbm_bytes` charges (1 for 1-D)."""
    if plan.n2 is None:
        return 1
    return plan.n if p.axis == -2 else plan.n2


def program_hbm_bytes(
    passes: tuple[Pass, ...], batch: int = 1, shape2d: tuple | None = None
) -> int:
    """Total modeled HBM traffic of a pass program.

    ``shape2d=(n2, n)`` scales each pass by the image axis it streams but
    does not transform (a 2-D program's passes all touch the whole image).
    """
    if shape2d is None:
        return sum(pass_hbm_bytes(p, batch) for p in passes)
    n2, n = shape2d
    return sum(
        pass_hbm_bytes(p, batch, n if p.axis == -2 else n2) for p in passes
    )


def _pass_chunk_bytes(p: Pass, c: int) -> int:
    """VMEM working set of one grid step of a pencil pass with chunk ``c``."""
    f32 = 4
    if p.kind == "bluestein":
        # Whole-signal chirp passes are batch-tiled, never chunked; charge
        # the tile model so a defensive caller still gets a sane bound.
        return vmem_bytes(p, c)
    sig = p.n * c * 2 * f32
    tw = sig if p.twiddle_after else 0
    if p.kind == "direct":
        luts = p.n * p.n * 2 * f32
    else:
        luts = (p.n1 * p.n1 + p.n2 * p.n2 + p.n1 * p.n2) * 2 * f32
    return 3 * sig + tw + luts  # in, intermediate, out (+ twiddle slab)


def pick_pass_chunk(
    p: Pass, budget: int = VMEM_BUDGET, width: int | None = None
) -> int:
    """Per-grid-step chunk (columns for strided passes, rows for contiguous
    ones) — largest power of two fitting the VMEM budget.

    ``width`` overrides the chunked-axis length — 2-D column passes chunk
    the image width (possibly the n//2+1 bins of an rfft2 half-spectrum),
    which the per-axis pencil view cannot know.  Non-power-of-two widths
    start from the largest power of two below them; the executor pads the
    last partial chunk.

    The budget is binding: for large factors the chunk drops below one
    128-lane tile (padded sublanes beat a working set that Mosaic cannot
    place in VMEM at all — interpret-mode CI would never catch that)."""
    if width is None:
        pencils, stride, _f = p.view_in
        width = stride if stride > 1 else pencils
    c = 1 << (max(width, 1).bit_length() - 1)  # largest pow2 <= width
    while c > 1 and _pass_chunk_bytes(p, c) > budget:
        c //= 2
    return max(c, 1)


def describe_program(p: FFTPlan, batch: int = 1) -> str:
    """Human-readable pass program, e.g. for logging/EXPERIMENTS.md."""
    if p.n2 is not None:
        head = f"N={p.n2}x{p.n} (axis -2 x axis -1)"
    else:
        head = f"N={p.n}"
    parts = [f"{head}: {p.hbm_round_trips} HBM round trip(s)"]
    for i, ps in enumerate(p.passes):
        mb = pass_hbm_bytes(ps, batch, pass_other(ps, p)) / 1e6
        if ps.kind == "reorder":
            parts.append(f"pass {i}: digit-reversal reorder (~{mb:.1f} MB)")
            continue
        if ps.kind == "bluestein":
            stage_txt = {
                "fwd": "chirp-pre + pad-FFT ⊙ B̂ (fused)",
                "inv": "pad-IFFT + chirp-post (fused)",
                "pre": "chirp pre-multiply + zero-pad",
                "mul": "⊙ B̂ chirp spectrum",
                "post": "slice + chirp post-multiply",
            }.get(ps.stage, ps.stage)
            parts.append(
                f"pass {i}: bluestein n={ps.n} pad={ps.n1} {stage_txt} "
                f"(~{mb:.1f} MB)"
            )
            continue
        pencils, stride, f = ps.view_in
        algo = (
            f"direct DFT n={f}"
            if ps.kind == "direct"
            else f"fused four-step n={f} ({ps.n1} x {ps.n2})"
        )
        if ps.axis == -2 and pencils > 1:
            layout = (
                f"axis -2 strip-mined cols {pencils}x{f} stride={stride} "
                f"(width {p.n})"
            )
        elif ps.axis == -2:
            layout = f"axis -2 in-place columns (width {p.n})"
        elif pencils == 1:
            layout = "whole-signal"
        elif stride == 1:
            layout = f"{pencils} rows"
        else:
            layout = f"{pencils} cols stride={stride}"
        tw = (
            f" + twiddle {ps.twiddle_after[0]}x{ps.twiddle_after[1]}"
            if ps.twiddle_after
            else ""
        )
        fold = " -> natural order (fused write)" if ps.view_out != ps.view_in else ""
        parts.append(f"pass {i}: {layout} {algo}{tw}{fold} (~{mb:.1f} MB)")
    return "; ".join(parts)


def describe(n: int, batch: int = 1, n2: int | None = None) -> str:
    """Describe the pass program for a 1-D length-``n`` transform, or — with
    ``n2`` — the joint multi-axis program of an ``(..., n2, n)`` 2-D one."""
    return describe_program(plan_fft2(n, n2) if n2 is not None else plan_fft(n), batch)
