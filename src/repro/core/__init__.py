"""The paper's primary contribution: memory-optimized FFT for TPU.

Layers:
  twiddle      precomputed LUTs (texture-memory analogue)
  plan         HBM-round-trip schedule (kernel-call count analogue)
  fft_xla      pure-JAX Stockham + four-step backends
  fft          plan-and-execute public API (FFTSpec → plan() → PlannedFFT)
               over a capability-negotiated backend registry
  conv         FFT-based long convolution (LM integration point)
  overlap      overlap-save streaming convolution (blocks through small plans)
  distributed  pencil FFT over mesh axes (pod-scale all-to-all schedule)
  limits       the regime thresholds (single source: DIRECT/FUSED_MAX, ...)
  tuning       roofline-seeded autotuner (measured configs, persistent cache)
"""

from repro.core import (
    conv,
    distributed,
    faults,
    fft,
    fft_xla,
    limits,
    overlap,
    plan,
    tuning,
    twiddle,
)
from repro.core.faults import (
    CollectiveError,
    KernelError,
    NumericsError,
    PlanError,
    ReproError,
    ServeError,
    TuningCacheError,
    inject_fault,
)
from repro.core.conv import fft_conv
from repro.core.overlap import StreamingConv, fft_conv_os
from repro.core.fft import (
    FFTSpec,
    PlannedFFT,
    available_backends,
    default_backend,
    fft2,
    ifft,
    ifft2,
    irfft,
    irfft2,
    register_backend,
    rfft,
    rfft2,
    use_backend,
)
from repro.core.fft import fft as fft_fn
from repro.core.fft import plan as plan_transform
from repro.core.plan import FFTPlan, plan_fft

__all__ = [
    "conv",
    "distributed",
    "faults",
    "ReproError",
    "PlanError",
    "KernelError",
    "TuningCacheError",
    "CollectiveError",
    "ServeError",
    "NumericsError",
    "inject_fault",
    "fft",
    "fft_xla",
    "limits",
    "overlap",
    "plan",
    "tuning",
    "twiddle",
    "fft_conv",
    "fft_conv_os",
    "StreamingConv",
    "fft_fn",
    "fft2",
    "ifft",
    "ifft2",
    "irfft",
    "irfft2",
    "rfft",
    "rfft2",
    "FFTSpec",
    "PlannedFFT",
    "plan_transform",
    "register_backend",
    "available_backends",
    "use_backend",
    "default_backend",
    "FFTPlan",
    "plan_fft",
]
