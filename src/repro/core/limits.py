"""Memory-hierarchy regime limits — the single source every layer consumes.

The paper's schedule is parameterized by where each transform regime ends
(§2.3.2: one kernel call while the working set fits the fast tier, two
beyond, ...).  These thresholds used to be scattered as per-module constants
(`plan.FUSED_MAX`, `overlap.OS_FACTOR`, ad-hoc VMEM budgets); they live here
so the planner, the overlap-save engine, the conv router and the autotuner
all agree on one regime map — and so the tuner (:mod:`repro.core.tuning`)
has one place to read the *fixed heuristics* it replaces with searched
decisions.

``tests/test_limits.py`` grep-asserts this file is the only assignment site
of each constant.
"""

from __future__ import annotations

__all__ = [
    "DIRECT_MAX",
    "FUSED_MAX",
    "OS_FACTOR",
    "VMEM_BUDGET",
    "next_pow2",
]

#: Largest N executed as a single direct DFT matmul (one (B,N)x(N,N) GEMM).
DIRECT_MAX = 1024

#: Largest N executed by the fused four-step kernel in one HBM round trip.
#: 65536 = 256·256 keeps the per-block working set (signal tile + two DFT
#: matrices + twiddle grid + scratch) under ~6 MB of VMEM — see
#: :func:`repro.core.plan.vmem_bytes`.
FUSED_MAX = 65536

#: Default overlap-save block multiplier: B = next_pow2(Lh) · OS_FACTOR.
#: 8 keeps the valid fraction per block at (B − Lh + 1)/B ≥ 7/8 — under 15%
#: redundant transform work — while staying inside the fused regime for the
#: 4k-tap filters of the Hyena/SAR workloads (8192 · 8 = 65536 = FUSED_MAX).
#: This is the fixed heuristic ``tune="measure"`` searches past.
OS_FACTOR = 8

#: Per-grid-step VMEM working-set budget: half of the ~16 MB per core,
#: leaving room for Mosaic's double buffering.  Binds the batch-tile and
#: pass-chunk picks (and the tuner's candidate feasibility check).
VMEM_BUDGET = 8 * 1024 * 1024


def next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()
