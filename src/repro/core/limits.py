"""Memory-hierarchy regime limits — the single source every layer consumes.

The paper's schedule is parameterized by where each transform regime ends
(§2.3.2: one kernel call while the working set fits the fast tier, two
beyond, ...).  These thresholds used to be scattered as per-module constants
(`plan.FUSED_MAX`, `overlap.OS_FACTOR`, ad-hoc VMEM budgets); they live here
so the planner, the overlap-save engine, the conv router and the autotuner
all agree on one regime map — and so the tuner (:mod:`repro.core.tuning`)
has one place to read the *fixed heuristics* it replaces with searched
decisions.

``tests/test_limits.py`` grep-asserts this file is the only assignment site
of each constant.
"""

from __future__ import annotations

__all__ = [
    "DIRECT_MAX",
    "FUSED_MAX",
    "OS_FACTOR",
    "VMEM_BUDGET",
    "GPU_SMEM_BUDGETS",
    "GPU_SMEM_DEFAULT",
    "BLUESTEIN_MIN",
    "memory_budget",
    "next_pow2",
    "next_fast_len",
    "bluestein_pad",
]

#: Largest N executed as a single direct DFT matmul (one (B,N)x(N,N) GEMM).
DIRECT_MAX = 1024

#: Largest N executed by the fused four-step kernel in one HBM round trip.
#: 65536 = 256·256 keeps the per-block working set (signal tile + two DFT
#: matrices + twiddle grid + scratch) under ~6 MB of VMEM — see
#: :func:`repro.core.plan.vmem_bytes`.
FUSED_MAX = 65536

#: Default overlap-save block multiplier: B = next_pow2(Lh) · OS_FACTOR.
#: 8 keeps the valid fraction per block at (B − Lh + 1)/B ≥ 7/8 — under 15%
#: redundant transform work — while staying inside the fused regime for the
#: 4k-tap filters of the Hyena/SAR workloads (8192 · 8 = 65536 = FUSED_MAX).
#: This is the fixed heuristic ``tune="measure"`` searches past.
OS_FACTOR = 8

#: Per-grid-step VMEM working-set budget: half of the ~16 MB per core,
#: leaving room for Mosaic's double buffering.  Binds the batch-tile and
#: pass-chunk picks (and the tuner's candidate feasibility check).
VMEM_BUDGET = 8 * 1024 * 1024

#: Per-SM shared-memory budgets (bytes) for CUDA-class devices, keyed by a
#: lowercase substring of ``jax.devices()[0].device_kind``.  These are the
#: opt-in dynamic-shared-memory carveouts (the paper's Fermi generation had
#: 48 KB; modern parts expose far more), matched most-specific-first.
GPU_SMEM_BUDGETS = (
    ("h100", 228 * 1024),
    ("h200", 228 * 1024),
    ("b200", 228 * 1024),
    ("a100", 164 * 1024),
    ("a10", 164 * 1024),
    ("l4", 100 * 1024),
    ("v100", 96 * 1024),
    ("t4", 64 * 1024),
    ("p100", 64 * 1024),
)

#: Conservative fallback for unrecognized GPU device kinds: the 48 KB
#: static shared-memory floor every CUDA generation since Fermi guarantees
#: (the budget the source paper tiles against).
GPU_SMEM_DEFAULT = 48 * 1024


def memory_budget(device_kind: str | None = None) -> int:
    """Fast-tier working-set budget (bytes) for ``device_kind``.

    The regime map used to hard-code the TPU ``VMEM_BUDGET``; on CUDA-class
    devices the same decisions (leaf batch tiles, pass chunk widths, tuner
    feasibility) bind against per-SM shared memory instead.  ``device_kind``
    defaults to the first visible jax device; TPU and CPU resolve to
    ``VMEM_BUDGET`` (CPU hosts interpret-mode runs of the TPU schedule), GPU
    kinds resolve through :data:`GPU_SMEM_BUDGETS`.
    """
    if device_kind is None:
        try:
            import jax

            device = jax.devices()[0]
            device_kind = device.device_kind
            if device.platform not in ("gpu", "cuda", "rocm"):
                return VMEM_BUDGET
        except Exception:
            return VMEM_BUDGET
    kind = device_kind.lower()
    if "tpu" in kind or kind in ("cpu", "", "interpreter"):
        return VMEM_BUDGET
    for tag, budget in GPU_SMEM_BUDGETS:
        if tag in kind:
            return budget
    if any(t in kind for t in ("nvidia", "cuda", "gpu", "rtx", "geforce", "amd", "mi3")):
        return GPU_SMEM_DEFAULT
    return VMEM_BUDGET


#: Smallest non-power-of-two length the Bluestein chirp-conv leaf accepts.
#: n = 1 is the identity transform and n = 2^k routes to the native pow2
#: programs, so the chirp path only ever sees n ≥ 2 composites/primes.
BLUESTEIN_MIN = 2


def next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def next_fast_len(n: int) -> int:
    """Smallest length ≥ ``n`` this engine transforms natively (pow2 —
    every leaf kernel, LUT builder and roofline account is pow2-shaped;
    arbitrary ``n`` itself routes through the Bluestein chirp leaf)."""
    return next_pow2(max(n, 1))


def bluestein_pad(n: int) -> int:
    """The chirp convolution length for a length-``n`` Bluestein transform:
    the circular conv must hold the 2n−1 support of a[j]·b[k−j], padded to
    the next power of two so the inner FFT pair stays on the native pow2
    engines.  This is the *floor* — the tuner may pick a larger pow2 pad."""
    return next_pow2(max(2 * n - 1, 1))
