"""Overlap-save streaming convolution — long signals through small plans.

The paper's whole point (§2.3.2, §3) is bounding global-memory round trips
by keeping each transform inside the fast tier, yet a one-shot ``fft_conv``
does the opposite for long signals: a 1M-sample signal with a 4k-tap filter
pads to ONE length-2²⁰ transform and plans a split-regime program.  Adámek
et al. ("GPU Fast Convolution via the Overlap-and-Save Method in Shared
Memory", PAPERS.md) show the alternative this module implements:

* **block** the signal into overlapping segments sized to the fast-memory
  tier — ``B = next_pow2(Lh)·OS_FACTOR``, capped at the fused-kernel regime
  (:data:`repro.core.plan.FUSED_MAX`), so every transform is a single
  HBM round trip;
* run ONE cached rfft/irfft plan pair **batched over all blocks** (the
  filter spectrum is computed once and broadcast) — exactly the shape the
  pallas pass programs are fastest at: big batch × fused-regime N;
* scatter each block's valid tail (the ``B − (Lh−1)`` samples whose history
  is fully inside the block) back into the output.

On top of the one-shot :func:`fft_conv_os`:

* :class:`StreamingConv` carries the ``Lh − 1`` overlap tail as **explicit
  state**, so chunked calls (serving decode, SAR strip ingest) compose to
  the one-shot result bit-for-bit at tolerance — including ragged final
  chunks and chunks shorter than the filter;
* ``repro.core.distributed.pconv_os_sharded`` shards the blocks over a mesh
  axis with ``shard_map`` — blocks are embarrassingly parallel, so the
  distributed convolution pays **zero** all-to-alls versus the 4 of the
  ``pfft``-based pencil path;
* ``repro.core.conv.fft_conv`` auto-routes here whenever the one-shot
  padded length would leave the fused regime.

``analysis.roofline.conv_report`` models the HBM traffic of both schedules
so the win is observable, not just asserted.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core import fft as fft_lib
from repro.core import plan as plan_lib
from repro.core.fft_xla import cmul
from repro.core.limits import OS_FACTOR, next_pow2

Planes = Tuple[jax.Array, jax.Array]

__all__ = [
    "OS_FACTOR",
    "pick_block",
    "frame_signal",
    "filter_spectrum",
    "conv_frames",
    "fft_conv_os",
    "stream_lookahead",
    "StreamingConv",
]

# OS_FACTOR (the fixed block-size heuristic the autotuner searches past)
# lives in repro.core.limits with the other regime thresholds; re-exported
# here because this engine is where callers historically imported it from.


def pick_block(filter_len: int, block: Optional[int] = None) -> int:
    """FIXED-heuristic overlap-save block size for a ``filter_len``-tap
    filter (the tuner's baseline; :func:`_resolve_block` searches past it).

    Default: ``next_pow2(filter_len) · OS_FACTOR``, capped at
    :data:`~repro.core.plan.FUSED_MAX` so no planned transform leaves the
    one-round-trip regime; for filters too long for that cap to leave room
    (``next_pow2(filter_len) > FUSED_MAX/2``) the block grows to twice the
    filter's padded length instead — correctness over the cap.  ``block``
    overrides (power of two, > filter_len − 1 so each block produces at
    least one valid sample).
    """
    if filter_len < 1:
        raise faults.PlanError(f"filter must have at least one tap, got {filter_len}")
    p = next_pow2(filter_len)
    if block is not None:
        if block <= 0 or block & (block - 1):
            raise faults.PlanError(f"block must be a power of two, got {block}")
        if block <= filter_len - 1:
            raise faults.PlanError(
                f"block={block} leaves no valid samples for a "
                f"{filter_len}-tap filter (needs block > {filter_len - 1})"
            )
        return block
    return max(min(p * OS_FACTOR, plan_lib.FUSED_MAX), 2 * p, 2)


def _resolve_block(
    filter_len: int,
    block: Optional[int],
    L: int,
    batch: int,
    backend: Optional[str],
    tune: Optional[str],
    chunk: Optional[int] = None,
) -> int:
    """The block an overlap-save call actually uses: an explicit ``block``
    is validated and wins; otherwise the autotuner decides (``tune="off"``
    → the fixed ``OS_FACTOR`` heuristic, ``"model"`` → the roofline
    modeled minimum, ``"measure"`` → the measured winner from the
    persistent cache — see :mod:`repro.core.tuning`).  ``chunk`` keys the
    decision to a streaming call grain: the tuner models and measures
    per-chunk calls (state + chunk in, chunk out) instead of one long
    ingest."""
    if block is not None:
        return pick_block(filter_len, block)
    from repro.core import tuning  # lazy: tuning measures through this module

    mode = tuning.resolve_mode(tune)
    if mode == "off" or filter_len < 2:
        return pick_block(filter_len)
    return tuning.tuned_block(L, filter_len, batch, backend, mode, chunk=chunk)


def frame_signal(
    x: jax.Array, block: int, step: int, num_blocks: int
) -> jax.Array:
    """Strided overlap-save framing of the last axis.

    Left-pads with ``block − step`` zeros (the causal history of the first
    block), right-pads with zeros to a whole number of steps, and gathers
    the overlapping windows: frame ``j`` covers padded offsets
    ``[j·step, j·step + block)``, so consecutive frames share the
    ``block − step`` overlap.  Returns ``(..., num_blocks, block)``.
    """
    overlap = block - step
    pad_r = num_blocks * step - x.shape[-1]
    if pad_r < 0:
        raise faults.PlanError(
            f"{num_blocks} blocks of step {step} cover only "
            f"{num_blocks * step} < {x.shape[-1]} samples"
        )
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(overlap, pad_r)])
    idx = np.arange(num_blocks)[:, None] * step + np.arange(block)[None, :]
    # indices are in-bounds by construction; mode="clip" skips the gather's
    # OOB mask (which XLA otherwise constant-folds at O(nb·B) compile cost)
    return jnp.take(xp, jnp.asarray(idx, np.int32), axis=-1, mode="clip")


def filter_spectrum(
    h: jax.Array, block: int, backend: Optional[str] = None
) -> Planes:
    """Half-spectrum of ``h`` zero-padded to ``block``, with a broadcast
    block axis inserted before the bins — computed once per call and shared
    by every block (the paper's precomputed-LUT idea one level up)."""
    h = jnp.asarray(h, jnp.float32)
    hp = jnp.pad(h, [(0, 0)] * (h.ndim - 1) + [(0, block - h.shape[-1])])
    fwd = fft_lib.plan(fft_lib.FFTSpec(n=block, kind="rfft"), backend=backend)
    Hr, Hi = fwd(hp)
    return Hr[..., None, :], Hi[..., None, :]


def conv_frames(
    frames: jax.Array,
    Hr: jax.Array,
    Hi: jax.Array,
    *,
    overlap: int,
    backend: Optional[str] = None,
) -> jax.Array:
    """Batched circular convolution of ``(..., nb, B)`` frames with the
    broadcast filter spectrum, keeping each frame's valid tail.

    ONE cached rfft/irfft plan pair over all blocks (batch = leading dims ×
    nb), pointwise spectrum multiply, and the overlap-save discard: the
    first ``overlap`` samples of each block alias history that belongs to
    the previous block.  Returns ``(..., nb, B − overlap)``.  Also the body
    of the sharded variant — it is collective-free, so blocks shard over a
    mesh axis with no all-to-alls.
    """
    block = frames.shape[-1]
    fwd = fft_lib.plan(fft_lib.FFTSpec(n=block, kind="rfft"), backend=backend)
    inv = fft_lib.plan(fft_lib.FFTSpec(n=block, kind="irfft"), backend=backend)
    Fr, Fi = fwd(frames)
    Yr, Yi = cmul(Fr, Fi, Hr, Hi)
    y = inv((Yr, Yi))
    return y[..., overlap:]


def fft_conv_os(
    x: jax.Array,
    h: jax.Array,
    *,
    causal: bool = True,
    axis: int = -1,
    block: Optional[int] = None,
    backend: Optional[str] = None,
    tune: Optional[str] = None,
) -> jax.Array:
    """Overlap-save convolution of ``x`` with filter ``h`` along ``axis``.

    Matches :func:`repro.core.conv.fft_conv` outputs at tolerance while
    never planning a transform larger than the block (≤ ``FUSED_MAX`` by
    default): the signal is framed into overlapping blocks, all blocks run
    through one cached rfft/irfft plan pair, and the valid tails are
    scattered back.  ``h`` broadcasts against ``x`` with the convolution
    axis moved last, exactly like ``fft_conv``.

    With ``block=None`` the block size is a tuned decision
    (:mod:`repro.core.tuning`): ``tune="off"`` keeps the fixed
    ``OS_FACTOR`` heuristic, ``"model"`` (default) takes the roofline
    modeled minimum, ``"measure"`` times the pruned candidates once per
    ``(device, backend, L, Lh, batch)`` and reuses the persisted winner.
    """
    x = jnp.asarray(x)
    out_dtype = x.dtype
    x = x.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    L, Lh = x.shape[-1], h.shape[-1]
    batch = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    B = _resolve_block(Lh, block, L, batch, backend, tune)
    overlap = Lh - 1
    step = B - overlap
    L_out = L if causal else L + Lh - 1
    nb = -(-L_out // step)
    frames = frame_signal(x, B, step, nb)
    Hr, Hi = filter_spectrum(h, B, backend)
    tails = conv_frames(frames, Hr, Hi, overlap=overlap, backend=backend)
    lead = tails.shape[:-2]
    y = tails.reshape(*lead, nb * step)[..., :L_out]
    if axis != -1:
        y = jnp.moveaxis(y, -1, axis)
    return y.astype(out_dtype)


def _stream_conv(
    xin: jax.Array,
    Hr: jax.Array,
    Hi: jax.Array,
    *,
    block: int,
    overlap: int,
    backend: Optional[str] = None,
) -> jax.Array:
    """Causal conv of ``xin`` (carried history prefix included) through the
    cached block plan, keeping only the outputs past the history:
    ``conv(xin)[..., overlap:]``.

    When everything fits one block (the decode-grain case: a flush of
    ``Lh − 1`` tail + one chunk) this is a single padded frame through ONE
    cached rfft/irfft pair — no framing gather at all.  Every kept output
    position ``p ≥ overlap ≥ j`` for all filter taps ``j``, so the circular
    convolution never wraps into the kept range and the single frame equals
    the framed multi-block result.
    """
    L = xin.shape[-1]
    if L <= block:
        pad = [(0, 0)] * (xin.ndim - 1) + [(0, block - L)]
        frames = jnp.pad(xin, pad)[..., None, :]
        y = conv_frames(frames, Hr, Hi, overlap=overlap, backend=backend)
        return y[..., 0, : L - overlap]
    step = block - overlap
    nb = -(-L // step)
    frames = frame_signal(xin, block, step, nb)
    tails = conv_frames(frames, Hr, Hi, overlap=overlap, backend=backend)
    lead = tails.shape[:-2]
    y = tails.reshape(*lead, nb * step)[..., :L]
    return y[..., overlap:]


def stream_lookahead(
    tail: jax.Array,
    Hr: jax.Array,
    Hi: jax.Array,
    *,
    window: int,
    block: int,
    backend: Optional[str] = None,
) -> jax.Array:
    """History-only contributions for the next ``window`` stream positions.

    ``tail``: (..., Lh − 1) — the carried overlap state.  Returns
    (..., window): entry ``i`` is what the causal conv would emit at the
    ``i``-th upcoming position if every upcoming input were zero, i.e. the
    Σ_{j>i} h[j]·x[t−j] half of the output.  This is the flush primitive of
    the amortized spectral decode: the serving cache adds the direct head
    (taps ``j ≤ i`` against the accumulating chunk) per token and refreshes
    this lookahead once per ``window`` tokens through the same cached block
    plan as prefill — no per-token transforms.

    ``Hr``/``Hi`` must be :func:`filter_spectrum` planes at ``block``; the
    kept outputs are exact (no circular contamination) for any
    ``tail``/``window`` because only positions ≥ ``len(tail)`` are kept.
    """
    lead = tail.shape[:-1]
    zeros = jnp.zeros((*lead, window), jnp.float32)
    xin = jnp.concatenate([tail.astype(jnp.float32), zeros], axis=-1)
    return _stream_conv(
        xin, Hr, Hi, block=block, overlap=tail.shape[-1], backend=backend
    )


class StreamingConv:
    """Chunked causal convolution with the overlap tail as explicit state.

    The streaming form of :func:`fft_conv_os` for serving decode and SAR
    strip ingest: the only cross-chunk dependency of a causal conv is the
    last ``Lh − 1`` input samples, carried as a state array so the object
    itself stays immutable (scan/jit-friendly — state in, state out).
    Chunked calls compose to the one-shot result for any chunking,
    including ragged final chunks and chunks shorter than the filter::

        sc = StreamingConv(h)
        state = sc.init_state(x.shape[:-1])
        y1, state = sc(x[..., :4096], state)
        y2, state = sc(x[..., 4096:], state)
        # concat([y1, y2]) == fft_conv_os(x, h)

    Every chunk reuses the same cached block-plan pair (the block size is
    fixed by the filter at construction) AND the filter spectrum computed
    here once — per-chunk work is the chunk's own frames only.

    With ``block=None`` the block is tuned like :func:`fft_conv_os`'s
    (``tune=`` modes, persistent cache); ``chunk_hint`` is the expected
    per-call chunk length.  When given, the tuner keys the decision to that
    decode grain and its measurement pass times chunked streaming calls
    (state + chunk in) rather than one long ingest — serving decode and
    strip ingest genuinely prefer different blocks (chunks shorter than the
    heuristic block waste the unfilled step on every call).  Without a hint
    the measurement uses a long-ingest stand-in of 8 heuristic blocks.

    ``spmd=True`` makes the block pick cache- and measurement-free
    (:func:`repro.core.tuning.modeled_block`): every host of a
    multi-process mesh derives the identical block from the shape alone,
    so a ``StreamingConv`` built inside per-host setup code stays safe to
    close over in a ``shard_map`` program.  A per-host cache hit or timing
    run could diverge across hosts and desynchronize collective shapes —
    the same rule :func:`repro.core.distributed.pconv_os_sharded` follows.
    """

    def __init__(
        self,
        h: jax.Array,
        *,
        block: Optional[int] = None,
        backend: Optional[str] = None,
        tune: Optional[str] = None,
        chunk_hint: Optional[int] = None,
        spmd: bool = False,
    ):
        self.h = jnp.asarray(h, jnp.float32)
        self.filter_len = int(self.h.shape[-1])
        self.overlap = self.filter_len - 1
        self.chunk_hint = chunk_hint
        L_tune = chunk_hint or 8 * pick_block(self.filter_len)
        if spmd and block is None:
            from repro.core import tuning  # lazy: tuning measures through here

            self.block = tuning.modeled_block(
                L_tune, self.filter_len, 1, backend, chunk=chunk_hint
            )
        else:
            self.block = _resolve_block(
                self.filter_len, block, L_tune, 1, backend, tune, chunk=chunk_hint
            )
        self.backend = backend
        self._Hr, self._Hi = filter_spectrum(self.h, self.block, backend)

    def init_state(self, lead: tuple = (), dtype=jnp.float32) -> jax.Array:
        """Zero history: ``(*lead, Lh − 1)``.  ``lead`` must broadcast like
        the chunks' leading dims (e.g. ``(batch, channels)``)."""
        return jnp.zeros((*tuple(lead), self.overlap), dtype)

    def __call__(self, x: jax.Array, state: jax.Array) -> tuple:
        """Convolve one chunk; returns ``(y, new_state)`` with ``y`` the
        causal output for exactly this chunk's samples."""
        x = jnp.asarray(x)
        out_dtype = x.dtype
        if state.shape[-1] != self.overlap:
            raise faults.PlanError(
                f"state carries {state.shape[-1]} samples, filter needs "
                f"{self.overlap}"
            )
        xin = jnp.concatenate(
            [state.astype(jnp.float32), x.astype(jnp.float32)], axis=-1
        )
        # The first ``overlap`` outputs re-derive samples the previous chunk
        # already emitted; _stream_conv keeps only this chunk's contribution
        # (single padded frame when state + chunk fit one block).
        y = _stream_conv(
            xin,
            self._Hr,
            self._Hi,
            block=self.block,
            overlap=self.overlap,
            backend=self.backend,
        )
        new_state = (
            xin[..., xin.shape[-1] - self.overlap :]
            if self.overlap
            else xin[..., :0]
        )
        return y.astype(out_dtype), new_state

    def lookahead(self, state: jax.Array, window: int) -> jax.Array:
        """History-only outputs for the next ``window`` positions — what the
        stream would emit if the next ``window`` samples were zero.  The
        decode-grain flush primitive; see :func:`stream_lookahead`."""
        if state.shape[-1] != self.overlap:
            raise faults.PlanError(
                f"state carries {state.shape[-1]} samples, filter needs "
                f"{self.overlap}"
            )
        return stream_lookahead(
            state,
            self._Hr,
            self._Hi,
            window=window,
            block=self.block,
            backend=self.backend,
        )
