"""Typed failures, deterministic fault injection, and per-process quarantine.

Production FFT serving (the paper's remote-sensing pitch) cannot afford a
process death every time a kernel refuses to compile on an unknown
device_kind or a tuning-cache file is half-written.  This module is the
single home for everything the engine does *on purpose* when something
goes wrong:

``ReproError`` taxonomy
    Every user-facing error the engine raises derives from ``ReproError``
    and carries the failing context (fault ``site``, ``spec``, ``backend``,
    ``pass_kind``, plus free-form keys) as attributes, formatted into the
    message.  Subclasses multiply inherit from the builtin exception the
    pre-taxonomy code raised (``PlanError`` is a ``ValueError``,
    ``ServeError`` is both a ``ValueError`` and a ``RuntimeError``, ...)
    so ``except ValueError`` call sites keep working.

Fault-injection registry
    A fixed set of named ``SITES`` is compiled into the hot paths via
    ``maybe_fail(site, **context)`` — a no-op unless the site is armed.
    Arm sites deterministically with the ``inject_fault(site, times=...)``
    context manager (tests) or the ``REPRO_FAULTS=site[:times],site2``
    environment variable (CI chaos jobs / ops drills).  A fired site
    raises that site's typed error with ``injected=True``.

Quarantine + degradation ledger
    ``run_leaf`` wraps a claimed pallas/pallas_gpu leaf: one retry on
    failure, then the failing ``(backend, pass-kind)`` pair is quarantined
    for the rest of the process and the leaf executes through its traced
    XLA fallback.  Each demotion is recorded on the owning plan's
    ``degradations`` list and in a process-global ledger surfaced by
    ``ServeSession.health()``.

Everything here is host-side Python: injection fires at trace time, never
inside a jitted computation, so the no-fault jaxpr is byte-identical to a
build without this module in the loop.
"""

from __future__ import annotations

import collections
import contextlib
import os
import threading
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "ReproError",
    "PlanError",
    "KernelError",
    "TuningCacheError",
    "CollectiveError",
    "ServeError",
    "NumericsError",
    "SITES",
    "inject_fault",
    "maybe_fail",
    "arm_env_faults",
    "fault_counters",
    "clear_faults",
    "quarantine",
    "is_quarantined",
    "quarantined",
    "clear_quarantine",
    "record_degradation",
    "degradation_log",
    "clear_degradations",
    "run_leaf",
]


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


class ReproError(Exception):
    """Base of every typed error the engine raises on purpose.

    Context (``site`` / ``spec`` / ``backend`` / ``pass_kind`` and any
    extra keyword pairs) is kept as attributes and appended to the
    message so a bare traceback names the failing plan, not just a line.
    """

    def __init__(
        self,
        message: str = "",
        *,
        site: Optional[str] = None,
        spec=None,
        backend: Optional[str] = None,
        pass_kind: Optional[str] = None,
        injected: bool = False,
        **context,
    ):
        self.site = site
        self.spec = spec
        self.backend = backend
        self.pass_kind = pass_kind
        self.injected = injected
        self.context = dict(context)
        bits = []
        for key, val in (
            ("site", site),
            ("spec", spec),
            ("backend", backend),
            ("pass", pass_kind),
        ):
            if val is not None:
                bits.append(f"{key}={val!r}" if not isinstance(val, str) else f"{key}={val}")
        bits.extend(f"{k}={v!r}" for k, v in self.context.items())
        if injected:
            bits.append("injected")
        super().__init__(message + (f" [{', '.join(bits)}]" if bits else ""))


class PlanError(ReproError, ValueError):
    """Invalid spec, unknown backend, failed negotiation, bad plan input."""


class KernelError(ReproError, RuntimeError):
    """A claimed pallas leaf failed to trace/compile/launch."""


class TuningCacheError(ReproError, RuntimeError):
    """The persistent tuning cache could not be read or written."""


class CollectiveError(ReproError, RuntimeError):
    """A pencil collective (all-to-all) failed."""


class ServeError(ReproError, ValueError, RuntimeError):
    """A serve phase failed or a request was rejected (backpressure)."""


class NumericsError(ReproError, ArithmeticError):
    """An opt-in numerics guard (check="nan"/"parseval") tripped."""


# ---------------------------------------------------------------------------
# fault-injection registry
# ---------------------------------------------------------------------------

#: The named sites compiled into the engine.  Arming any other name is a
#: PlanError — chaos configs fail fast instead of silently never firing.
SITES: Tuple[str, ...] = (
    "kernel.launch",
    "tuning.cache_read",
    "tuning.cache_write",
    "pencil.all_to_all",
    "serve.prefill",
    "serve.insert",
    "serve.generate",
)

_SITE_EXC: Dict[str, type] = {
    "kernel.launch": KernelError,
    "tuning.cache_read": TuningCacheError,
    "tuning.cache_write": TuningCacheError,
    "pencil.all_to_all": CollectiveError,
    "serve.prefill": ServeError,
    "serve.insert": ServeError,
    "serve.generate": ServeError,
}

_LOCK = threading.Lock()
_ARMED: Dict[str, dict] = {}
_FIRED: collections.Counter = collections.Counter()
_ENV_PARSED = False


def _check_site(site: str) -> None:
    if site not in SITES:
        raise PlanError(
            f"unknown fault site {site!r}; registered sites: {', '.join(SITES)}"
        )


def arm_env_faults(force: bool = False) -> None:
    """Parse ``REPRO_FAULTS`` (comma list of ``site`` or ``site:times``).

    Runs once lazily on the first ``maybe_fail``; ``force=True`` re-reads
    the environment (tests).
    """
    global _ENV_PARSED
    if _ENV_PARSED and not force:
        return
    _ENV_PARSED = True
    raw = os.environ.get("REPRO_FAULTS", "")
    for item in (s.strip() for s in raw.split(",")):
        if not item:
            continue
        site, _, times = item.partition(":")
        _check_site(site)
        n = int(times) if times else 1
        with _LOCK:
            _ARMED[site] = {"remaining": n, "exc": _SITE_EXC[site]}


@contextlib.contextmanager
def inject_fault(site: str, *, times: int = 1, exc: Optional[type] = None):
    """Arm ``site`` to raise its typed error the next ``times`` hits.

    Deterministic: exactly the next ``times`` executions of the site fail,
    then the site reverts to whatever arming it had before the block.
    """
    _check_site(site)
    with _LOCK:
        prev = _ARMED.get(site)
        _ARMED[site] = {"remaining": times, "exc": exc or _SITE_EXC[site]}
    try:
        yield
    finally:
        with _LOCK:
            if prev is None:
                _ARMED.pop(site, None)
            else:
                _ARMED[site] = prev


def maybe_fail(site: str, **context) -> None:
    """The hook compiled into each fault site.  No-op unless armed."""
    arm_env_faults()
    if site not in _ARMED:  # fast path: plain dict probe, no lock
        return
    with _LOCK:
        armed = _ARMED.get(site)
        if not armed or armed["remaining"] <= 0:
            return
        armed["remaining"] -= 1
        _FIRED[site] += 1
        exc = armed["exc"]
    raise exc(f"injected fault at {site}", site=site, injected=True, **context)


def fault_counters() -> Dict[str, int]:
    """How many times each site has fired (injected faults only)."""
    return dict(_FIRED)


def clear_faults() -> None:
    """Disarm every site and zero the fired counters (tests)."""
    global _ENV_PARSED
    with _LOCK:
        _ARMED.clear()
        _FIRED.clear()
        _ENV_PARSED = True  # a cleared state stays cleared; force re-arm explicitly


# ---------------------------------------------------------------------------
# per-process quarantine of failing (backend, pass-kind) pairs
# ---------------------------------------------------------------------------

_QUARANTINED: Dict[Tuple[str, str], str] = {}


def quarantine(backend: str, kind: str, reason: str = "") -> None:
    """Stop attempting pallas leaves of ``kind`` on ``backend`` this process."""
    with _LOCK:
        _QUARANTINED.setdefault((backend, kind), reason)


def is_quarantined(backend: str, kind: str) -> bool:
    return (backend, kind) in _QUARANTINED


def quarantined() -> Tuple[Tuple[str, str], ...]:
    """Sorted (backend, pass-kind) pairs currently quarantined."""
    return tuple(sorted(_QUARANTINED))


def clear_quarantine() -> None:
    with _LOCK:
        _QUARANTINED.clear()


# ---------------------------------------------------------------------------
# degradation ledger
# ---------------------------------------------------------------------------

DEGRADATION_LOG_MAX = 256
_DEGRADATIONS: collections.deque = collections.deque(maxlen=DEGRADATION_LOG_MAX)


def record_degradation(
    sink: Optional[list],
    *,
    backend: str,
    kind: str,
    index: Optional[int] = None,
    reason: str = "",
) -> None:
    """Record one leaf demotion on the plan's ledger and the global one.

    Deduplicated by (backend, kind, index) so jit retraces of the same
    plan don't multiply entries.
    """
    rec = {"backend": backend, "kind": kind, "pass": index, "reason": reason}
    key = (backend, kind, index)

    def _has(entries) -> bool:
        return any((r["backend"], r["kind"], r["pass"]) == key for r in entries)

    with _LOCK:
        if sink is not None and not _has(sink):
            sink.append(rec)
        if not _has(_DEGRADATIONS):
            _DEGRADATIONS.append(rec)


def degradation_log() -> Tuple[dict, ...]:
    """Process-global record of every leaf demotion (bounded)."""
    return tuple(_DEGRADATIONS)


def clear_degradations() -> None:
    with _LOCK:
        _DEGRADATIONS.clear()


def run_leaf(
    backend: str,
    kind: str,
    attempt: Callable[[], tuple],
    fallback: Callable[[], tuple],
    *,
    degradations: Optional[list] = None,
    index: Optional[int] = None,
):
    """Execute one claimed pallas leaf with retry → quarantine → fallback.

    The happy path is ``attempt()`` guarded only by host-side Python — a
    dict probe and a try — so the traced jaxpr is identical to calling
    ``attempt()`` directly.  On failure the leaf is retried once (a fault
    armed with ``times=1`` recovers here with no degradation); a second
    failure quarantines ``(backend, kind)`` for the process, records the
    demotion, and runs ``fallback()`` — the traced XLA execution of the
    same pass, numerically equivalent at float32 tolerance.
    """
    if is_quarantined(backend, kind):
        record_degradation(
            degradations, backend=backend, kind=kind, index=index, reason="quarantined"
        )
        return fallback()
    try:
        maybe_fail("kernel.launch", backend=backend, pass_kind=kind)
        return attempt()
    except NotImplementedError:
        raise  # a contract gate, not a kernel failure — never demote it
    except Exception:
        try:
            maybe_fail("kernel.launch", backend=backend, pass_kind=kind)
            return attempt()
        except NotImplementedError:
            raise
        except Exception as err:  # second strike: demote this leaf for good
            reason = f"{type(err).__name__}: {err}"
            quarantine(backend, kind, reason)
            record_degradation(
                degradations, backend=backend, kind=kind, index=index, reason=reason
            )
            return fallback()
