"""Distributed pencil FFT — the paper's hierarchy lifted to the pod level.

On a single chip the paper's schedule bounds HBM↔on-chip round trips; across
a TPU pod the analogous slow tier is ICI, and the analogous schedule bounds
**all-to-all transposes**.  A length-N transform sharded over D devices is
factored N = N1 · N2 (both divisible by D) and executed as:

    a2a-transpose → local FFT(N1) → twiddle → a2a-transpose → local FFT(N2)
    [→ a2a-transpose for natural output order]

Every local FFT executes a per-leaf :class:`~repro.core.fft.PlannedFFT` (one
frozen plan per pencil factor, fused kernels on TPU), and the per-device
twiddle slab is generated with traced iota from
``lax.axis_index`` — no device ever materialises another shard's table.

Beyond-paper optimisation (recorded in EXPERIMENTS.md §Perf): with
``natural_order=False`` the spectrum stays in "k1-major" pencil layout and the
inverse consumes it directly, so an fft→pointwise→ifft round trip (the
long-conv pattern) costs **4** all-to-alls instead of 6.

These functions use raw ``jax.lax`` collectives and must run inside a
``shard_map`` body (or under jit with the axis bound); :func:`pfft_sharded`
is the standalone convenience wrapper.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fft as fft_lib
from repro.core import plan as plan_lib
from repro.core import twiddle as tw
from repro.core.fft_xla import cmul

Planes = Tuple[jax.Array, jax.Array]

__all__ = [
    "pfft",
    "pifft",
    "pencil_factors",
    "pfft_sharded",
    "pifft_sharded",
    "pconv_os_sharded",
    "shard_map_compat",
]


def _leaf_plan(
    n: int, inverse: bool, backend: str | None, axis: int = -1
) -> "fft_lib.PlannedFFT":
    """Per-leaf :class:`PlannedFFT` for the local pencil transforms.

    Each pencil factor gets its own plan (cached by spec), so the local
    length-n1 and length-n2 passes reuse frozen schedules and LUTs instead of
    re-dispatching on a backend string per call.  ``axis=-2`` plans are the
    column passes of the pass program: axis-capable backends (pallas, xla)
    execute them in place over the strided view — the hand-rolled
    swapaxes sandwiches this driver used to carry are gone.
    """
    return fft_lib.plan(
        fft_lib.FFTSpec(n=n, kind="ifft" if inverse else "fft", axis=axis),
        backend=backend,
    )


def pencil_factors(n: int, d: int) -> tuple[int, int]:
    """Split n = n1 · n2 (powers of two), both divisible by d, near-square."""
    n1, n2 = plan_lib.balanced_split(n)
    while n1 % d and n2 >= d * 2:
        n1 *= 2
        n2 //= 2
    if n1 % d or n2 % d:
        raise ValueError(f"cannot pencil-split n={n} over {d} devices")
    return n1, n2


def _local_twiddle(n1: int, n2: int, q: int, axis_name: str, inverse: bool):
    """Twiddle slab T[k1, n2] for this device's n2 ∈ [d·q, (d+1)·q).

    Delegates to :func:`repro.core.twiddle.traced_twiddle`'s column window:
    with x64 disabled (the default) the int64 iotas this used to build
    silently downcast to int32 and the ``(k1·m2) % n`` reduction overflowed
    for n > 2³¹ — the huge-N regime pencil FFTs exist for.
    """
    d = jax.lax.axis_index(axis_name)
    return tw.traced_twiddle(n1, n2, inverse, col_start=d * q, col_count=q)


def _a2a(x, axis_name, split_axis, concat_axis):
    return jax.lax.all_to_all(
        x, axis_name, split_axis=split_axis, concat_axis=concat_axis, tiled=True
    )


def pfft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    n: int,
    axis_name: str,
    num_shards: int,
    inverse: bool = False,
    natural_order: bool = True,
    backend: str | None = None,
) -> Planes:
    """Distributed FFT over the last axis; call inside shard_map.

    ``xr/xi``: local shard (..., n // num_shards) of the globally length-``n``
    signal, contiguous (block) sharding.  Returns the local output shard.
    With ``natural_order=False`` the output is in pencil (k1-major) layout:
    global flat index k1·n2 + k2 holds X[k1 + n1·k2].
    """
    d = num_shards
    n1, n2 = pencil_factors(n, d)
    p, q = n1 // d, n2 // d
    lead = xr.shape[:-1]
    la = len(lead)  # number of leading batch axes

    # Per-leaf plans: the n1 and n2 local passes each reuse a frozen
    # schedule.  n1 is a column pass (axis -2) straight out of the program —
    # executed in place over the strided view, no swapaxes glue.
    plan_n1 = _leaf_plan(n1, inverse, backend, axis=-2)
    plan_n2 = _leaf_plan(n2, inverse, backend)

    # Local shard is rows [d·p, (d+1)·p) of the (n1, n2) matrix.
    xr = xr.reshape(*lead, p, n2)
    xi = xi.reshape(*lead, p, n2)
    # (1) a2a transpose → full columns n2 ∈ [d·q, (d+1)·q): (n1, q)
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    # (2) FFT over n1 (axis -2): in-place column pass.
    xr, xi = plan_n1.apply_planes(xr, xi)
    # (3) twiddle in (n1, q) layout.
    twr, twi = _local_twiddle(n1, n2, q, axis_name, inverse)  # (n1, q)
    xr, xi = cmul(xr, xi, twr, twi)
    # (4) a2a transpose back → full rows k1 ∈ [d·p, (d+1)·p): (n1, q) → (p, n2)
    xr = _a2a(xr, axis_name, la, la + 1)
    xi = _a2a(xi, axis_name, la, la + 1)
    # after split on rows (n1 → d·p) and concat on cols: (p, n2) with full rows.
    # (5) FFT over n2 (last axis, local).  (For inverse=True the two leaf
    # transforms already contribute 1/n1 · 1/n2 = 1/n scaling.)
    xr, xi = plan_n2.apply_planes(xr, xi)
    if not natural_order:
        return xr.reshape(*lead, p * n2), xi.reshape(*lead, p * n2)
    # (6) a2a transpose → natural order: C (p, n2) → C^T slab (q2, n1).
    q2 = n2 // d
    xr = _a2a(xr, axis_name, la + 1, la)  # (n1, q2): C columns slab
    xi = _a2a(xi, axis_name, la + 1, la)
    xr = jnp.swapaxes(xr, -1, -2)  # (q2, n1) = C^T rows = natural order
    xi = jnp.swapaxes(xi, -1, -2)
    return xr.reshape(*lead, q2 * n1), xi.reshape(*lead, q2 * n1)


def pifft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    n: int,
    axis_name: str,
    num_shards: int,
    from_pencil: bool = False,
    backend: str | None = None,
) -> Planes:
    """Distributed inverse FFT.

    With ``from_pencil=True`` consumes the k1-major layout produced by
    ``pfft(..., natural_order=False)`` using the mirrored schedule (no extra
    reordering collective).
    """
    d = num_shards
    n1, n2 = pencil_factors(n, d)
    p, q = n1 // d, n2 // d
    lead = xr.shape[:-1]
    la = len(lead)

    plan_n1 = _leaf_plan(n1, inverse=True, backend=backend, axis=-2)
    plan_n2 = _leaf_plan(n2, inverse=True, backend=backend)

    if not from_pencil:
        # Natural order: device holds C^T rows (q, n1); transpose to pencil.
        xr = xr.reshape(*lead, q, n1)
        xi = xi.reshape(*lead, q, n1)
        xr = _a2a(xr, axis_name, la + 1, la)  # (n2, p): wait -> see note
        xi = _a2a(xi, axis_name, la + 1, la)
        # now (n2·? ) — split n1 cols into d pieces of p, concat rows: (d·q, p)
        # device holds C^T full columns k1 ∈ slab → transpose to C rows slab.
        xr = jnp.swapaxes(xr, -1, -2)  # (p, n2)
        xi = jnp.swapaxes(xi, -1, -2)
    else:
        xr = xr.reshape(*lead, p, n2)
        xi = xi.reshape(*lead, p, n2)
    # Mirror of pfft: inverse FFT over n2 (rows, local)...
    xr, xi = plan_n2.apply_planes(xr, xi)
    # a2a to column slabs: (p, n2) → (n1, q)
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    # conjugate twiddle, then inverse FFT over n1 (in-place column pass).
    twr, twi = _local_twiddle(n1, n2, q, axis_name, inverse=True)  # (n1, q)
    xr, xi = cmul(xr, xi, twr, twi)
    xr, xi = plan_n1.apply_planes(xr, xi)  # (n1, q), axis -2
    # back to block layout over the original axis: (n1, q) → (p, n2) rows.
    xr = _a2a(xr, axis_name, la, la + 1)  # (p, n2)
    xi = _a2a(xi, axis_name, la, la + 1)
    return xr.reshape(*lead, p * n2), xi.reshape(*lead, p * n2)


def pfft2d(
    xr: jax.Array,
    xi: jax.Array,
    *,
    n1: int,
    n2: int,
    axis_name: str,
    num_shards: int,
    inverse: bool = False,
    backend: str | None = None,
) -> Planes:
    """Distributed 2-D FFT (SAR range/azimuth): rows local, columns pencil.

    xr/xi: local shard (..., n1 // D, n2) of a (n1, n2) image, rows sharded
    over ``axis_name``.  Each shard consumes ONE joint 2-D plan
    (``FFTSpec(kind='fft2')`` — the same compiled rows+columns program the
    single-chip path runs) split around the collectives: the row passes run
    on the row-sharded slab, then one all-to-all transpose, the in-place
    column passes on the column slab, and the transpose back — 2 all-to-alls
    per direction (the 2-D analogue of the paper's two-exchange schedule).
    """
    del num_shards  # the joint plan is shard-count-agnostic (slab widths vary)
    lead = xr.shape[:-2]
    la = len(lead)

    joint = fft_lib.plan(
        fft_lib.FFTSpec(n=n2, kind="ifft2" if inverse else "fft2", n2=n1),
        backend=backend,
    )

    # (1) row passes of the joint program over n2 — local and contiguous.
    xr, xi = joint.apply_rows(xr, xi)
    # (2) a2a transpose: (p, n2) → (n1, q) column slabs.
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    # (3) column passes over n1 — in place down axis -2 of the (n1, q) slab.
    xr, xi = joint.apply_cols(xr, xi)
    # (4) a2a back to row slabs (p, n2).
    xr = _a2a(xr, axis_name, la, la + 1)
    xi = _a2a(xi, axis_name, la, la + 1)
    return xr, xi


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """Version-tolerant shard_map: ``jax.shard_map``/``check_vma`` on new JAX,
    ``jax.experimental.shard_map``/``check_rep`` on older releases (including
    the window where ``jax.shard_map`` exists but still takes ``check_rep``)."""
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm

        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def _shard_wrap(fn, mesh: Mesh, axis: str):
    def wrapper(xr, xi, **kw):
        nbatch = xr.ndim - 1
        pspec = P(*([None] * nbatch + [axis]))
        f = functools.partial(fn, axis_name=axis, **kw)
        return shard_map_compat(
            f, mesh, in_specs=(pspec, pspec), out_specs=(pspec, pspec)
        )(xr, xi)

    return wrapper


def pfft_sharded(
    xr, xi, mesh: Mesh, axis: str, *, inverse=False, natural_order=True, backend=None
):
    """Standalone distributed FFT: shards the last axis over ``mesh[axis]``."""
    n = xr.shape[-1]
    d = mesh.shape[axis]
    return _shard_wrap(pfft, mesh, axis)(
        xr,
        xi,
        n=n,
        num_shards=d,
        inverse=inverse,
        natural_order=natural_order,
        backend=backend,
    )


def pifft_sharded(xr, xi, mesh: Mesh, axis: str, *, from_pencil=False, backend=None):
    n = xr.shape[-1]
    d = mesh.shape[axis]
    return _shard_wrap(pifft, mesh, axis)(
        xr, xi, n=n, num_shards=d, from_pencil=from_pencil, backend=backend
    )


def pconv_os_sharded(
    x: jax.Array,
    h: jax.Array,
    mesh: Mesh,
    axis: str,
    *,
    causal: bool = True,
    block: int | None = None,
    backend: str | None = None,
    tune: str | None = None,
) -> jax.Array:
    """Distributed overlap-save convolution: blocks sharded over ``mesh[axis]``.

    The overlap-save blocks of :func:`repro.core.overlap.fft_conv_os` are
    embarrassingly parallel — every block carries its own ``Lh − 1`` history
    in the overlapping frame — so the convolution shards over the *block*
    axis with ``shard_map`` and pays **zero** all-to-alls, versus the 4 of
    the pencil ``pfft → ⊙H → pifft`` path (and its transforms stay in the
    fused one-round-trip regime, where the pencil leaves may not).

    ``x``: (..., L) replicated input; ``h`` broadcasts like ``fft_conv``.
    The block count is padded up to a multiple of the mesh axis size with
    zero frames (their outputs fall past ``L_out`` and are sliced away).
    Returns the (..., L) causal output (or L + Lh − 1 with
    ``causal=False``), replicated — the framing gather and tail scatter run
    outside the ``shard_map`` body.

    Block tuning here is DETERMINISTIC by construction: with ``block=None``
    and ``tune`` ≠ "off" the block is the pure roofline pick
    (:func:`repro.core.tuning.modeled_block`) — never a cache hit or a
    measurement, which could differ across the hosts of a multi-process
    mesh and desynchronize the shard_map program.  To use a measured
    winner, tune on one host (``tuning.tuned_block(..., "measure")``) and
    pass the result as ``block=`` explicitly.
    """
    from repro.core import overlap as ov  # lazy: distributed loads before overlap at package init
    from repro.core import tuning

    x = jnp.asarray(x)
    out_dtype = x.dtype
    x = x.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    d = mesh.shape[axis]
    L, Lh = x.shape[-1], h.shape[-1]
    batch = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    if block is not None:
        B = ov.pick_block(Lh, block)
    elif tuning.resolve_mode(tune) == "off" or Lh < 2:
        B = ov.pick_block(Lh)
    else:
        B = tuning.modeled_block(L, Lh, batch, backend)
    overlap = Lh - 1
    step = B - overlap
    L_out = L if causal else L + Lh - 1
    nb = -(-L_out // step)
    nb = -(-nb // d) * d  # whole blocks per shard; extras are zero frames
    frames = ov.frame_signal(x, B, step, nb)
    Hr, Hi = ov.filter_spectrum(h, B, backend)  # computed once, replicated
    fspec = P(*([None] * (frames.ndim - 2)), axis, None)

    def body(fr, hr, hi):
        return ov.conv_frames(fr, hr, hi, overlap=overlap, backend=backend)

    tails = shard_map_compat(
        body, mesh, in_specs=(fspec, P(), P()), out_specs=fspec
    )(frames, Hr, Hi)
    lead = tails.shape[:-2]
    y = tails.reshape(*lead, nb * step)[..., :L_out]
    return y.astype(out_dtype)
