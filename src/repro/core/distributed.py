"""Distributed pencil FFT — the paper's hierarchy lifted to the pod level.

On a single chip the paper's schedule bounds HBM↔on-chip round trips; across
a TPU pod the analogous slow tier is ICI, and the analogous schedule bounds
**all-to-all transposes**.  A length-N transform sharded over D devices is
factored N = N1 · N2 (both divisible by D) and executed as:

    a2a-transpose → local FFT(N1) → twiddle → a2a-transpose → local FFT(N2)
    [→ a2a-transpose for natural output order]

Every local FFT executes a per-leaf :class:`~repro.core.fft.PlannedFFT` (one
frozen plan per pencil factor, fused kernels on TPU), and the per-device
twiddle slab is generated with traced iota from
``lax.axis_index`` — no device ever materialises another shard's table.

The pencil path is a *planned, tuned, overlapped* pipeline:

* **Packed collectives** — the split-complex ``(xr, xi)`` pair rides ONE
  stacked ``all_to_all`` per transpose (the distributed analogue of the
  rfft even/odd packing): 3 collectives for a natural-order forward, not
  the 6 the per-plane path paid.  ``pack=False`` keeps the historical
  serial path for A/B benchmarking.
* **Chunk-overlapped transposes** — the two inner all-to-alls are
  strip-mined into ``K`` column chunks, double-buffered so chunk *i*'s
  transpose is in flight while chunk *i−1* runs its local column FFT +
  twiddle (``lax`` slicing inside the ``shard_map`` body; XLA's async
  collectives overlap the wire with the compute).  ``K`` is a tuned
  decision.
* **Plan layer** — :func:`plan_pencil` resolves the tuned decisions
  (factor balance, K, packing — :func:`repro.core.tuning.pencil_config`,
  modeled-only so every SPMD host agrees deterministically) into a cached
  :class:`PencilPlan` whose :meth:`~PencilPlan.describe` prints the pencil
  schedule (factors, collective count, modeled comm MB) exactly like
  single-device plan handles do.
* **Degenerate meshes** — with one shard the pencil path collapses to the
  local single-chip plan: zero collectives in the program (jaxpr-asserted
  in the tests), and ``natural_order=False``/``from_pencil=True`` keep
  their k1-major layout semantics via a purely local four-step.

Beyond-paper optimisation (recorded in EXPERIMENTS.md §Perf): with
``natural_order=False`` the spectrum stays in "k1-major" pencil layout and
the inverse consumes it directly, so an fft→pointwise→ifft round trip (the
long-conv pattern) costs **2** packed all-to-alls instead of the natural
path's 6.

These functions use raw ``jax.lax`` collectives and must run inside a
``shard_map`` body (or under jit with the axis bound); :func:`pfft_sharded`
is the standalone convenience wrapper.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import faults
from repro.core import fft as fft_lib
from repro.core import plan as plan_lib
from repro.core import twiddle as tw
from repro.core.fft_xla import cmul

Planes = Tuple[jax.Array, jax.Array]

__all__ = [
    "pfft",
    "pifft",
    "pencil_factors",
    "PencilPlan",
    "plan_pencil",
    "pfft_sharded",
    "pifft_sharded",
    "pfft2d",
    "pconv_os_sharded",
    "shard_map_compat",
]


def _leaf_plan(
    n: int, inverse: bool, backend: str | None, axis: int = -1
) -> "fft_lib.PlannedFFT":
    """Per-leaf :class:`PlannedFFT` for the local pencil transforms.

    Each pencil factor gets its own plan (cached by spec), so the local
    length-n1 and length-n2 passes reuse frozen schedules and LUTs instead of
    re-dispatching on a backend string per call.  ``axis=-2`` plans are the
    column passes of the pass program: axis-capable backends (pallas, xla)
    execute them in place over the strided view — the hand-rolled
    swapaxes sandwiches this driver used to carry are gone.
    """
    return fft_lib.plan(
        fft_lib.FFTSpec(n=n, kind="ifft" if inverse else "fft", axis=axis),
        backend=backend,
    )


def pencil_factors(n: int, d: int) -> tuple[int, int]:
    """Split n = n1 · n2 (powers of two), both divisible by d, near-square."""
    n1, n2 = plan_lib.balanced_split(n)
    while n1 % d and n2 >= d * 2:
        n1 *= 2
        n2 //= 2
    if n1 % d or n2 % d:
        raise faults.PlanError(f"cannot pencil-split n={n} over {d} devices")
    return n1, n2


def _local_twiddle(n1: int, n2: int, q: int, axis_name: str, inverse: bool):
    """Twiddle slab T[k1, n2] for this device's n2 ∈ [d·q, (d+1)·q).

    Delegates to :func:`repro.core.twiddle.traced_twiddle`'s column window:
    with x64 disabled (the default) the int64 iotas this used to build
    silently downcast to int32 and the ``(k1·m2) % n`` reduction overflowed
    for n > 2³¹ — the huge-N regime pencil FFTs exist for.
    """
    d = jax.lax.axis_index(axis_name)
    return tw.traced_twiddle(n1, n2, inverse, col_start=d * q, col_count=q)


def _a2a(x, axis_name, split_axis, concat_axis):
    faults.maybe_fail(
        "pencil.all_to_all", axis_name=axis_name, split_axis=split_axis
    )
    return jax.lax.all_to_all(
        x, axis_name, split_axis=split_axis, concat_axis=concat_axis, tiled=True
    )


# ---------------------------------------------------------------------------
# Plan layer: PencilPlan / plan_pencil
# ---------------------------------------------------------------------------


class PencilPlan:
    """The frozen schedule of one distributed pencil transform.

    The pencil analogue of :class:`~repro.core.fft.PlannedFFT`: factors,
    packing, chunk count and the per-leaf local plans are resolved ONCE
    (through :func:`repro.core.tuning.pencil_config` — modeled-only, so
    every host of an SPMD mesh derives the identical schedule) and reused
    by every ``pfft``/``pifft`` call of the same shape.  ``describe()``
    prints the schedule with modeled comm MB next to it, like the
    single-device handles.
    """

    def __init__(
        self,
        n: int,
        d: int,
        *,
        inverse: bool,
        backend: Optional[str],
        config: dict,
        natural_order: bool = True,
    ):
        from repro.analysis import roofline as rl  # lazy: analysis layer

        self.n, self.d, self.inverse = n, d, inverse
        self.backend = backend
        self.n1, self.n2 = int(config["n1"]), int(config["n2"])
        if self.n1 * self.n2 != n:
            raise faults.PlanError(f"pencil factors {self.n1}x{self.n2} != n={n}")
        if d > 1 and (self.n1 % d or self.n2 % d):
            raise faults.PlanError(
                f"pencil factors {self.n1}x{self.n2} not divisible by d={d}"
            )
        self.p = self.n1 // max(d, 1)
        self.q = self.n2 // max(d, 1)
        self.pack = bool(config.get("pack", True))
        k = int(config.get("a2a_chunks", 1))
        # K must divide the per-device column count — clamp a foreign or
        # hand-written config rather than fail the transform.
        while k > 1 and (k > self.q or self.q % k):
            k //= 2
        self.a2a_chunks = k if self.pack else 1
        self.tuned = dict(config)
        self.plan_n1 = _leaf_plan(self.n1, inverse, backend, axis=-2)
        self.plan_n2 = _leaf_plan(self.n2, inverse, backend)
        #: d == 1 natural order collapses to the single-chip program.
        self.local_plan = (
            _leaf_plan(n, inverse, backend) if d <= 1 else None
        )
        self.report = rl.pencil_report(
            n,
            d,
            n1=self.n1,
            n2=self.n2,
            pack=self.pack,
            chunks=self.a2a_chunks,
            natural_order=natural_order,
        )

    def a2a_count(self, natural_order: bool = True) -> int:
        """Collectives one transform emits (what the jaxpr tests assert)."""
        if self.d <= 1:
            return 0
        if self.pack:
            return 2 * self.a2a_chunks + (1 if natural_order else 0)
        return 2 * (3 if natural_order else 2)

    def describe(self) -> str:
        kind = "pifft" if self.inverse else "pfft"
        mb = self.report["comm_bytes_per_step"] / 2**20
        local_mb = self.report["local_hbm_bytes"] / 2**20
        head = (
            f"{kind} N={self.n} over d={self.d}: factors {self.n1}x{self.n2} "
            f"(p={self.p}, q={self.q}); "
        )
        if self.d <= 1:
            sched = "collapses to the local plan, 0 collectives"
        else:
            sched = (
                f"{'packed' if self.pack else 'split-plane'} a2a x"
                f"{self.a2a_count(True)} natural / x{self.a2a_count(False)} "
                f"pencil (K={self.a2a_chunks}); comm {mb:.2f} MB/step"
            )
        lines = [head + sched + f"; local HBM {local_mb:.2f} MB"]
        if self.local_plan is not None:
            lines.append(f"  local: {self.local_plan.describe()}")
        lines.append(f"  leaf n1: {self.plan_n1.describe()}")
        lines.append(f"  leaf n2: {self.plan_n2.describe()}")
        return "\n".join(lines)

    def __repr__(self):
        return (
            f"PencilPlan(n={self.n}, d={self.d}, {self.n1}x{self.n2}, "
            f"pack={self.pack}, K={self.a2a_chunks})"
        )


@functools.lru_cache(maxsize=256)
def _pencil_plan_cached(
    n: int,
    d: int,
    inverse: bool,
    backend: Optional[str],
    mode: str,
    factors: Optional[tuple],
    pack: Optional[bool],
    chunks: Optional[int],
    natural_order: bool,
) -> PencilPlan:
    from repro.core import tuning  # lazy: tuning imports the conv engines

    config = dict(
        tuning.pencil_config(
            n, d, backend=backend, tune=mode, natural_order=natural_order
        )
    )
    if factors is not None:
        config["n1"], config["n2"] = factors
    if pack is not None:
        config["pack"] = pack
    if chunks is not None:
        config["a2a_chunks"] = chunks
    return PencilPlan(
        n,
        d,
        inverse=inverse,
        backend=backend,
        config=config,
        natural_order=natural_order,
    )


def plan_pencil(
    n: int,
    num_shards: int,
    *,
    inverse: bool = False,
    backend: Optional[str] = None,
    tune: Optional[str] = None,
    factors: Optional[tuple] = None,
    pack: Optional[bool] = None,
    chunks: Optional[int] = None,
    natural_order: bool = True,
) -> PencilPlan:
    """Resolve a distributed pencil transform into a cached
    :class:`PencilPlan`.

    ``tune`` selects how the schedule's knobs are chosen — ``"off"`` is the
    historical balanced/serial schedule, ``"model"`` (the default) the
    roofline-modeled pick; both are cache-free pure functions of the shape
    so SPMD hosts agree (``"measure"`` clamps to the modeled pick here —
    see :func:`repro.core.tuning.pencil_config`).  ``factors``/``pack``/
    ``chunks`` override single decisions explicitly (every host must pass
    the same values).
    """
    from repro.core import tuning  # lazy: tuning imports the conv engines

    return _pencil_plan_cached(
        int(n),
        int(num_shards),
        bool(inverse),
        backend,
        tuning.resolve_mode(tune),
        tuple(factors) if factors is not None else None,
        pack,
        chunks,
        bool(natural_order),
    )


# ---------------------------------------------------------------------------
# The overlapped middle: a2a-in → column compute → a2a-out, K chunks
# ---------------------------------------------------------------------------


def _middle_pipelined(
    z: jax.Array,
    *,
    axis_name: str,
    d: int,
    q: int,
    k: int,
    la: int,
    compute: Callable,
) -> jax.Array:
    """The pencil schedule's middle section on the packed (2, ..., p, n2)
    stack: transpose to column slabs, run ``compute`` on each column chunk,
    transpose back — strip-mined into ``k`` chunks of ``q/k`` columns per
    device and software-pipelined so chunk *i*'s all-to-all is issued
    before chunk *i−1*'s compute is consumed (double-buffering: XLA's
    async collectives can then overlap the wire with the column FFT).

    ``compute(chunk, col_start, width)`` maps a (2, ..., n1, width) column
    chunk (``col_start`` the traced global column offset of this device's
    window) to its transformed chunk of the same shape.
    """
    lead = z.shape[:-1]  # (2, *batch, p)
    qk = q // k
    zs = z.reshape(*lead, d, q)
    didx = jax.lax.axis_index(axis_name)

    def send(c):
        # Columns {j·q + c·qk .. j·q + (c+1)·qk} for every destination j —
        # exactly the slices whose tiled all-to-all lands as this chunk's
        # contiguous (n1, qk) column slab on device j.
        sl = jax.lax.slice_in_dim(zs, c * qk, (c + 1) * qk, axis=zs.ndim - 1)
        return _a2a(sl.reshape(*lead, d * qk), axis_name, la + 1, la)

    recv = send(0)
    outs = []
    for c in range(k):
        nxt = send(c + 1) if c + 1 < k else None  # next transfer in flight
        y = compute(recv, didx * q + c * qk, qk)
        outs.append(_a2a(y, axis_name, la, la + 1))  # back to row slabs
        recv = nxt
    outs = [o.reshape(*lead, d, qk) for o in outs]
    out = jnp.stack(outs, axis=-2)  # (..., p, d, k, qk): chunk-major columns
    return out.reshape(*lead, d * q)


def _pack2(xr, xi):
    return jnp.stack([xr, xi])


# ---------------------------------------------------------------------------
# pfft / pifft
# ---------------------------------------------------------------------------


def pfft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    n: int,
    axis_name: str,
    num_shards: int,
    inverse: bool = False,
    natural_order: bool = True,
    backend: str | None = None,
    tune: str | None = None,
    pack: bool | None = None,
    chunks: int | None = None,
    factors: tuple | None = None,
    pplan: PencilPlan | None = None,
) -> Planes:
    """Distributed FFT over the last axis; call inside shard_map.

    ``xr/xi``: local shard (..., n // num_shards) of the globally length-``n``
    signal, contiguous (block) sharding.  Returns the local output shard.
    With ``natural_order=False`` the output is in pencil (k1-major) layout:
    global flat index k1·n2 + k2 holds X[k1 + n1·k2].

    The schedule (factor balance, split-complex packing, the a2a chunk
    count K the two inner transposes are overlapped at) comes from
    :func:`plan_pencil`; pass ``pplan`` to reuse a handle across calls, or
    ``pack``/``chunks``/``factors`` to override single decisions (SPMD:
    identical on every host).  With one shard the transform collapses to
    the local single-chip plan — zero collectives.
    """
    d = num_shards
    pl = pplan or plan_pencil(
        n,
        d,
        inverse=inverse,
        backend=backend,
        tune=tune,
        factors=factors,
        pack=pack,
        chunks=chunks,
        natural_order=natural_order,
    )
    n1, n2, p, q = pl.n1, pl.n2, pl.p, pl.q
    lead = xr.shape[:-1]
    la = len(lead)  # number of leading batch axes

    if d <= 1:
        if natural_order:
            return pl.local_plan.apply_planes(xr, xi)
        # Local four-step in pencil layout — keeps the k1-major semantics
        # callers of natural_order=False rely on, with zero collectives.
        xr = xr.reshape(*lead, n1, n2)
        xi = xi.reshape(*lead, n1, n2)
        xr, xi = pl.plan_n1.apply_planes(xr, xi)
        twr, twi = tw.traced_twiddle(n1, n2, inverse)
        xr, xi = cmul(xr, xi, twr, twi)
        xr, xi = pl.plan_n2.apply_planes(xr, xi)
        return xr.reshape(*lead, n), xi.reshape(*lead, n)

    # Local shard is rows [d·p, (d+1)·p) of the (n1, n2) matrix.
    xr = xr.reshape(*lead, p, n2)
    xi = xi.reshape(*lead, p, n2)

    if not pl.pack:
        return _pfft_serial_unpacked(
            xr, xi, pl, axis_name=axis_name, inverse=inverse,
            natural_order=natural_order, la=la, lead=lead,
        )

    z = _pack2(xr, xi)  # (2, *lead, p, n2): ONE collective per transpose
    lz = la + 1

    def col_chunk(chunk, col_start, width):
        cr, ci = pl.plan_n1.apply_planes(chunk[0], chunk[1])
        twr, twi = tw.traced_twiddle(
            n1, n2, inverse, col_start=col_start, col_count=width
        )
        cr, ci = cmul(cr, ci, twr, twi)
        return _pack2(cr, ci)

    z = _middle_pipelined(
        z, axis_name=axis_name, d=d, q=q, k=pl.a2a_chunks, la=lz,
        compute=col_chunk,
    )
    # after the transposes back: (2, *lead, p, n2) with full rows.
    # FFT over n2 (last axis, local).  (For inverse=True the two leaf
    # transforms already contribute 1/n1 · 1/n2 = 1/n scaling.)
    zr, zi = pl.plan_n2.apply_planes(z[0], z[1])
    if not natural_order:
        return zr.reshape(*lead, p * n2), zi.reshape(*lead, p * n2)
    # Final a2a transpose → natural order: C (p, n2) → C^T slab (q2, n1) —
    # one packed collective even though no chunk-overlap applies here.
    q2 = n2 // d
    z = _a2a(_pack2(zr, zi), axis_name, lz + 1, lz)  # (2, ..., n1, q2)
    z = jnp.swapaxes(z, -1, -2)  # (q2, n1) = C^T rows = natural order
    return (
        z[0].reshape(*lead, q2 * n1),
        z[1].reshape(*lead, q2 * n1),
    )


def _pfft_serial_unpacked(
    xr, xi, pl: PencilPlan, *, axis_name, inverse, natural_order, la, lead
) -> Planes:
    """The historical per-plane serial schedule (2 collectives per
    transpose, no chunk overlap) — kept as the A/B baseline the packed
    path is benchmarked against (``bench_pfft``)."""
    n1, n2, p, q = pl.n1, pl.n2, pl.p, pl.q
    d = pl.d
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    xr, xi = pl.plan_n1.apply_planes(xr, xi)
    twr, twi = _local_twiddle(n1, n2, q, axis_name, inverse)
    xr, xi = cmul(xr, xi, twr, twi)
    xr = _a2a(xr, axis_name, la, la + 1)
    xi = _a2a(xi, axis_name, la, la + 1)
    xr, xi = pl.plan_n2.apply_planes(xr, xi)
    if not natural_order:
        return xr.reshape(*lead, p * n2), xi.reshape(*lead, p * n2)
    q2 = n2 // d
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    xr = jnp.swapaxes(xr, -1, -2)
    xi = jnp.swapaxes(xi, -1, -2)
    return xr.reshape(*lead, q2 * n1), xi.reshape(*lead, q2 * n1)


def pifft(
    xr: jax.Array,
    xi: jax.Array,
    *,
    n: int,
    axis_name: str,
    num_shards: int,
    from_pencil: bool = False,
    backend: str | None = None,
    tune: str | None = None,
    pack: bool | None = None,
    chunks: int | None = None,
    factors: tuple | None = None,
    pplan: PencilPlan | None = None,
) -> Planes:
    """Distributed inverse FFT.

    With ``from_pencil=True`` consumes the k1-major layout produced by
    ``pfft(..., natural_order=False)`` using the mirrored schedule (no extra
    reordering collective).  Packing / chunk-overlap mirror :func:`pfft`.
    """
    d = num_shards
    pl = pplan or plan_pencil(
        n,
        d,
        inverse=True,
        backend=backend,
        tune=tune,
        factors=factors,
        pack=pack,
        chunks=chunks,
        natural_order=not from_pencil,
    )
    n1, n2, p, q = pl.n1, pl.n2, pl.p, pl.q
    lead = xr.shape[:-1]
    la = len(lead)

    if d <= 1:
        if not from_pencil:
            return pl.local_plan.apply_planes(xr, xi)
        # Mirror of the d=1 pencil-layout forward, still collective-free.
        xr = xr.reshape(*lead, n1, n2)
        xi = xi.reshape(*lead, n1, n2)
        xr, xi = pl.plan_n2.apply_planes(xr, xi)
        twr, twi = tw.traced_twiddle(n1, n2, True)
        xr, xi = cmul(xr, xi, twr, twi)
        xr, xi = pl.plan_n1.apply_planes(xr, xi)
        return xr.reshape(*lead, n), xi.reshape(*lead, n)

    if not pl.pack:
        return _pifft_serial_unpacked(
            xr, xi, pl, axis_name=axis_name, from_pencil=from_pencil,
            la=la, lead=lead,
        )

    if not from_pencil:
        # Natural order: device holds C^T rows (q, n1); transpose to pencil
        # with one packed collective.
        z = _pack2(xr.reshape(*lead, q, n1), xi.reshape(*lead, q, n1))
        z = _a2a(z, axis_name, la + 2, la + 1)  # (2, ..., n2_slab rows, p)
        z = jnp.swapaxes(z, -1, -2)  # (2, ..., p, n2)
    else:
        z = _pack2(xr.reshape(*lead, p, n2), xi.reshape(*lead, p, n2))
    lz = la + 1
    # Mirror of pfft: inverse FFT over n2 (rows, local)...
    zr, zi = pl.plan_n2.apply_planes(z[0], z[1])
    z = _pack2(zr, zi)

    def col_chunk(chunk, col_start, width):
        twr, twi = tw.traced_twiddle(
            n1, n2, True, col_start=col_start, col_count=width
        )
        cr, ci = cmul(chunk[0], chunk[1], twr, twi)
        cr, ci = pl.plan_n1.apply_planes(cr, ci)
        return _pack2(cr, ci)

    z = _middle_pipelined(
        z, axis_name=axis_name, d=d, q=q, k=pl.a2a_chunks, la=lz,
        compute=col_chunk,
    )
    return z[0].reshape(*lead, p * n2), z[1].reshape(*lead, p * n2)


def _pifft_serial_unpacked(
    xr, xi, pl: PencilPlan, *, axis_name, from_pencil, la, lead
) -> Planes:
    """Historical per-plane inverse schedule (A/B baseline)."""
    n1, n2, p, q = pl.n1, pl.n2, pl.p, pl.q
    if not from_pencil:
        xr = xr.reshape(*lead, q, n1)
        xi = xi.reshape(*lead, q, n1)
        xr = _a2a(xr, axis_name, la + 1, la)
        xi = _a2a(xi, axis_name, la + 1, la)
        xr = jnp.swapaxes(xr, -1, -2)
        xi = jnp.swapaxes(xi, -1, -2)
    else:
        xr = xr.reshape(*lead, p, n2)
        xi = xi.reshape(*lead, p, n2)
    xr, xi = pl.plan_n2.apply_planes(xr, xi)
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    twr, twi = _local_twiddle(n1, n2, q, axis_name, inverse=True)
    xr, xi = cmul(xr, xi, twr, twi)
    xr, xi = pl.plan_n1.apply_planes(xr, xi)
    xr = _a2a(xr, axis_name, la, la + 1)
    xi = _a2a(xi, axis_name, la, la + 1)
    return xr.reshape(*lead, p * n2), xi.reshape(*lead, p * n2)


def pfft2d(
    xr: jax.Array,
    xi: jax.Array,
    *,
    n1: int,
    n2: int,
    axis_name: str,
    num_shards: int,
    inverse: bool = False,
    backend: str | None = None,
    pack: bool = True,
) -> Planes:
    """Distributed 2-D FFT (SAR range/azimuth): rows local, columns pencil.

    xr/xi: local shard (..., n1 // D, n2) of a (n1, n2) image, rows sharded
    over ``axis_name``.  Each shard consumes ONE joint 2-D plan
    (``FFTSpec(kind='fft2')`` — the same compiled rows+columns program the
    single-chip path runs) split around the collectives: the row passes run
    on the row-sharded slab, then one all-to-all transpose, the in-place
    column passes on the column slab, and the transpose back — 2 packed
    all-to-alls per direction with the split-complex pair stacked into one
    collective each (``pack=False`` keeps the historical 4-call schedule).
    """
    del num_shards  # the joint plan is shard-count-agnostic (slab widths vary)
    lead = xr.shape[:-2]
    la = len(lead)

    joint = fft_lib.plan(
        fft_lib.FFTSpec(n=n2, kind="ifft2" if inverse else "fft2", n2=n1),
        backend=backend,
    )

    # (1) row passes of the joint program over n2 — local and contiguous.
    xr, xi = joint.apply_rows(xr, xi)
    if pack:
        # (2) ONE packed a2a transpose: (p, n2) → (n1, q) column slabs.
        z = _a2a(_pack2(xr, xi), axis_name, la + 2, la + 1)
        # (3) column passes over n1 — in place down axis -2 of the slab.
        xr, xi = joint.apply_cols(z[0], z[1])
        # (4) one packed a2a back to row slabs (p, n2).
        z = _a2a(_pack2(xr, xi), axis_name, la + 1, la + 2)
        return z[0], z[1]
    xr = _a2a(xr, axis_name, la + 1, la)
    xi = _a2a(xi, axis_name, la + 1, la)
    xr, xi = joint.apply_cols(xr, xi)
    xr = _a2a(xr, axis_name, la, la + 1)
    xi = _a2a(xi, axis_name, la, la + 1)
    return xr, xi


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """Version-tolerant shard_map: ``jax.shard_map``/``check_vma`` on new JAX,
    ``jax.experimental.shard_map``/``check_rep`` on older releases (including
    the window where ``jax.shard_map`` exists but still takes ``check_rep``)."""
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm

        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def _shard_wrap(fn, mesh: Mesh, axis: str):
    def wrapper(xr, xi, **kw):
        nbatch = xr.ndim - 1
        pspec = P(*([None] * nbatch + [axis]))
        f = functools.partial(fn, axis_name=axis, **kw)
        return shard_map_compat(
            f, mesh, in_specs=(pspec, pspec), out_specs=(pspec, pspec)
        )(xr, xi)

    return wrapper


def pfft_sharded(
    xr,
    xi,
    mesh: Mesh,
    axis: str,
    *,
    inverse=False,
    natural_order=True,
    backend=None,
    tune=None,
    pack=None,
    chunks=None,
    factors=None,
):
    """Standalone distributed FFT: shards the last axis over ``mesh[axis]``."""
    n = xr.shape[-1]
    d = mesh.shape[axis]
    return _shard_wrap(pfft, mesh, axis)(
        xr,
        xi,
        n=n,
        num_shards=d,
        inverse=inverse,
        natural_order=natural_order,
        backend=backend,
        tune=tune,
        pack=pack,
        chunks=chunks,
        factors=factors,
    )


def pifft_sharded(
    xr,
    xi,
    mesh: Mesh,
    axis: str,
    *,
    from_pencil=False,
    backend=None,
    tune=None,
    pack=None,
    chunks=None,
    factors=None,
):
    n = xr.shape[-1]
    d = mesh.shape[axis]
    return _shard_wrap(pifft, mesh, axis)(
        xr,
        xi,
        n=n,
        num_shards=d,
        from_pencil=from_pencil,
        backend=backend,
        tune=tune,
        pack=pack,
        chunks=chunks,
        factors=factors,
    )


def pconv_os_sharded(
    x: jax.Array,
    h: jax.Array,
    mesh: Mesh,
    axis: str,
    *,
    causal: bool = True,
    block: int | None = None,
    backend: str | None = None,
    tune: str | None = None,
    chunk_hint: int | None = None,
) -> jax.Array:
    """Distributed overlap-save convolution: blocks sharded over ``mesh[axis]``.

    The overlap-save blocks of :func:`repro.core.overlap.fft_conv_os` are
    embarrassingly parallel — every block carries its own ``Lh − 1`` history
    in the overlapping frame — so the convolution shards over the *block*
    axis with ``shard_map`` and pays **zero** all-to-alls, versus the 2 of
    the packed pencil ``pfft → ⊙H → pifft`` path (and its transforms stay in
    the fused one-round-trip regime, where the pencil leaves may not).

    ``x``: (..., L) replicated input; ``h`` broadcasts like ``fft_conv``.
    The block count is padded up to a multiple of the mesh axis size with
    zero frames (their outputs fall past ``L_out`` and are sliced away).
    Returns the (..., L) causal output (or L + Lh − 1 with
    ``causal=False``), replicated — the framing gather and tail scatter run
    outside the ``shard_map`` body.

    Block tuning here is DETERMINISTIC by construction: with ``block=None``
    and ``tune`` ≠ "off" the block is the pure roofline pick
    (:func:`repro.core.tuning.modeled_block`) — never a cache hit or a
    measurement, which could differ across the hosts of a multi-process
    mesh and desynchronize the shard_map program.  ``chunk_hint`` keys the
    modeled pick to a streaming call grain (the sharded analogue of
    :class:`~repro.core.overlap.StreamingConv`'s ``chunk_hint``), still
    cache-free.  To use a measured winner, tune on one host
    (``tuning.tuned_block(..., "measure")``) and pass the result as
    ``block=`` explicitly.
    """
    from repro.core import overlap as ov  # lazy: distributed loads before overlap at package init
    from repro.core import tuning

    x = jnp.asarray(x)
    out_dtype = x.dtype
    x = x.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    d = mesh.shape[axis]
    L, Lh = x.shape[-1], h.shape[-1]
    batch = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    if block is not None:
        B = ov.pick_block(Lh, block)
    elif tuning.resolve_mode(tune) == "off" or Lh < 2:
        B = ov.pick_block(Lh)
    else:
        B = tuning.modeled_block(L, Lh, batch, backend, chunk=chunk_hint)
    overlap = Lh - 1
    step = B - overlap
    L_out = L if causal else L + Lh - 1
    nb = -(-L_out // step)
    nb = -(-nb // d) * d  # whole blocks per shard; extras are zero frames
    frames = ov.frame_signal(x, B, step, nb)
    Hr, Hi = ov.filter_spectrum(h, B, backend)  # computed once, replicated
    fspec = P(*([None] * (frames.ndim - 2)), axis, None)

    def body(fr, hr, hi):
        return ov.conv_frames(fr, hr, hi, overlap=overlap, backend=backend)

    tails = shard_map_compat(
        body, mesh, in_specs=(fspec, P(), P()), out_specs=fspec
    )(frames, Hr, Hi)
    lead = tails.shape[:-2]
    y = tails.reshape(*lead, nb * step)[..., :L_out]
    return y.astype(out_dtype)
