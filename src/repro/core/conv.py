"""FFT-based convolution — the paper's primitive put to work in the LM stack.

Long causal convolution (Hyena/S4-style global filters, SSM skip paths) is the
layer through which the memory-optimized FFT enters the assigned SSM/hybrid
architectures.  ``y = causal_conv(x, h)`` with a filter as long as the
sequence costs O(L²) direct but O(L log L) via rfft → pointwise → irfft, and
every transform goes through :mod:`repro.core.fft`, i.e. the paper's
one-round-trip kernels.

Beyond-paper notes:
* real-packing (rfft) halves transform length for the real-valued signals;
* the filter spectrum is computed once per call and broadcast over batch —
  the "precomputed LUT" idea (paper §2.3.1) applied one level up;
* for distributed sequences :func:`fft_conv` composes with
  ``repro.core.distributed.pfft`` which keeps the frequency domain in
  transposed pencil layout, so the fwd+inv pair pays 2 all-to-alls, not 4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft as fft_lib
from repro.core import plan as plan_lib
from repro.core.fft_xla import cmul
from repro.core.limits import next_pow2

__all__ = [
    "fft_conv",
    "fft_conv2d",
    "fft_conv_packed",
    "next_pow2",
    "toeplitz_conv_ref",
]


def fft_conv(
    x: jax.Array,
    h: jax.Array,
    *,
    causal: bool = True,
    axis: int = -1,
    backend: str | None = None,
    overlap_save: bool | None = None,
    tune: str | None = None,
    pad: str = "pow2",
) -> jax.Array:
    """Causal convolution of ``x`` with filter ``h`` along ``axis``.

    Zero-pads to the next power of two ≥ L + Lh - 1 (linear, not circular,
    convolution), transforms through cached :class:`PlannedFFT` handles
    (rfft forward, irfft inverse — one plan pair per padded length),
    multiplies spectra, and truncates to the first L samples (causal) — the
    standard overlap-free long-conv used by Hyena/S4 layers.  ``L`` and
    ``Lh`` are arbitrary — nothing requires powers of two.

    ``pad='exact'`` transforms at exactly ``n = L + Lh - 1`` instead,
    routing non-pow2 lengths through the planner's Bluestein chirp-conv
    leaves.  The exact length keeps the spectrum bin-aligned to the true
    linear-convolution length (useful when the spectrum itself is consumed);
    for raw throughput the default pow2 pad is never slower, since Bluestein
    internally pads to ``next_pow2(2n-1)``.

    ``overlap_save=None`` (default) auto-routes to
    :func:`repro.core.overlap.fft_conv_os` whenever the one-shot padded
    length would leave the fused one-round-trip regime
    (``next_pow2(L + Lh - 1) > FUSED_MAX``) — long signals then run as many
    fused-regime block transforms instead of one split-regime program.
    ``True`` forces the overlap-save path, ``False`` forces one-shot.
    ``tune`` controls the overlap-save block autotuner
    (:mod:`repro.core.tuning`): off/model/measure, default model.

    ``h`` is indexed over its *last* axis and broadcasts against ``x`` with
    the convolution axis moved last (e.g. per-channel filters of shape
    (D, Lh) against activations (B, D, L), or (B, S, D) with ``axis=1``).
    Inputs are computed in float32 regardless of dtype (like
    :func:`fft_conv2d`); the output is cast back to the input dtype.
    """
    if pad not in ("pow2", "exact"):
        raise ValueError(f"pad must be 'pow2' or 'exact', got {pad!r}")
    x = jnp.asarray(x)
    L = x.shape[axis]
    Lh = h.shape[-1]
    n = L + Lh - 1 if pad == "exact" else next_pow2(L + Lh - 1)
    if pad == "pow2" and (
        overlap_save or (overlap_save is None and n > plan_lib.FUSED_MAX)
    ):
        from repro.core import overlap  # lazy: conv loads before overlap at package init

        return overlap.fft_conv_os(
            x, h, causal=causal, axis=axis, backend=backend, tune=tune
        )
    out_dtype = x.dtype
    x = x.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    fwd = fft_lib.plan(fft_lib.FFTSpec(n=n, kind="rfft"), backend=backend)
    inv = fft_lib.plan(fft_lib.FFTSpec(n=n, kind="irfft"), backend=backend)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n - L)])
    hp = jnp.pad(h, [(0, 0)] * (h.ndim - 1) + [(0, n - Lh)])
    Xr, Xi = fwd(xp)
    Hr, Hi = fwd(hp)
    Yr, Yi = cmul(Xr, Xi, Hr, Hi)
    y = inv((Yr, Yi))
    y = y[..., :L] if causal else y[..., : L + Lh - 1]
    if axis != -1:
        y = jnp.moveaxis(y, -1, axis)
    return y.astype(out_dtype)


def toeplitz_conv_ref(x: np.ndarray, h: np.ndarray) -> np.ndarray:
    """O(L²) direct causal convolution oracle for tests.

    ``h`` broadcasts against ``x`` with the same rule as :func:`fft_conv`:
    a 1-D filter applies to every row, per-channel filters broadcast over
    the leading axes — so multi-filter test cases exercise every filter,
    not just ``h[0]``.
    """
    L, Lh = x.shape[-1], h.shape[-1]
    hb = np.broadcast_to(h, x.shape[:-1] + (Lh,))
    flat_x = x.reshape(-1, L)
    flat_h = hb.reshape(-1, Lh)
    rows = [
        np.convolve(row, filt, mode="full")[:L]
        for row, filt in zip(flat_x, flat_h)
    ]
    return np.stack(rows).reshape(x.shape)


def fft_conv2d(
    x: jax.Array,
    h: jax.Array,
    *,
    mode: str = "same",
    backend: str | None = None,
) -> jax.Array:
    """2-D linear convolution of real images — the SAR matched-filter path.

    ``x``: (..., H, W) real image(s); ``h``: real filter broadcast against
    ``x`` over leading axes (a (1, Wh) filter is a per-row matched filter —
    SAR range compression; a full 2-D reference function is the spotlight
    matched filter).  Both are zero-padded to powers of two covering the
    full linear convolution and transformed through ONE cached rfft2/irfft2
    plan pair, i.e. the joint rows+columns pass program with the Hermitian
    epilogue — two real 2-D transforms and a pointwise spectrum multiply,
    never a per-axis transpose sandwich.

    ``mode='same'`` returns the leading (H, W) window (causal 2-D: output
    pixel (i, j) only sees inputs at (≤ i, ≤ j)); ``mode='full'`` returns
    the whole (H + Hh - 1, W + Wh - 1) linear convolution.  Computed in
    float32; the output is cast back to the input dtype.
    """
    x = jnp.asarray(x)
    out_dtype = x.dtype
    H, W = x.shape[-2:]
    Hh, Wh = h.shape[-2:]
    N2 = next_pow2(H + Hh - 1)
    N = next_pow2(W + Wh - 1)
    fwd = fft_lib.plan(fft_lib.FFTSpec(n=N, kind="rfft2", n2=N2), backend=backend)
    inv = fft_lib.plan(fft_lib.FFTSpec(n=N, kind="irfft2", n2=N2), backend=backend)

    def pad2(a, hgt, wid):
        a = jnp.asarray(a, jnp.float32)
        return jnp.pad(a, [(0, 0)] * (a.ndim - 2) + [(0, N2 - hgt), (0, N - wid)])

    Xr, Xi = fwd(pad2(x, H, W))
    Hr, Hi = fwd(pad2(h, Hh, Wh))
    Yr, Yi = cmul(Xr, Xi, Hr, Hi)
    y = inv((Yr, Yi))
    if mode == "same":
        return y[..., :H, :W].astype(out_dtype)
    if mode == "full":
        return y[..., : H + Hh - 1, : W + Wh - 1].astype(out_dtype)
    raise ValueError(f"mode must be 'same' or 'full', got {mode!r}")


def fft_conv_packed(
    x: jax.Array,
    h: jax.Array,
    *,
    causal: bool = True,
    backend: str | None = None,
) -> jax.Array:
    """Real-filter convolution with complex batch packing (§Perf win).

    Convolution with a *real* filter is linear over the reals, so two real
    signals packed as one complex signal convolve in a single complex FFT:
    conv(x1 + i·x2, h) = conv(x1, h) + i·conv(x2, h).  Halves transforms,
    HBM traffic and (distributed) all-to-all payload versus transforming
    each row separately — with zero recombination cost.

    ``x``: (..., 2·B, L) real; pairs (2b, 2b+1) are packed together.  Odd
    row counts are handled by packing a zero row with the last real one
    (stripped from the output), so odd channel counts don't crash.
    Computed in float32; the output is cast back to the input dtype.
    """
    x = jnp.asarray(x)
    out_dtype = x.dtype
    x = x.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    lead, twob, L = x.shape[:-2], x.shape[-2], x.shape[-1]
    odd = twob % 2
    if odd:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, 1), (0, 0)])
    rows = twob + odd
    xr = x[..., 0::2, :]
    xi = x[..., 1::2, :]
    Lh = h.shape[-1]
    n = next_pow2(L + Lh - 1)
    fwd = fft_lib.plan(fft_lib.FFTSpec(n=n, kind="fft"), backend=backend)
    inv = fft_lib.plan(fft_lib.FFTSpec(n=n, kind="ifft"), backend=backend)
    rfwd = fft_lib.plan(fft_lib.FFTSpec(n=n, kind="rfft"), backend=backend)
    pad = [(0, 0)] * (xr.ndim - 1) + [(0, n - L)]
    zr, zi = jnp.pad(xr, pad), jnp.pad(xi, pad)
    Zr, Zi = fwd((zr, zi))
    hp = jnp.pad(h, [(0, 0)] * (h.ndim - 1) + [(0, n - Lh)])
    Hr, Hi = rfwd(hp)
    # full-length hermitian extension of the real filter's spectrum
    m = n // 2
    Hr_f = jnp.concatenate([Hr, Hr[..., 1:m][..., ::-1]], axis=-1)
    Hi_f = jnp.concatenate([Hi, -Hi[..., 1:m][..., ::-1]], axis=-1)
    Yr, Yi = cmul(Zr, Zi, Hr_f, Hi_f)
    yr, yi = inv((Yr, Yi))
    out = jnp.stack([yr, yi], axis=-2).reshape(*lead, rows, n)
    if odd:
        out = out[..., :twob, :]
    out = out[..., :L] if causal else out[..., : L + Lh - 1]
    return out.astype(out_dtype)
