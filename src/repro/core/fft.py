"""Public FFT API — plan-and-execute over a backend registry.

The paper's core idea is that the transform *schedule* (kernel-call count,
memory-tier placement, LUT reuse — §2.3, §3) is decided once per size and
reused.  This module exposes that as a plan-and-execute API in the FFTW /
cuFFT mold:

    spec    = FFTSpec(n=4096, kind="fft", axis=-1)
    planned = plan(spec)             # cached: plan(spec) is plan(spec)
    y       = planned(x)             # executes the frozen schedule

:func:`plan` resolves an :class:`FFTSpec` (length, kind, axis, precision,
batch hint) into a hashable :class:`PlannedFFT` executor carrying the
:class:`repro.core.plan.FFTPlan` schedule, pre-materialized twiddle/DFT LUTs,
the chosen per-leaf batch tiles, and a backend selected from the **backend
registry**.

Backends
--------
Backends are registered entries (:func:`register_backend`), not an if/elif
chain.  Each declares capabilities (platforms, precisions, max length) and
selection is by capability negotiation against the running platform unless a
name is forced per call or scoped with the :func:`use_backend` context
manager.  Built-in entries:

``pallas``    fused Pallas TPU kernels (``repro.kernels``), one HBM round trip
              per plan level.  Runs under ``interpret=True`` on CPU.
``xla``       pure-JAX four-step with the same factorisation (MXU matmuls on
              TPU, portable everywhere).  Preferred on CPU/GPU.
``stockham``  radix-2 butterfly reference (the paper's original formulation).

Module functions ``fft/ifft/rfft/irfft/fft2/ifft2/rfft2/irfft2`` remain as
thin plan-cached wrappers (each call re-uses the cached :class:`PlannedFFT`);
the 1-D kinds grow an ``axis=`` argument for transforms over a non-last axis,
while the 2-D kinds always transform the last two axes.  ``fft2``/``ifft2``
compile into ONE joint multi-axis pass program (rows, then in-place strided
columns — zero transposes between the axes); ``rfft2``/``irfft2`` add the
row-wise Hermitian recombination epilogue around it.

All complex transforms accept either a complex array or a ``(real, imag)``
tuple of float32 planes, and return whichever form was supplied.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import inspect
import itertools
import os
import threading
import types
import warnings
from typing import Callable, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core import fft_xla
from repro.core import plan as plan_lib
from repro.core import twiddle as tw
from repro.core.faults import NumericsError, PlanError

Planes = Tuple[jax.Array, jax.Array]
ArrayOrPlanes = Union[jax.Array, Planes]

__all__ = [
    "FFTSpec",
    "PlannedFFT",
    "plan",
    "BackendCapabilities",
    "register_backend",
    "available_backends",
    "get_backend",
    "use_backend",
    "default_backend",
    "plan_log",
    "clear_plan_log",
    "PLAN_LOG_MAX",
    "fft",
    "ifft",
    "rfft",
    "irfft",
    "fft2",
    "ifft2",
    "rfft2",
    "irfft2",
]

KINDS = ("fft", "ifft", "rfft", "irfft", "fft2", "ifft2", "rfft2", "irfft2")
_COMPLEX_KINDS = ("fft", "ifft")
_2D_KINDS = ("fft2", "ifft2", "rfft2", "irfft2")

#: Relative tolerance of the opt-in ``check="parseval"`` energy guard —
#: generous for float32 accumulation; it flags corruption, not rounding.
PARSEVAL_RTOL = 1e-2


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# FFTSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTSpec:
    """What to transform — the hashable key a :class:`PlannedFFT` is built for.

    n:          transform length along ``axis``.  Any length ≥ 1: powers of
                two run the paper's native schedules; other lengths compile
                into the planner's Bluestein chirp-conv leaf (a cached
                power-of-two circular convolution at ``bluestein_pad(n)``).
                ``rfft2``/``irfft2`` still require a power of two.  For
                ``irfft``/``irfft2`` this is the *output* signal length along
                the last axis; for the 2-D kinds it is the last-axis (row)
                length and ``n2`` the second-to-last (column) length.
    kind:       'fft' | 'ifft' | 'rfft' | 'irfft' | 'fft2' | 'ifft2' |
                'rfft2' | 'irfft2'.  The 2-D complex kinds compile into ONE
                joint pass program (rows then in-place columns); ``rfft2``
                transforms a real ``(..., n2, n)`` image into its
                ``(..., n2, n//2 + 1)`` half-spectrum (numpy ``rfft2``
                layout: real transform over the last axis, full complex
                transform over axis -2) and ``irfft2`` inverts it.
    axis:       transform axis (2-D kinds always use the last two axes).
    precision:  compute precision of the planes ('float32' for now; the field
                exists so mixed-precision plans slot in without an API break).
    batch_hint: expected batch rows, used to cap the kernel batch tile so a
                small batch is not padded up to the VMEM-optimal tile.
    n2:         second-to-last-axis length, 2-D kinds only.
    """

    n: int
    kind: str = "fft"
    axis: int = -1
    precision: str = "float32"
    batch_hint: Optional[int] = None
    n2: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise PlanError(f"unknown FFT kind {self.kind!r}; one of {KINDS}")
        if self.n < 1:
            raise PlanError(f"FFT length must be >= 1, got {self.n}")
        if self.kind in ("rfft2", "irfft2") and not _is_pow2(self.n):
            raise PlanError(
                f"{self.kind} requires a power-of-two row length, got n={self.n}; "
                f"non-power-of-two lengths are supported for "
                f"{_COMPLEX_KINDS + ('rfft', 'irfft', 'fft2', 'ifft2')} via the "
                f"Bluestein chirp-conv route"
            )
        if self.kind in ("rfft", "irfft", "rfft2", "irfft2") and self.n < 2:
            raise PlanError(f"{self.kind} length must be >= 2, got {self.n}")
        if self.kind in _2D_KINDS:
            if self.n2 is None or not _is_pow2(self.n2):
                raise PlanError(
                    f"{self.kind} needs a power-of-two n2 (column length), got "
                    f"{self.n2}; only the last (row) axis takes non-power-of-two "
                    f"lengths (Bluestein route)"
                )
            if self.axis != -1:
                raise PlanError(f"{self.kind} always transforms the last two axes")
        elif self.n2 is not None:
            raise PlanError(f"n2 is only meaningful for the 2-D kinds {_2D_KINDS}")
        if self.batch_hint is not None and self.batch_hint < 1:
            raise PlanError(f"batch_hint must be >= 1, got {self.batch_hint}")


# ---------------------------------------------------------------------------
# Backend registry + capability negotiation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a backend can run, consulted during plan-time negotiation.

    platforms:           JAX platforms the backend runs on at all.
    preferred_platforms: platforms where it should win negotiation (scored
                         above plain support).
    precisions:          plane precisions it implements.
    max_n:               largest supported transform length (None = unbounded).
    priority:            tie-break between equally-capable backends.
    native_2d:           the backend fn executes a joint multi-axis plan
                         (``fft_plan.n2`` set) in one call.  Backends without
                         it still serve 2-D specs — the handle composes the
                         cached row and ``axis=-2`` column 1-D plans of the
                         same backend.
    bluestein:           the backend executes non-power-of-two lengths (the
                         planner's Bluestein chirp-conv leaves).  Backends
                         without it (``stockham``) disclaim non-pow2 specs
                         during negotiation.
    """

    platforms: frozenset = frozenset({"cpu", "gpu", "tpu"})
    preferred_platforms: frozenset = frozenset()
    precisions: frozenset = frozenset({"float32"})
    max_n: Optional[int] = None
    priority: int = 10
    native_2d: bool = False
    bluestein: bool = False

    def supports(self, spec: FFTSpec, platform: str) -> bool:
        if platform not in self.platforms:
            return False
        if spec.precision not in self.precisions:
            return False
        if self.max_n is not None and max(spec.n, spec.n2 or 0) > self.max_n:
            return False
        if not self.bluestein and not _is_pow2(spec.n):
            return False
        return True

    def score(self, platform: str) -> int:
        return self.priority + (100 if platform in self.preferred_platforms else 0)


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered executor: transforms the last axis of split planes.

    ``takes_axis`` backends additionally accept ``axis=-2`` and transform the
    second-to-last axis in place (the pencil column pass) — detected from the
    function signature at registration.

    ``claims`` is the per-leaf capability surface: a predicate over program
    :class:`~repro.core.plan.Pass` records saying which passes the backend
    executes natively.  ``None`` means the backend claims whole plans (every
    pass).  A backend with a partial claim surface must fall back to ``xla``
    for unclaimed passes *inside its own fn* — the registry only records the
    claim map so :attr:`PlannedFFT.pass_claims` can report it per leaf.

    ``seq`` is the registration sequence number — the negotiation tie-break
    (see :func:`_negotiate`).
    """

    name: str
    fn: Callable  # (xr, xi, *, inverse: bool, planned: PlannedFFT) -> Planes
    capabilities: BackendCapabilities
    takes_axis: bool = False
    claims: Optional[Callable] = None  # Pass -> bool; None = claims all
    seq: int = 0


_REGISTRY: dict = {}
_REGISTRY_SEQ = itertools.count()


def _accepts_axis(fn: Callable) -> bool:
    try:
        return "axis" in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / exotic callables
        return False


def register_backend(
    name: str,
    fn: Callable,
    capabilities: BackendCapabilities | None = None,
    *,
    overwrite: bool = False,
    claims: Optional[Callable] = None,
) -> Backend:
    """Register ``fn`` as FFT backend ``name``.

    ``fn(xr, xi, *, inverse, planned)`` must transform the last axis of the
    split float32 planes, following ``planned.fft_plan``'s schedule (or its
    own, for reference backends).  If it also takes an ``axis`` keyword it
    will be handed ``axis=-2`` column transforms directly (no transpose glue).
    ``claims`` declares a per-leaf capability surface (see
    :class:`Backend`); leave ``None`` for whole-plan backends.
    Registering an existing name requires ``overwrite=True`` so a typo cannot
    silently shadow a built-in.
    """
    if not overwrite and name in _REGISTRY:
        raise PlanError(f"FFT backend {name!r} is already registered")
    entry = Backend(
        name,
        fn,
        capabilities or BackendCapabilities(),
        takes_axis=_accepts_axis(fn),
        claims=claims,
        seq=next(_REGISTRY_SEQ),
    )
    _REGISTRY[name] = entry
    # Existing cached plans may have negotiated without this entry (or hold a
    # stale fn under overwrite=True) — re-resolve on next plan().
    _plan_cached.cache_clear()
    return entry


def available_backends() -> tuple:
    """Registered backend names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise PlanError(
            f"unknown FFT backend {name!r}; registered: {available_backends()}"
        ) from None


def _negotiate(spec: FFTSpec, platform: str) -> Backend:
    """Highest capability score wins; ties break toward the *most recently
    registered* entry, so an explicitly registered platform-preferred backend
    beats a built-in default that also prefers the platform (the built-ins
    register first)."""
    best = None
    for entry in _REGISTRY.values():
        if not entry.capabilities.supports(spec, platform):
            continue
        key = (entry.capabilities.score(platform), entry.seq)
        if best is None or key > (best.capabilities.score(platform), best.seq):
            best = entry
    if best is None:
        raise PlanError(
            f"no registered FFT backend supports {spec} on platform {platform!r}"
        )
    return best


# ---------------------------------------------------------------------------
# Default-backend scoping
# ---------------------------------------------------------------------------

_GLOBAL_DEFAULT: Optional[str] = os.environ.get("REPRO_FFT_BACKEND") or None
_scope = threading.local()


def _scope_stack() -> list:
    stack = getattr(_scope, "stack", None)
    if stack is None:
        stack = _scope.stack = []
    return stack


@contextlib.contextmanager
def use_backend(name: str):
    """Scope the default FFT backend: ``with use_backend('stockham'): ...``.

    Nested scopes stack; the previous default is restored on exit even when
    the body raises.  The name is validated against the registry on entry.
    """
    get_backend(name)  # fail fast on unknown names
    stack = _scope_stack()
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


def default_backend() -> Optional[str]:
    """The backend name new plans will use absent a per-call ``backend=``.

    Innermost :func:`use_backend` scope, else the ``REPRO_FFT_BACKEND``
    environment override, else None — meaning capability negotiation picks
    per plan (xla on CPU/GPU, pallas on TPU).
    """
    stack = _scope_stack()
    if stack:
        return stack[-1]
    return _GLOBAL_DEFAULT


def set_default_backend(name: str) -> None:  # deprecated shim
    """Deprecated: use :func:`use_backend` (scoped) instead."""
    warnings.warn(
        "set_default_backend is deprecated; use the use_backend() context "
        "manager (scoped) or pass backend= to plan()",
        DeprecationWarning,
        stacklevel=2,
    )
    global _GLOBAL_DEFAULT
    get_backend(name)
    _GLOBAL_DEFAULT = name


# ---------------------------------------------------------------------------
# Planes helpers
# ---------------------------------------------------------------------------


def _split(x: ArrayOrPlanes) -> tuple[jax.Array, jax.Array, bool]:
    """Returns (real, imag, was_complex)."""
    if isinstance(x, (tuple, list)):
        xr, xi = x
        return jnp.asarray(xr, jnp.float32), jnp.asarray(xi, jnp.float32), False
    x = jnp.asarray(x)
    if jnp.iscomplexobj(x):
        return (
            jnp.real(x).astype(jnp.float32),
            jnp.imag(x).astype(jnp.float32),
            True,
        )
    return x.astype(jnp.float32), jnp.zeros_like(x, jnp.float32), True


def _join(yr, yi, was_complex: bool) -> ArrayOrPlanes:
    if was_complex:
        return jax.lax.complex(yr, yi)
    return yr, yi


def _input_shape(x: ArrayOrPlanes) -> tuple:
    if isinstance(x, (tuple, list)):
        return jnp.shape(x[0])
    return jnp.shape(x)


# ---------------------------------------------------------------------------
# PlannedFFT
# ---------------------------------------------------------------------------


def _materialize_luts(
    fft_plan: plan_lib.FFTPlan, inverse: bool, backend_name: str
) -> tuple:
    """Host-side LUTs for every program pass — the paper's texture-memory
    tables built at plan time so first execution pays no table construction.

    Warms the exact builder the backend will hit (ops' scaled transform-LUT
    and inter-factor twiddle caches for pallas, the twiddle factory
    otherwise); the returned references keep the arrays alive for the
    lifetime of the plan."""
    luts = []
    if backend_name in ("pallas", "pallas_gpu"):
        from repro.kernels import ops as kernel_ops  # lazy: avoids cycle

        for p in fft_plan.passes:
            # Bluestein inner-conv passes pin their own direction.
            eff = p.inverse if p.inverse is not None else inverse
            if p.kind == "reorder":
                continue
            if p.kind == "bluestein":
                luts.append(kernel_ops._bluestein_luts(p, eff))
            elif p.kind == "direct":
                luts.append(kernel_ops._direct_luts(p.n, eff))
            else:
                luts.append(kernel_ops._fused_luts(p.n1, p.n2, eff))
            if p.twiddle_after is not None:
                luts.append(kernel_ops._pass_twiddle_luts(*p.twiddle_after, eff))
        return tuple(luts)
    for p in fft_plan.leaf_passes:
        if p.kind == "bluestein":
            # Chirp planes + B̂ spectrum, interned like every twiddle table.
            luts.append(tw.bluestein_chirp(p.n, inverse))
            luts.append(tw.bluestein_spectrum(p.n, p.n1, inverse))
            luts.append(tw.bluestein_postchirp(p.n, inverse))
        elif p.kind == "direct":
            luts.append(tw.dft_matrix(p.n, inverse))
        else:
            luts.append(tw.dft_matrix(p.n1, inverse))
            luts.append(tw.twiddle_grid(p.n1, p.n2, inverse))
            luts.append(tw.dft_matrix(p.n2, inverse))
    return tuple(luts)


def _pick_tiles(
    fft_plan: plan_lib.FFTPlan, batch_hint: Optional[int], *, gpu: bool = False
) -> tuple:
    """((leaf_n, batch_tile), ...) — budget-picked, capped by the batch hint.

    ``gpu`` selects the shared-memory working-set model (LUTs staged, not
    resident) against the device-resolved budget instead of the TPU VMEM
    model.  The hint only applies to level-free plans: under a split level
    each leaf runs with batch × co-factor rows, so capping by the user batch
    alone would collapse the tile (and explode the kernel grid) on large
    sizes.
    """
    picker = plan_lib.pick_batch_tile_gpu if gpu else plan_lib.pick_batch_tile
    tiles = []
    for p in fft_plan.leaf_passes:
        bt = picker(p)
        if batch_hint is not None and not fft_plan.levels:
            cap = 1 << (batch_hint - 1).bit_length()  # next pow2 >= hint
            bt = max(1, min(bt, cap))
        tiles.append((p.n, bt))
    return tuple(tiles)


def _tuned_tiles(
    fft_plan: plan_lib.FFTPlan,
    batch_hint: Optional[int],
    cfg: Optional[dict],
    *,
    gpu: bool = False,
) -> tuple:
    """The heuristic tiles of :func:`_pick_tiles`, scaled per leaf by a
    tuned plan config.

    The tuner's tile is relative to the *hint-free* heuristic (it cannot
    know per-call batch hints), so it is applied as a scale on top of the
    hint-capped default — a tuned halving halves the capped tile too, and
    the modeled (no-op) pick leaves the hint behavior untouched."""
    picker = plan_lib.pick_batch_tile_gpu if gpu else plan_lib.pick_batch_tile
    tiles = dict(_pick_tiles(fft_plan, batch_hint, gpu=gpu))
    if cfg:
        for leaf_n, bt in cfg.get("batch_tiles", {}).items():
            n = int(leaf_n)
            if n not in tiles:
                continue
            base = picker(fft_plan.leaf_pass(n))
            while base > int(bt) and tiles[n] > 1:
                base //= 2
                tiles[n] = max(1, tiles[n] // 2)
    return tuple(tiles.items())


class PlannedFFT:
    """A frozen, executable transform schedule (the cuFFT/FFTW plan handle).

    Carries the :class:`FFTSpec`, the resolved :class:`Backend`, the
    :class:`~repro.core.plan.FFTPlan` factorisation, pre-materialized
    twiddle/DFT LUTs and per-leaf batch tiles.  Calling it runs the
    transform; instances are hashable and interned by :func:`plan` so
    ``plan(spec) is plan(spec)``.

    The complex kinds — including fft2/ifft2, whose rows+columns compile
    into ONE joint :class:`~repro.core.plan.FFTPlan` program — execute
    directly through the backend.  The real-packing kinds (rfft/irfft/
    rfft2/irfft2) hold child PlannedFFT handles for their inner complex
    transforms plus an ``epilogue`` :class:`~repro.core.plan.Pass` — the
    Hermitian recombination executed as one more program pass (a single
    Pallas kernel on the pallas backend) rather than traced XLA glue; the
    2-D real kinds apply it row-wise between the row and column programs.
    """

    def __init__(
        self,
        spec: FFTSpec,
        backend: Backend,
        fft_plan: Optional[plan_lib.FFTPlan],
        *,
        children: tuple = (),
        luts: tuple = (),
        batch_tiles: tuple = (),
        epilogue: Optional[plan_lib.Pass] = None,
        tuned: Optional[dict] = None,
    ):
        self.spec = spec
        self.backend = backend
        self.fft_plan = fft_plan
        self.children = children
        self.luts = luts
        self.epilogue = epilogue
        self._batch_tiles = dict(batch_tiles)
        #: The tuning config this plan was built from (None = fixed
        #: heuristics) — see :mod:`repro.core.tuning`.
        self.tuned = tuned
        #: pass index → tuned grid-step chunk, consumed by the pallas
        #: executor; empty when untuned (heuristic chunks per pass).
        self.pass_chunks: Mapping[int, int] = (
            {int(k): int(v) for k, v in tuned.get("chunks", {}).items()}
            if tuned
            else {}
        )
        #: Leaf demotions recorded at execution time (kernel failed twice →
        #: quarantined → traced-XLA fallback) — see :mod:`repro.core.faults`.
        #: Empty on the happy path; appended to by the executors through the
        #: ``degradations`` thread, deduplicated per (backend, kind, pass).
        self._degradations: list = []

    # -- identity ----------------------------------------------------------

    def __hash__(self):
        return hash((self.spec, self.backend.name))

    def __eq__(self, other):
        return (
            isinstance(other, PlannedFFT)
            and self.spec == other.spec
            and self.backend.name == other.backend.name
        )

    def __repr__(self):
        return f"PlannedFFT({self.spec}, backend={self.backend.name!r})"

    # -- introspection -----------------------------------------------------

    @property
    def batch_tiles(self) -> Mapping[int, int]:
        """leaf length → chosen kernel batch tile (read-only: the handle is
        interned and shared process-wide)."""
        return types.MappingProxyType(self._batch_tiles)

    @property
    def degradations(self) -> tuple:
        """Leaf demotions this plan has taken (snapshot, execution-recorded).

        Each entry is ``{"backend", "kind", "pass", "reason"}``: a claimed
        pallas leaf that failed twice, was quarantined, and now executes
        through the traced-XLA fallback.  Includes the children's ledgers
        for the real-packing / composed kinds.
        """
        recs = list(self._degradations)
        for c in self.children:
            recs.extend(c.degradations)
        return tuple(recs)

    @property
    def hbm_round_trips(self) -> int:
        if self.fft_plan is not None:
            return self.fft_plan.hbm_round_trips
        trips = sum(c.hbm_round_trips for c in self.children)
        return trips + (1 if self.epilogue is not None else 0)

    @property
    def passes(self) -> tuple:
        """The linearized pass program this handle executes, in execution
        order (child passes for the real-packing kinds, with the Hermitian
        recombination epilogue slotted where it actually runs)."""
        if self.fft_plan is not None:
            return self.fft_plan.passes
        ep = (self.epilogue,) if self.epilogue is not None else ()
        kind = self.spec.kind
        if kind == "irfft":
            return ep + self.children[0].passes
        if kind == "rfft2":
            inner, cols = self.children
            return inner.passes + ep + cols.passes
        if kind == "irfft2":
            inner, cols = self.children
            return cols.passes + ep + inner.passes
        return tuple(p for c in self.children for p in c.passes) + ep

    @property
    def pass_claims(self) -> tuple:
        """Executing backend name per program pass, in :attr:`passes` order.

        Whole-plan backends claim every pass.  A backend with a per-leaf
        ``claims`` surface (``pallas_gpu``) reports its own name where the
        pass runs through its kernels and ``"xla"`` where its executor falls
        back — so a mixed program is observable leaf by leaf.
        """
        claims = self.backend.claims
        if claims is None:
            return tuple(self.backend.name for _ in self.passes)
        return tuple(
            self.backend.name if claims(p) else "xla" for p in self.passes
        )

    def describe(self) -> str:
        spec = self.spec
        size = f"N={spec.n2}x{spec.n}" if spec.n2 is not None else f"N={spec.n}"
        head = f"{spec.kind} {size} backend={self.backend.name}: "
        if self.fft_plan is not None:
            return (
                head
                + plan_lib.describe_program(self.fft_plan)
                + self._describe_tuned()
                + self._describe_bluestein()
                + self._describe_gpu()
                + self._describe_degraded()
            )
        parts = [plan_lib.describe_program(c.fft_plan) for c in self.children
                 if c.fft_plan is not None]
        s = head + " | ".join(parts)
        if self.epilogue is not None:
            s += f"; epilogue pass: {self.epilogue.kind} n={self.epilogue.n}"
        return (
            s
            + self._describe_bluestein()
            + self._describe_gpu()
            + self._describe_degraded()
        )

    def _describe_bluestein(self) -> str:
        """Chirp-conv pad and modeled overhead vs a hypothetical mixed-radix
        transform, appended for non-power-of-two lengths so the Bluestein tax
        is visible next to the schedule that pays it."""
        n = self.spec.n
        if n < 2 or not (n & (n - 1)):
            return ""
        from repro.analysis import roofline as rl  # lazy: analysis layer

        pad = (self.tuned or {}).get("bluestein_pad")
        rep = rl.bluestein_report(n, pad=pad)
        return (
            f"; bluestein: pad {rep['pad']} ({rep['pad_ratio']:.2f}x), "
            f"{rep['flops_overhead']:.1f}x flops vs mixed-radix, "
            f"{rep['hbm_round_trips']} hbm round trips"
        )

    def _describe_gpu(self) -> str:
        """Shared-memory bytes + global-memory round trips, appended for GPU
        plans — the paper's metric on the paper's hardware."""
        if self.backend.claims is None:
            return ""
        from repro.analysis import roofline as rl  # lazy: analysis layer

        rep = rl.gpu_plan_report(self)
        return (
            f"; gpu: {rep['global_round_trips']} global round trips, "
            f"{rep['smem_bytes_max'] / 1024:.0f} KiB peak smem/block "
            f"(budget {rep['smem_budget'] / 1024:.0f} KiB), "
            f"claims [{', '.join(rep['claims'])}]"
        )

    def _describe_degraded(self) -> str:
        """Leaf demotions, appended so a degraded schedule is visible next
        to the plan that took it (empty on the happy path)."""
        recs = self.degradations
        if not recs:
            return ""
        parts = [
            f"pass {r['pass']} {r['kind']} ({r['backend']}→xla)" for r in recs
        ]
        return "; DEGRADED: " + ", ".join(parts)

    def _describe_tuned(self) -> str:
        """The tuned choices per pass, appended to :meth:`describe` so the
        searched decisions are visible next to the schedule they shape."""
        if not self.tuned:
            return ""
        parts = [
            f"fused_max={self.tuned['fused_max']}",
            f"direct_max={self.tuned.get('direct_max', plan_lib.DIRECT_MAX)}",
        ]
        for i, c in sorted(self.pass_chunks.items()):
            parts.append(f"pass {i} chunk={c}")
        for n, bt in sorted(self._batch_tiles.items()):
            parts.append(f"leaf {n} tile={bt}")
        return "; tuned: " + ", ".join(parts)

    # -- execution ---------------------------------------------------------

    def _complex(self, xr, xi, inverse: bool, axis: int = -1) -> Planes:
        """Backend-executed complex transform over ``axis`` (-1 or -2).

        ``axis=-2`` goes to the backend natively when it declared axis
        support (the pencil column pass); otherwise through a transpose
        sandwich so externally registered last-axis backends keep working.
        """
        if axis == -1 or self.backend.takes_axis:
            return self.backend.fn(xr, xi, inverse=inverse, planned=self, axis=axis) \
                if self.backend.takes_axis \
                else self.backend.fn(xr, xi, inverse=inverse, planned=self)
        xr, xi = jnp.swapaxes(xr, axis, -1), jnp.swapaxes(xi, axis, -1)
        yr, yi = self.backend.fn(xr, xi, inverse=inverse, planned=self)
        return jnp.swapaxes(yr, axis, -1), jnp.swapaxes(yi, axis, -1)

    def _to_last(self, a):
        return jnp.moveaxis(a, self.spec.axis, -1)

    def _from_last(self, a):
        return jnp.moveaxis(a, -1, self.spec.axis)

    def apply_planes(self, xr: jax.Array, xi: jax.Array) -> Planes:
        """Run the planned transform on split float32 planes (axis-aware).

        This is the raw entry point used by the distributed pencil driver and
        the conv layer; :meth:`__call__` adds complex-array packing on top.
        An ``axis=-2`` complex plan executes as an in-place column pass on
        axis-capable backends — no materialized transpose.
        """
        kind = self.spec.kind
        if kind in ("fft2", "ifft2"):
            return self._fft2_planes(xr, xi)
        ax = self.spec.axis
        if ax < 0:
            ax += xr.ndim
        if kind in _COMPLEX_KINDS and ax == xr.ndim - 2 and xr.ndim >= 2:
            return self._complex(xr, xi, inverse=kind == "ifft", axis=-2)
        move = ax != xr.ndim - 1
        if move:
            xr, xi = self._to_last(xr), self._to_last(xi)
        if kind in _COMPLEX_KINDS:
            yr, yi = self._complex(xr, xi, inverse=kind == "ifft")
        else:
            raise PlanError(f"apply_planes on {kind!r} plan; use __call__")
        if move:
            yr, yi = self._from_last(yr), self._from_last(yi)
        return yr, yi

    def __call__(
        self, x: ArrayOrPlanes, check: Optional[str] = None
    ) -> ArrayOrPlanes:
        """Execute the planned transform.

        ``check`` arms an opt-in numerics guard over the result (host-side,
        eager-only): ``"nan"`` raises :class:`~repro.core.faults.NumericsError`
        on non-finite output values; ``"parseval"`` checks energy
        conservation (complex kinds) at :data:`PARSEVAL_RTOL` — a cheap
        structured detector for silent corruption on degraded or unfamiliar
        hardware paths.
        """
        kind = self.spec.kind
        if kind in _COMPLEX_KINDS or kind in ("fft2", "ifft2"):
            xr, xi, was_c = _split(x)
            yr, yi = self.apply_planes(xr, xi)
            out = _join(yr, yi, was_c)
        elif kind == "rfft":
            out = self._rfft(x)
        elif kind == "irfft":
            out = self._irfft(x)
        elif kind == "rfft2":
            out = self._rfft2(x)
        else:
            out = self._irfft2(x)
        if check is not None:
            self._run_check(x, out, check)
        return out

    def _run_check(self, x, out, check: str) -> None:
        """The opt-in numerics guards behind ``__call__(x, check=...)``."""
        if check not in ("nan", "parseval"):
            raise PlanError(
                f"unknown numerics check {check!r}; expected 'nan' or 'parseval'",
                spec=self.spec,
                backend=self.backend.name,
            )
        ins = list(x) if isinstance(x, (tuple, list)) else [x]
        outs = list(out) if isinstance(out, (tuple, list)) else [out]
        if any(isinstance(a, jax.core.Tracer) for a in ins + outs):
            raise PlanError(
                "numerics checks are host-side guards; call the plan with "
                "check= outside jit",
                spec=self.spec,
                backend=self.backend.name,
            )
        if check == "nan":
            if not all(bool(jnp.all(jnp.isfinite(a))) for a in outs):
                raise NumericsError(
                    "non-finite values in planned FFT output",
                    spec=self.spec,
                    backend=self.backend.name,
                    check="nan",
                )
            return
        kind = self.spec.kind
        if kind not in ("fft", "ifft", "fft2", "ifft2"):
            raise PlanError(
                f'check="parseval" covers the complex kinds, not {kind!r}',
                spec=self.spec,
                backend=self.backend.name,
            )

        def energy(arrays) -> float:
            # Split planes sum to the same |z|² as the packed complex array.
            return float(
                sum(np.sum(np.abs(np.asarray(a, np.complex128)) ** 2) for a in arrays)
            )

        e_in, e_out = energy(ins), energy(outs)
        scale = self.spec.n * (self.spec.n2 or 1)
        expected = e_in * scale if kind in ("fft", "fft2") else e_in / scale
        if not np.isclose(e_out, expected, rtol=PARSEVAL_RTOL, atol=1e-30):
            raise NumericsError(
                f"Parseval energy mismatch: output {e_out:.6g}, expected "
                f"{expected:.6g} (rtol {PARSEVAL_RTOL})",
                spec=self.spec,
                backend=self.backend.name,
                check="parseval",
            )

    # -- 2-D execution: ONE joint program, no transposes between the axes ---

    def _check_image(self, xr):
        n, n2 = self.spec.n, self.spec.n2
        if xr.ndim < 2 or xr.shape[-2:] != (n2, n):
            raise PlanError(
                f"{self.spec.kind} planned for (..., {n2}, {n}) images, "
                f"got shape {tuple(xr.shape)}"
            )

    def _axis_child(self, axis: int, inverse: bool) -> "PlannedFFT":
        """Cached 1-D plan of the same backend over one image axis — the
        composition path for backends without native multi-axis programs."""
        n = self.spec.n if axis == -1 else self.spec.n2
        return plan(
            FFTSpec(
                n=n,
                kind="ifft" if inverse else "fft",
                axis=axis,
                precision=self.spec.precision,
            ),
            backend=self.backend.name,
        )

    def _fft2_planes(self, xr, xi) -> Planes:
        self._check_image(xr)
        inverse = self.spec.kind == "ifft2"
        if self.fft_plan is not None and self.backend.capabilities.native_2d:
            # The joint program in one backend call: row passes over the
            # last axis, then the in-place strided-column pass — zero
            # materialized transposes (jaxpr-asserted in the tests).
            return self._complex(xr, xi, inverse=inverse)
        xr, xi = self._row_col_plans()[0].apply_planes(xr, xi)
        return self._row_col_plans()[1].apply_planes(xr, xi)

    def _row_col_plans(self) -> tuple:
        """The per-axis 1-D plans of the composition path: the pre-built
        children for beyond-fused column lengths, lazily cached axis plans
        otherwise (backends without native multi-axis programs)."""
        if self.children:
            return self.children
        inverse = self.spec.kind == "ifft2"
        return self._axis_child(-1, inverse), self._axis_child(-2, inverse)

    def apply_rows(self, xr: jax.Array, xi: jax.Array) -> Planes:
        """Run only the row (last-axis) sub-program of a 2-D plan.

        The distributed pencil driver consumes the joint program in two
        halves around its all-to-all transposes: row passes on the
        row-sharded slab, column passes on the column slab."""
        if self.spec.kind not in ("fft2", "ifft2"):
            raise PlanError(f"apply_rows needs a 2-D complex plan, not {self.spec.kind!r}")
        inverse = self.spec.kind == "ifft2"
        if self.fft_plan is None or not self.backend.capabilities.native_2d:
            return self._row_col_plans()[0].apply_planes(xr, xi)
        from repro.kernels import ops as kernel_ops  # lazy: avoids cycle

        row_idx = [i for i, p in enumerate(self.fft_plan.passes) if p.axis == -1]
        row_passes = tuple(self.fft_plan.passes[i] for i in row_idx)
        lead, n = xr.shape[:-1], xr.shape[-1]
        b = int(np.prod(lead)) if lead else 1
        yr, yi = kernel_ops.execute_program(
            xr.reshape(b, n),
            xi.reshape(b, n),
            row_passes,
            inverse=inverse,
            batch_tiles=self._batch_tiles,
            chunks=self._half_chunks(row_idx),
            degradations=self._degradations,
        )
        return yr.reshape(*lead, n), yi.reshape(*lead, n)

    def _half_chunks(self, idx: list) -> Optional[dict]:
        """Re-index tuned pass chunks onto a program half (the joint
        program's pass indices renumber when rows/cols run separately)."""
        chunks = {
            j: self.pass_chunks[i]
            for j, i in enumerate(idx)
            if i in self.pass_chunks
        }
        return chunks or None

    def apply_cols(self, xr: jax.Array, xi: jax.Array) -> Planes:
        """Run only the column (axis -2) sub-program of a 2-D plan, in place
        over whatever width the slab carries (see :meth:`apply_rows`)."""
        if self.spec.kind not in ("fft2", "ifft2"):
            raise PlanError(f"apply_cols needs a 2-D complex plan, not {self.spec.kind!r}")
        inverse = self.spec.kind == "ifft2"
        if self.fft_plan is None or not self.backend.capabilities.native_2d:
            return self._row_col_plans()[1].apply_planes(xr, xi)
        from repro.kernels import ops as kernel_ops  # lazy: avoids cycle

        col_idx = [i for i, p in enumerate(self.fft_plan.passes) if p.axis == -2]
        col_passes = tuple(self.fft_plan.passes[i] for i in col_idx)
        if not col_passes:
            return xr, xi
        lead, (rows, w) = xr.shape[:-2], xr.shape[-2:]
        if rows != self.spec.n2:
            raise PlanError(f"plan is for n2={self.spec.n2} columns, got {rows}")
        b = int(np.prod(lead)) if lead else 1
        yr, yi = kernel_ops.execute_program2d(
            xr.reshape(b, rows, w),
            xi.reshape(b, rows, w),
            col_passes,
            inverse=inverse,
            batch_tiles=self._batch_tiles,
            chunks=self._half_chunks(col_idx),
            degradations=self._degradations,
        )
        return yr.reshape(*lead, rows, w), yi.reshape(*lead, rows, w)

    def _recomb_kernel(self) -> bool:
        """Whether the Hermitian recombination runs as a Pallas epilogue pass
        (pallas backend) instead of traced XLA glue."""
        return self.backend.name == "pallas" and self.epilogue is not None

    def _recomb_fwd(self, Zr, Zi) -> Planes:
        """Forward Hermitian recombination over the last axis: the packed
        (..., m) spectrum → (..., m+1) real-FFT bins.  One Pallas epilogue
        pass on the pallas backend (row-wise over any leading dims — the 2-D
        kinds reuse it across the image's rows), traced jnp elsewhere."""
        wr_np, wi_np = self.luts[0]
        m = Zr.shape[-1]
        if self._recomb_kernel():

            def kernel() -> Planes:
                from repro.kernels import ops as kernel_ops
                from repro.kernels import pencil as pencil_kernels

                lead = Zr.shape[:-1]
                b = int(np.prod(lead)) if lead else 1
                Xr, Xi = pencil_kernels.rfft_recomb_call(
                    Zr.reshape(b, m), Zi.reshape(b, m), wr_np, wi_np,
                    interpret=kernel_ops.should_interpret(),
                )
                return Xr.reshape(*lead, m + 1), Xi.reshape(*lead, m + 1)

            return faults.run_leaf(
                self.backend.name,
                self.epilogue.kind,
                kernel,
                lambda: fft_xla.rfft_recomb(
                    Zr, Zi, jnp.asarray(wr_np), jnp.asarray(wi_np)
                ),
                degradations=self._degradations,
            )
        wr, wi = jnp.asarray(wr_np), jnp.asarray(wi_np)
        return fft_xla.rfft_recomb(Zr, Zi, wr, wi)

    def _recomb_inv(self, Xr, Xi) -> Planes:
        """Inverse recombination over the last axis: (..., m+1) bins → the
        packed (..., m) spectrum (mirror of :meth:`_recomb_fwd`)."""
        wr_np, wi_np = self.luts[0]  # e^{+2πik/n}
        m = Xr.shape[-1] - 1
        if self._recomb_kernel():

            def kernel() -> Planes:
                from repro.kernels import ops as kernel_ops
                from repro.kernels import pencil as pencil_kernels

                lead = Xr.shape[:-1]
                b = int(np.prod(lead)) if lead else 1
                Zr, Zi = pencil_kernels.irfft_recomb_call(
                    Xr.reshape(b, m + 1), Xi.reshape(b, m + 1), wr_np, wi_np,
                    interpret=kernel_ops.should_interpret(),
                )
                return Zr.reshape(*lead, m), Zi.reshape(*lead, m)

            return faults.run_leaf(
                self.backend.name,
                self.epilogue.kind,
                kernel,
                lambda: fft_xla.irfft_recomb(
                    Xr, Xi, jnp.asarray(wr_np), jnp.asarray(wi_np)
                ),
                degradations=self._degradations,
            )
        wr, wi = jnp.asarray(wr_np), jnp.asarray(wi_np)
        return fft_xla.irfft_recomb(Xr, Xi, wr, wi)

    def _rfft(self, x: jax.Array) -> Planes:
        """Real FFT via even/odd complex packing — N/2-point complex transform.

        Beyond-paper optimisation: the paper transforms complex signals only;
        for the real signals of the SAR / long-conv workloads this halves both
        the arithmetic and — more importantly here — the HBM traffic of the
        forward transform.  Returns (real, imag) planes of n//2 + 1 bins.

        The Hermitian recombination is the plan's ``epilogue`` pass: one
        Pallas kernel round trip on the pallas backend (see
        ``kernels.pencil.rfft_recomb_call``), traced jnp on the others.
        """
        n = self.spec.n
        x = jnp.asarray(x, jnp.float32)
        move = self.spec.axis != -1
        if move:
            x = self._to_last(x)
        if x.shape[-1] != n:
            raise PlanError(f"rfft planned for n={n}, got axis length {x.shape[-1]}")
        (inner,) = self.children
        if n % 2:
            # Odd length: full complex transform (Bluestein leaf), sliced to
            # the n//2+1 Hermitian bins.
            Xr, Xi = inner._complex(x, jnp.zeros_like(x), inverse=False)
            Xr, Xi = Xr[..., : n // 2 + 1], Xi[..., : n // 2 + 1]
        else:
            zr = x[..., 0::2]  # even samples  -> real plane
            zi = x[..., 1::2]  # odd samples   -> imag plane
            Zr, Zi = inner._complex(zr, zi, inverse=False)
            Xr, Xi = self._recomb_fwd(Zr, Zi)
        if move:
            Xr, Xi = self._from_last(Xr), self._from_last(Xi)
        return Xr, Xi

    def _irfft(self, x: Planes) -> jax.Array:
        """Inverse of the rfft packing; output is the length-``n`` real signal.

        The recombination prologue mirrors :meth:`_rfft`: a single Pallas
        pass on the pallas backend, traced jnp elsewhere.
        """
        n = self.spec.n
        Xr, Xi = x
        move = self.spec.axis != -1
        if move:
            Xr, Xi = self._to_last(Xr), self._to_last(Xi)
        m = n // 2
        if Xr.shape[-1] != m + 1:
            raise PlanError(f"irfft expects n//2+1={m + 1} bins, got {Xr.shape[-1]}")
        (inner,) = self.children
        if n % 2:
            # Odd length: Hermitian-extend the bins to the full spectrum,
            # complex inverse (Bluestein leaf), real part.
            Zr = jnp.concatenate([Xr, jnp.flip(Xr[..., 1:], -1)], axis=-1)
            Zi = jnp.concatenate([Xi, -jnp.flip(Xi[..., 1:], -1)], axis=-1)
            out, _ = inner._complex(Zr, Zi, inverse=True)
        else:
            Zr, Zi = self._recomb_inv(Xr, Xi)
            zr, zi = inner._complex(Zr, Zi, inverse=True)
            out = jnp.stack([zr, zi], axis=-1).reshape(*zr.shape[:-1], n)
        if move:
            out = self._from_last(out)
        return out

    def _rfft2(self, x: jax.Array) -> Planes:
        """Real 2-D FFT: row rfft (packed complex rows + row-wise Hermitian
        recombination epilogue) followed by the full complex column pass over
        the (..., n2, n//2+1) half-spectrum — numpy ``rfft2`` layout.  On the
        pallas backend every stage is a kernel pass: the packed row program,
        the recombination epilogue, and the in-place strided-column pass."""
        n = self.spec.n
        x = jnp.asarray(x, jnp.float32)
        self._check_image(x)
        inner, cols = self.children
        zr = x[..., 0::2]  # even samples  -> real plane
        zi = x[..., 1::2]  # odd samples   -> imag plane
        Zr, Zi = inner._complex(zr, zi, inverse=False)
        Xr, Xi = self._recomb_fwd(Zr, Zi)  # (..., n2, n//2 + 1)
        return cols._complex(Xr, Xi, inverse=False, axis=-2)

    def _irfft2(self, x: Planes) -> jax.Array:
        """Inverse of :meth:`_rfft2`: column ifft over the half-spectrum,
        inverse recombination row-wise, packed row ifft, sample interleave."""
        n, n2 = self.spec.n, self.spec.n2
        Xr, Xi = x
        m = n // 2
        if Xr.ndim < 2 or Xr.shape[-2:] != (n2, m + 1):
            raise PlanError(
                f"irfft2 expects (..., {n2}, {m + 1}) bins, got {tuple(Xr.shape)}"
            )
        inner, cols = self.children
        Xr, Xi = cols._complex(Xr, Xi, inverse=True, axis=-2)
        Zr, Zi = self._recomb_inv(Xr, Xi)
        zr, zi = inner._complex(Zr, Zi, inverse=True)
        return jnp.stack([zr, zi], axis=-1).reshape(*zr.shape[:-1], n)


# ---------------------------------------------------------------------------
# plan()
# ---------------------------------------------------------------------------


def plan(
    spec: FFTSpec | int,
    *,
    backend: Optional[str] = None,
    tune: Optional[str] = None,
) -> PlannedFFT:
    """Resolve ``spec`` into an interned :class:`PlannedFFT` executor.

    ``backend=None`` uses the innermost :func:`use_backend` scope, the
    ``REPRO_FFT_BACKEND`` env var, or capability negotiation, in that order.
    Plans are cached: the same (spec, backend, platform, tune mode) returns
    the *same* object, so jit tracing of a planned call hits the
    compilation cache.

    ``tune`` selects how the plan's performance knobs (fused-vs-split
    crossover, per-pass chunk widths, leaf batch tiles) are chosen:
    ``"off"`` keeps the fixed VMEM-budget heuristics, ``"model"`` (the
    default, also via ``REPRO_FFT_TUNE``) takes the roofline model's pick
    with zero measurements, and ``"measure"`` times the roofline-pruned
    survivors once and records the winner in the persistent tuning cache —
    see :mod:`repro.core.tuning`.
    """
    from repro.core import tuning  # lazy: tuning imports the conv engines

    if isinstance(spec, int):
        spec = FFTSpec(n=spec)
    name = backend if backend is not None else default_backend()
    return _plan_cached(spec, name, jax.default_backend(), tuning.resolve_mode(tune))


#: Ring-buffer capacity of the plan log: long sessions (serving loops that
#: plan thousands of shapes) keep the most recent schedules instead of
#: growing without bound.
PLAN_LOG_MAX = 1024

#: Every (FFTSpec, backend name) materialized by :func:`_plan_cached`, in
#: creation order — a bounded deque of the last :data:`PLAN_LOG_MAX`
#: entries.  Cache hits don't re-log, so the tail of the log after a
#: snapshot is exactly the set of *new* schedules an operation forced —
#: which is how the tests assert overlap-save never plans past FUSED_MAX.
_PLAN_LOG: collections.deque = collections.deque(maxlen=PLAN_LOG_MAX)


def plan_log() -> tuple:
    """Snapshot of the most recent (spec, backend_name) pairs planned this
    process (ring buffer of :data:`PLAN_LOG_MAX`; oldest entries fall off)."""
    return tuple(_PLAN_LOG)


def clear_plan_log() -> None:
    """Empty the plan log (the creation-order record, NOT the plan cache —
    existing :class:`PlannedFFT` handles stay interned)."""
    _PLAN_LOG.clear()


@functools.lru_cache(maxsize=1024)
def _plan_cached(
    spec: FFTSpec, backend_name: Optional[str], platform: str, tune: str = "model"
) -> PlannedFFT:
    planned = _build_plan(spec, backend_name, platform, tune)
    _PLAN_LOG.append((spec, planned.backend.name))
    return planned


def _build_plan(
    spec: FFTSpec, backend_name: Optional[str], platform: str, tune: str = "model"
) -> PlannedFFT:
    from repro.core import tuning  # lazy: tuning imports the conv engines

    if backend_name is None:
        entry = _negotiate(spec, platform)
        if entry.claims is not None and tune != "off":
            # A per-leaf backend won negotiation: let the tuner decide the
            # pallas↔xla crossover for this device (modeled by default,
            # measured under tune="measure"; cached either way).
            pick = tuning.backend_pick(spec, platform, tune)
            if pick is not None and pick != entry.name:
                entry = get_backend(pick)
    else:
        entry = get_backend(backend_name)
        if not entry.capabilities.supports(spec, platform):
            raise PlanError(
                f"backend {entry.name!r} does not support {spec} on {platform!r}"
            )

    kind = spec.kind
    gpu = entry.claims is not None
    if kind in _COMPLEX_KINDS:
        cfg = tuning.plan_config(spec, entry.name, tune)
        fft_plan = plan_lib.plan_fft(
            spec.n,
            cfg["fused_max"] if cfg else plan_lib.FUSED_MAX,
            cfg.get("direct_max", plan_lib.DIRECT_MAX) if cfg else plan_lib.DIRECT_MAX,
            pad=cfg.get("bluestein_pad") if cfg else None,
        )
        return PlannedFFT(
            spec,
            entry,
            fft_plan,
            luts=_materialize_luts(fft_plan, kind == "ifft", entry.name),
            batch_tiles=_tuned_tiles(fft_plan, spec.batch_hint, cfg, gpu=gpu),
            tuned=cfg,
        )

    if kind in ("fft2", "ifft2") and plan_lib.joint2d_supported(spec.n2):
        # ONE joint multi-axis program: row passes over the last axis,
        # then the column passes over n2 — in-place for fused-regime
        # columns, strip-mined (width-swept multi-factor strided passes)
        # beyond — no per-axis child plans and no transposes between the
        # axes (compile_passes2d).
        cfg = tuning.plan_config(spec, entry.name, tune)
        fft_plan = plan_lib.plan_fft2(
            spec.n,
            spec.n2,
            cfg["fused_max"] if cfg else plan_lib.FUSED_MAX,
            cfg.get("direct_max", plan_lib.DIRECT_MAX) if cfg else plan_lib.DIRECT_MAX,
        )
        return PlannedFFT(
            spec,
            entry,
            fft_plan,
            luts=_materialize_luts(fft_plan, kind == "ifft2", entry.name),
            batch_tiles=_tuned_tiles(fft_plan, None, cfg, gpu=gpu),
            tuned=cfg,
        )

    def child(n: int, inverse: bool, batch_hint: Optional[int], axis: int = -1) -> PlannedFFT:
        return _plan_cached(
            FFTSpec(
                n=n,
                kind="ifft" if inverse else "fft",
                axis=axis,
                precision=spec.precision,
                batch_hint=batch_hint,
            ),
            entry.name,
            platform,
            tune,
        )

    if kind in ("fft2", "ifft2"):
        # Column length beyond even the strip-mined gate (> FUSED_MAX²):
        # the handle composes the row plan and the axis=-2 column plan.
        inverse2 = kind == "ifft2"
        rows = child(spec.n, inverse2, None)
        cols = child(spec.n2, inverse2, None, axis=-2)
        return PlannedFFT(spec, entry, None, children=(rows, cols))

    inverse = kind in ("irfft", "irfft2")
    if kind in ("rfft", "irfft") and spec.n % 2:
        # Odd length: the even/odd complex packing needs an even split, so
        # the real transform runs as a full-length complex Bluestein FFT
        # (imag plane zero) sliced to the n//2+1 Hermitian bins — no
        # recombination epilogue.
        inner = child(spec.n, inverse, spec.batch_hint)
        return PlannedFFT(spec, entry, None, children=(inner,))
    m = spec.n // 2
    bins = (1, 1, m + 1)
    epilogue = plan_lib.Pass(
        kind="irfft_recomb" if inverse else "rfft_recomb",
        n=spec.n,
        view_in=bins if inverse else (1, 1, m),
        view_out=(1, 1, m) if inverse else bins,
        order="natural",
    )
    luts = (tw.rfft_recomb_twiddle(spec.n, inverse=inverse),)
    # The packed complex row transform sees the caller's batch unchanged.
    inner = child(m, inverse, spec.batch_hint if kind in ("rfft", "irfft") else None)
    if kind in ("rfft", "irfft"):
        return PlannedFFT(
            spec, entry, None, children=(inner,), luts=luts, epilogue=epilogue
        )
    # rfft2 / irfft2: packed rows + recomb epilogue + axis=-2 column pass
    # over the half-spectrum (the column plan executes in place at whatever
    # width the slab carries, so the non-power-of-two m+1 bins are fine).
    cols = child(spec.n2, inverse, None, axis=-2)
    return PlannedFFT(
        spec, entry, None, children=(inner, cols), luts=luts, epilogue=epilogue
    )


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


def _swap_to_last(fn):
    """Run a last-axis transform over axis -2 via a transpose sandwich."""

    def run(xr, xi, *args, **kw):
        xr, xi = jnp.swapaxes(xr, -1, -2), jnp.swapaxes(xi, -1, -2)
        yr, yi = fn(xr, xi, *args, **kw)
        return jnp.swapaxes(yr, -1, -2), jnp.swapaxes(yi, -1, -2)

    return run


def _stockham_backend(xr, xi, *, inverse, planned, axis=-1):
    f = fft_xla.stockham_fft
    if axis == -2:
        f = _swap_to_last(f)
    return f(xr, xi, inverse=inverse)


def _xla_backend(xr, xi, *, inverse, planned, axis=-1):
    n = planned.fft_plan.n
    if n & (n - 1):
        # Non-pow2: traced Bluestein (chirp → cached pow2 conv → chirp).
        f = fft_xla.bluestein_fft
        if axis == -2:
            f = _swap_to_last(f)
        return f(xr, xi, inverse=inverse)
    if axis == -2:
        if n <= plan_lib.DIRECT_MAX and n > 1:
            # Transpose-free column DFT: contract axis -2 directly (the XLA
            # analogue of the pencil column pass); 1/n for inverse is the
            # leaf convention of four_step_fft's direct leaves.
            yr, yi = fft_xla._col_dft(xr, xi, n, inverse)
            if inverse:
                yr, yi = yr / n, yi / n
            return yr, yi
        return _swap_to_last(fft_xla.four_step_fft)(xr, xi, inverse=inverse)
    return fft_xla.four_step_fft(xr, xi, inverse=inverse)


def _pallas_backend(xr, xi, *, inverse, planned, axis=-1):
    from repro.kernels import ops as kernel_ops  # lazy: avoids import cycle

    return kernel_ops.execute_plan(
        xr,
        xi,
        planned.fft_plan,
        inverse=inverse,
        batch_tiles=planned.batch_tiles,
        axis=axis,
        chunks=planned.pass_chunks or None,
        degradations=planned._degradations,
    )


def _pallas_gpu_backend(xr, xi, *, inverse, planned, axis=-1):
    from repro.kernels import fft_gpu  # lazy: avoids import cycle

    if axis == -2:
        # Column transforms are not on the GPU claim surface yet — same
        # transpose-free contraction / sandwich the xla backend uses.
        return _xla_backend(xr, xi, inverse=inverse, planned=planned, axis=axis)
    return fft_gpu.execute_plan_gpu(
        xr,
        xi,
        planned.fft_plan,
        inverse=inverse,
        batch_tiles=planned.batch_tiles,
        degradations=planned._degradations,
    )


def _pallas_gpu_claims(p) -> bool:
    from repro.kernels import fft_gpu  # lazy: avoids import cycle

    return fft_gpu.gpu_claims(p)


register_backend(
    "stockham",
    _stockham_backend,
    BackendCapabilities(priority=0),
)
register_backend(
    "xla",
    _xla_backend,
    BackendCapabilities(
        preferred_platforms=frozenset({"cpu", "gpu"}), bluestein=True
    ),
)
register_backend(
    "pallas",
    _pallas_backend,
    BackendCapabilities(
        platforms=frozenset({"cpu", "tpu"}),  # cpu = interpret mode
        preferred_platforms=frozenset({"tpu"}),
        native_2d=True,  # executes joint rows+cols programs in one call
        bluestein=True,
    ),
)
# The paper's native hardware.  Registered after xla so the registration-
# order tie-break resolves the shared gpu preference toward the Triton-shaped
# kernels; cpu stays xla's (pallas_gpu does not prefer cpu — it merely runs
# there under interpret mode, which is how CI proves its numerics).
register_backend(
    "pallas_gpu",
    _pallas_gpu_backend,
    BackendCapabilities(
        platforms=frozenset({"cpu", "gpu"}),  # cpu = interpret mode
        preferred_platforms=frozenset({"gpu"}),
        bluestein=True,
    ),
    claims=_pallas_gpu_claims,
)


# ---------------------------------------------------------------------------
# Plan-cached convenience wrappers (compatibility surface)
# ---------------------------------------------------------------------------


def fft(x: ArrayOrPlanes, *, axis: int = -1, backend: Optional[str] = None) -> ArrayOrPlanes:
    """Complex FFT over ``axis`` (any length ≥ 1), via a cached plan.

    Non-power-of-two lengths route through the planner's Bluestein leaf."""
    n = int(_input_shape(x)[axis])
    return plan(FFTSpec(n=n, kind="fft", axis=axis), backend=backend)(x)


def ifft(x: ArrayOrPlanes, *, axis: int = -1, backend: Optional[str] = None) -> ArrayOrPlanes:
    n = int(_input_shape(x)[axis])
    return plan(FFTSpec(n=n, kind="ifft", axis=axis), backend=backend)(x)


def rfft(x: jax.Array, *, axis: int = -1, backend: Optional[str] = None) -> Planes:
    """Real FFT: n//2+1 bins over ``axis`` via even/odd complex packing."""
    n = int(jnp.shape(x)[axis])
    return plan(FFTSpec(n=n, kind="rfft", axis=axis), backend=backend)(x)


def irfft(x: Planes, n: int, *, axis: int = -1, backend: Optional[str] = None) -> jax.Array:
    """Inverse of :func:`rfft`; output is the length-``n`` real signal."""
    return plan(FFTSpec(n=n, kind="irfft", axis=axis), backend=backend)(x)


def fft2(x: ArrayOrPlanes, *, backend: Optional[str] = None) -> ArrayOrPlanes:
    """2-D FFT over the last two axes (row pass then column pass)."""
    shape = _input_shape(x)
    spec = FFTSpec(n=int(shape[-1]), kind="fft2", n2=int(shape[-2]))
    return plan(spec, backend=backend)(x)


def ifft2(x: ArrayOrPlanes, *, backend: Optional[str] = None) -> ArrayOrPlanes:
    shape = _input_shape(x)
    spec = FFTSpec(n=int(shape[-1]), kind="ifft2", n2=int(shape[-2]))
    return plan(spec, backend=backend)(x)


def rfft2(x: jax.Array, *, backend: Optional[str] = None) -> Planes:
    """Real 2-D FFT of an (..., n2, n) image: (..., n2, n//2 + 1) bins
    (numpy ``rfft2`` layout), via a cached rfft2 plan."""
    shape = jnp.shape(x)
    spec = FFTSpec(n=int(shape[-1]), kind="rfft2", n2=int(shape[-2]))
    return plan(spec, backend=backend)(x)


def irfft2(x: Planes, n: int, n2: int, *, backend: Optional[str] = None) -> jax.Array:
    """Inverse of :func:`rfft2`; output is the real (..., n2, n) image."""
    return plan(FFTSpec(n=n, kind="irfft2", n2=n2), backend=backend)(x)
