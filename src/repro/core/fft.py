"""Public FFT API — backend dispatch over the paper's algorithm.

Backends
--------
``pallas``    fused Pallas TPU kernels (``repro.kernels``), one HBM round trip
              per plan level.  Runs under ``interpret=True`` on CPU.
``xla``       pure-JAX four-step with the same factorisation (MXU matmuls on
              TPU, portable everywhere).  Default on CPU.
``stockham``  radix-2 butterfly reference (the paper's original formulation).

All functions accept either a complex array or a ``(real, imag)`` tuple of
float32 planes, and return whichever form was supplied.  Transform axis is
always the last one; move axes outside (cheap under jit) if needed.
"""

from __future__ import annotations

import os
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft_xla
from repro.core import twiddle as tw

Planes = Tuple[jax.Array, jax.Array]
ArrayOrPlanes = Union[jax.Array, Planes]

__all__ = [
    "fft",
    "ifft",
    "rfft",
    "irfft",
    "fft2",
    "ifft2",
    "default_backend",
    "set_default_backend",
]

_DEFAULT_BACKEND = os.environ.get("REPRO_FFT_BACKEND", "xla")


def default_backend() -> str:
    return _DEFAULT_BACKEND


def set_default_backend(name: str) -> None:
    global _DEFAULT_BACKEND
    if name not in ("pallas", "xla", "stockham"):
        raise ValueError(f"unknown FFT backend {name!r}")
    _DEFAULT_BACKEND = name


def _split(x: ArrayOrPlanes) -> tuple[jax.Array, jax.Array, bool]:
    """Returns (real, imag, was_complex)."""
    if isinstance(x, (tuple, list)):
        xr, xi = x
        return jnp.asarray(xr, jnp.float32), jnp.asarray(xi, jnp.float32), False
    x = jnp.asarray(x)
    if jnp.iscomplexobj(x):
        return (
            jnp.real(x).astype(jnp.float32),
            jnp.imag(x).astype(jnp.float32),
            True,
        )
    return x.astype(jnp.float32), jnp.zeros_like(x, jnp.float32), True


def _join(yr, yi, was_complex: bool) -> ArrayOrPlanes:
    if was_complex:
        return jax.lax.complex(yr, yi)
    return yr, yi


def _dispatch(xr, xi, inverse: bool, backend: str | None) -> Planes:
    backend = backend or _DEFAULT_BACKEND
    if backend == "stockham":
        return fft_xla.stockham_fft(xr, xi, inverse=inverse)
    if backend == "xla":
        return fft_xla.four_step_fft(xr, xi, inverse=inverse)
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops  # lazy: avoids cycle

        return kernel_ops.fft(xr, xi, inverse=inverse)
    raise ValueError(f"unknown FFT backend {backend!r}")


def fft(x: ArrayOrPlanes, *, backend: str | None = None) -> ArrayOrPlanes:
    """Complex FFT over the last axis (power-of-two length)."""
    xr, xi, was_c = _split(x)
    yr, yi = _dispatch(xr, xi, False, backend)
    return _join(yr, yi, was_c)


def ifft(x: ArrayOrPlanes, *, backend: str | None = None) -> ArrayOrPlanes:
    xr, xi, was_c = _split(x)
    yr, yi = _dispatch(xr, xi, True, backend)
    return _join(yr, yi, was_c)


def rfft(x: jax.Array, *, backend: str | None = None) -> Planes:
    """Real FFT via even/odd complex packing — N/2-point complex transform.

    Beyond-paper optimisation: the paper transforms complex signals only; for
    the real signals of the SAR / long-conv workloads this halves both the
    arithmetic and — more importantly here — the HBM traffic of the forward
    transform.  Returns (real, imag) planes of length n//2 + 1.
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    if n & (n - 1) or n < 2:
        raise ValueError(f"rfft length must be a power of two >= 2, got {n}")
    zr = x[..., 0::2]  # even samples  -> real plane
    zi = x[..., 1::2]  # odd samples   -> imag plane
    Zr, Zi = _dispatch(zr, zi, False, backend)
    m = n // 2
    # Z[-k] with wraparound: index (m - k) mod m.
    idx = (m - jnp.arange(m)) % m
    Zr_f, Zi_f = Zr[..., idx], Zi[..., idx]
    # E[k] = (Z[k] + conj(Z[-k]))/2 ; O[k] = (Z[k] - conj(Z[-k]))/(2i)
    Er, Ei = (Zr + Zr_f) * 0.5, (Zi - Zi_f) * 0.5
    Or_, Oi = (Zi + Zi_f) * 0.5, (Zr_f - Zr) * 0.5
    wr_np, wi_np = tw.rfft_recomb_twiddle(n)
    wr, wi = jnp.asarray(wr_np)[: m], jnp.asarray(wi_np)[: m]
    Tr, Ti = fft_xla.cmul(Or_, Oi, wr, wi)
    Xr, Xi = Er + Tr, Ei + Ti
    # k = m (Nyquist): X[m] = E[0] - O[0] (real for real input).
    nyq_r = Er[..., 0:1] - Or_[..., 0:1]
    nyq_i = Ei[..., 0:1] - Oi[..., 0:1]
    Xr = jnp.concatenate([Xr, nyq_r], axis=-1)
    Xi = jnp.concatenate([Xi, nyq_i], axis=-1)
    return Xr, Xi


def irfft(x: Planes, n: int, *, backend: str | None = None) -> jax.Array:
    """Inverse of :func:`rfft`; output is the length-``n`` real signal."""
    Xr, Xi = x
    m = n // 2
    if Xr.shape[-1] != m + 1:
        raise ValueError(f"irfft expects n//2+1={m + 1} bins, got {Xr.shape[-1]}")
    # Reconstruct E and O from X[k], X*[m-k]:
    idx = m - jnp.arange(m)
    Xr_k, Xi_k = Xr[..., :m], Xi[..., :m]
    Xr_f, Xi_f = Xr[..., idx], Xi[..., idx]
    Er, Ei = (Xr_k + Xr_f) * 0.5, (Xi_k - Xi_f) * 0.5
    Dr, Di = (Xr_k - Xr_f) * 0.5, (Xi_k + Xi_f) * 0.5
    wr_np, wi_np = tw.rfft_recomb_twiddle(n, inverse=True)  # e^{+2πik/n}
    wr, wi = jnp.asarray(wr_np)[: m], jnp.asarray(wi_np)[: m]
    Or_, Oi = fft_xla.cmul(Dr, Di, wr, wi)
    # Z = E + i·O
    Zr = Er - Oi
    Zi = Ei + Or_
    zr, zi = _dispatch(Zr, Zi, True, backend)
    out = jnp.stack([zr, zi], axis=-1).reshape(*zr.shape[:-1], n)
    return out


def fft2(x: ArrayOrPlanes, *, backend: str | None = None) -> ArrayOrPlanes:
    """2-D FFT over the last two axes (row pass then column pass)."""
    xr, xi, was_c = _split(x)
    yr, yi = _dispatch(xr, xi, False, backend)  # rows
    yr, yi = jnp.swapaxes(yr, -1, -2), jnp.swapaxes(yi, -1, -2)
    yr, yi = _dispatch(yr, yi, False, backend)  # columns
    yr, yi = jnp.swapaxes(yr, -1, -2), jnp.swapaxes(yi, -1, -2)
    return _join(yr, yi, was_c)


def ifft2(x: ArrayOrPlanes, *, backend: str | None = None) -> ArrayOrPlanes:
    xr, xi, was_c = _split(x)
    yr, yi = _dispatch(xr, xi, True, backend)
    yr, yi = jnp.swapaxes(yr, -1, -2), jnp.swapaxes(yi, -1, -2)
    yr, yi = _dispatch(yr, yi, True, backend)
    yr, yi = jnp.swapaxes(yr, -1, -2), jnp.swapaxes(yi, -1, -2)
    return _join(yr, yi, was_c)
