"""Twiddle-factor / DFT-matrix factory — the paper's "texture memory" stage.

The paper precomputes sine/cosine tables once and serves them through the GPU
texture cache so butterfly kernels never recompute or re-fetch them from
global memory (§2.3.1).  The TPU analogue implemented here:

* tables are computed **once on the host** in float64 and cached per size
  (``functools.lru_cache`` over hashable plan keys);
* they enter kernels as **operands** whose BlockSpec maps every grid step to
  the same block, so XLA/Mosaic keeps them VMEM-resident across the whole
  batch grid — computed once, read at VMEM bandwidth, exactly the texture-LUT
  behaviour;
* for sizes too large to embed as constants, :func:`traced_twiddle` generates
  them with on-device iota arithmetic instead (still computed once per jit).

All tables are returned as split real/imag ``float32`` planes because Pallas
TPU kernels have no native complex dtype.
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "dft_matrix",
    "twiddle_grid",
    "pass_twiddle",
    "stage_twiddle",
    "mulfrac_pow2",
    "traced_twiddle",
    "rfft_recomb_twiddle",
    "bluestein_chirp",
    "bluestein_postchirp",
    "bluestein_spectrum",
]


@functools.lru_cache(maxsize=256)
def _dft_matrix_np(n: int, inverse: bool) -> tuple[np.ndarray, np.ndarray]:
    """(n, n) DFT matrix W[j, k] = exp(∓2πi·j·k/n), float64 → float32 planes."""
    j = np.arange(n, dtype=np.float64)
    # Reduce j*k mod n in integer arithmetic first: keeps the argument of
    # sin/cos small so float64 → float32 rounding stays at the ulp level even
    # for n = 2**20 (j*k up to ~1e12 would lose precision otherwise).
    jk = np.outer(np.arange(n, dtype=np.int64), np.arange(n, dtype=np.int64)) % n
    ang = (2.0 * np.pi / n) * jk.astype(np.float64)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


def dft_matrix(n: int, inverse: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Cached (n, n) DFT matrix as (real, imag) float32 planes."""
    if n <= 0 or n & (n - 1):
        raise ValueError(f"DFT matrix size must be a power of two, got {n}")
    return _dft_matrix_np(n, inverse)


@functools.lru_cache(maxsize=256)
def _twiddle_grid_np(
    n1: int, n2: int, inverse: bool
) -> tuple[np.ndarray, np.ndarray]:
    n = n1 * n2
    k1 = np.arange(n1, dtype=np.int64)[:, None]
    m2 = np.arange(n2, dtype=np.int64)[None, :]
    ang = (2.0 * np.pi / n) * ((k1 * m2) % n).astype(np.float64)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


def twiddle_grid(
    n1: int, n2: int, inverse: bool = False
) -> tuple[np.ndarray, np.ndarray]:
    """Four-step inter-factor twiddle T[k1, m2] = exp(∓2πi·k1·m2/(n1·n2))."""
    return _twiddle_grid_np(n1, n2, inverse)


def pass_twiddle(
    n_bins: int, n_phases: int, inverse: bool = False
) -> tuple[np.ndarray, np.ndarray]:
    """Inter-factor twiddle grid for one pass of the linearized program.

    ``T[k, p] = exp(∓2πi·k·p / (n_bins·n_phases))`` — multiplied into bin
    ``k`` of pencil ``p`` as the pass kernel's VMEM epilogue.  Host-cached
    once per (bins, phases) pair and served to the kernel chunk-by-chunk
    through a BlockSpec, so the table is built once and streamed at HBM
    bandwidth exactly once per pass (the paper's texture table, §2.3.1).
    Identical values to :func:`twiddle_grid` — the four-step in-VMEM grid and
    the program-level grid are the same object at different tiers.
    """
    return _twiddle_grid_np(n_bins, n_phases, inverse)


@functools.lru_cache(maxsize=512)
def stage_twiddle(l: int, inverse: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Stockham stage twiddle w[j] = exp(∓πi·j/l), j ∈ [0, l) — radix-2."""
    ang = (np.pi / l) * np.arange(l, dtype=np.float64)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


def mulfrac_pow2(k1, m2, n: int):
    """frac((k1·m2) / n) for power-of-two ``n`` without 64-bit integers.

    With x64 disabled (the default JAX config) ``jnp.int64`` iotas silently
    downcast to int32, so the obvious ``(k1·m2) % n`` overflows for
    ``n > 2³¹`` — exactly the huge-N regime on-device tables exist for.
    Instead split both operands into 16-bit halves: every partial product
    fits uint32 exactly, and because ``n`` is a power of two each partial's
    contribution to the fractional phase reduces independently —
    ``frac(p·2^s / n) = (p mod (n >> s)) / (n >> s)`` when ``n > 2^s`` and
    0 otherwise (``p·2^s`` is then a multiple of ``n``).  When ``n >> s``
    exceeds 2³² the mod is a no-op (``p < 2³²``) and is skipped, so the
    decomposition is exact for any ``n`` up to 2⁶².

    ``k1``/``m2``: non-negative integer arrays (values < 2³¹).  Returns a
    float32 array in [0, 4) — callers feed it to cos/sin where only the
    value mod 1 matters.
    """
    import jax.numpy as jnp

    if n <= 0 or n & (n - 1):
        raise ValueError(f"n must be a power of two, got {n}")
    k1 = k1.astype(jnp.uint32)
    m2 = m2.astype(jnp.uint32)
    a, b = k1 >> 16, k1 & 0xFFFF
    c, d = m2 >> 16, m2 & 0xFFFF

    def term(p, shift):
        if n <= (1 << shift):
            return jnp.float32(0.0)
        mod = n >> shift
        if mod < (1 << 32):
            p = p % jnp.uint32(mod)
        return p.astype(jnp.float32) * np.float32(1.0 / mod)

    # k1·m2 = ac·2³² + (ad + bc)·2¹⁶ + bd, each partial < 2³².
    return term(a * c, 32) + term(a * d, 16) + term(b * c, 16) + term(b * d, 0)


def traced_twiddle(
    n1: int,
    n2: int,
    inverse: bool = False,
    *,
    col_start=0,
    col_count: int | None = None,
):
    """On-device twiddle grid for sizes too large to embed as constants.

    Returns (real, imag) float32 planes ``T[k1, j] = exp(∓2πi·k1·m2/n)`` with
    ``n = n1·n2`` and ``m2 = col_start + j`` — the full (n1, n2) grid by
    default, or an (n1, col_count) column window (``col_start`` may be a
    traced scalar: the distributed driver passes ``axis_index·q`` so each
    device builds only its own slab).

    For ``n ≤ 2³¹`` the product ``k1·m2 < n`` fits int32 exactly; beyond
    that :func:`mulfrac_pow2` keeps the reduction int32-safe — the previous
    int64 iotas silently downcast to int32 under the default (x64-disabled)
    config and overflowed precisely in the huge-N regime.
    """
    import jax.numpy as jnp

    n = n1 * n2
    q = n2 if col_count is None else col_count
    k1 = jnp.arange(n1, dtype=jnp.int32)[:, None]
    m2 = (col_start + jnp.arange(q, dtype=jnp.int32))[None, :]
    if n < 2**31:
        # k1·m2 < n1·n2 = n < 2³¹ fits int32 (and n itself stays an int32
        # scalar — at exactly 2³¹ the % n operand would fail to parse).
        red = ((k1 * m2) % n).astype(jnp.float32)
        ang = np.float32(2.0 * np.pi / n) * red
    else:
        ang = np.float32(2.0 * np.pi) * mulfrac_pow2(k1, m2, n)
    sign = 1.0 if inverse else -1.0
    return jnp.cos(ang), sign * jnp.sin(ang)


def _chirp_angles(n: int) -> np.ndarray:
    """Chirp phase π·j²/n reduced exactly: j² mod 2n in int64 keeps the
    sin/cos argument < 2π so float64 → float32 rounding stays at the ulp
    level for any n the planner accepts (the j² ≈ 1e12 raw argument would
    lose the phase entirely)."""
    j = np.arange(n, dtype=np.int64)
    return (np.pi / n) * ((j * j) % (2 * n)).astype(np.float64)


@functools.lru_cache(maxsize=128)
def bluestein_chirp(n: int, inverse: bool = False):
    """Bluestein pre-multiply chirp A[j] = exp(∓iπ·j²/n), length n.

    The modulation that turns the DFT's jk cross term into a convolution:
    jk = (j² + k² − (k−j)²)/2, so X[k] = A[k]·Σ_j (x[j]A[j])·B[k−j] with
    B the conjugate chirp (:func:`bluestein_spectrum` carries B's padded
    circular spectrum).  Float32 (real, imag) planes, host-cached like
    every other LUT.
    """
    ang = _chirp_angles(n)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


@functools.lru_cache(maxsize=128)
def bluestein_postchirp(n: int, inverse: bool = False):
    """Bluestein post-multiply chirp — same phasor as the pre-chirp, with
    the 1/n inverse-DFT normalization folded in for ``inverse=True`` (the
    same fold-into-the-last-LUT convention the pow2 engines use)."""
    ang = _chirp_angles(n)
    sign = 1.0 if inverse else -1.0
    scale = (1.0 / n) if inverse else 1.0
    return (
        (scale * np.cos(ang)).astype(np.float32),
        (scale * sign * np.sin(ang)).astype(np.float32),
    )


@functools.lru_cache(maxsize=128)
def bluestein_spectrum(n: int, pad: int, inverse: bool = False):
    """Length-``pad`` circular spectrum B̂ of the Bluestein kernel chirp.

    b[m] = exp(±iπ·m²/n) wrapped circularly (b_circ[pad−m] = b[m] for
    1 ≤ m < n) so linear indices k−j ∈ (−n, n) all resolve; the spectrum
    is computed ONCE on the host in float64 (np.fft) and interned per
    (n, pad, direction) — the chirp analogue of the texture-cached twiddle
    tables.  Requires pad ≥ 2n−1 (the conv support) and pow2 pad.
    """
    if pad < 2 * n - 1:
        raise ValueError(f"bluestein pad {pad} < 2n-1 = {2 * n - 1}")
    if pad & (pad - 1):
        raise ValueError(f"bluestein pad must be a power of two, got {pad}")
    ang = _chirp_angles(n)
    sign = -1.0 if inverse else 1.0  # conjugate of the pre-chirp
    b = np.cos(ang) + 1j * sign * np.sin(ang)
    b_circ = np.zeros(pad, dtype=np.complex128)
    b_circ[:n] = b
    b_circ[pad - n + 1 :] = b[1:][::-1]
    spec = np.fft.fft(b_circ)
    return (
        spec.real.astype(np.float32),
        spec.imag.astype(np.float32),
    )


@functools.lru_cache(maxsize=128)
def rfft_recomb_twiddle(n: int, inverse: bool = False):
    """Recombination twiddles for real-FFT even/odd packing.

    For rfft of a length-``n`` real signal computed via a length-``n/2``
    complex FFT: X[k] = E[k] + e^{∓2πik/n}·O[k].  Returns the unit phasor
    e^{∓2πik/n} for k ∈ [0, n/2] as float32 planes (length n//2 + 1).
    """
    k = np.arange(n // 2 + 1, dtype=np.float64)
    ang = (2.0 * np.pi / n) * k
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )
