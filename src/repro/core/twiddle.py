"""Twiddle-factor / DFT-matrix factory — the paper's "texture memory" stage.

The paper precomputes sine/cosine tables once and serves them through the GPU
texture cache so butterfly kernels never recompute or re-fetch them from
global memory (§2.3.1).  The TPU analogue implemented here:

* tables are computed **once on the host** in float64 and cached per size
  (``functools.lru_cache`` over hashable plan keys);
* they enter kernels as **operands** whose BlockSpec maps every grid step to
  the same block, so XLA/Mosaic keeps them VMEM-resident across the whole
  batch grid — computed once, read at VMEM bandwidth, exactly the texture-LUT
  behaviour;
* for sizes too large to embed as constants, :func:`traced_twiddle` generates
  them with on-device iota arithmetic instead (still computed once per jit).

All tables are returned as split real/imag ``float32`` planes because Pallas
TPU kernels have no native complex dtype.
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "dft_matrix",
    "twiddle_grid",
    "pass_twiddle",
    "stage_twiddle",
    "traced_twiddle",
    "rfft_recomb_twiddle",
]


@functools.lru_cache(maxsize=256)
def _dft_matrix_np(n: int, inverse: bool) -> tuple[np.ndarray, np.ndarray]:
    """(n, n) DFT matrix W[j, k] = exp(∓2πi·j·k/n), float64 → float32 planes."""
    j = np.arange(n, dtype=np.float64)
    # Reduce j*k mod n in integer arithmetic first: keeps the argument of
    # sin/cos small so float64 → float32 rounding stays at the ulp level even
    # for n = 2**20 (j*k up to ~1e12 would lose precision otherwise).
    jk = np.outer(np.arange(n, dtype=np.int64), np.arange(n, dtype=np.int64)) % n
    ang = (2.0 * np.pi / n) * jk.astype(np.float64)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


def dft_matrix(n: int, inverse: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Cached (n, n) DFT matrix as (real, imag) float32 planes."""
    if n <= 0 or n & (n - 1):
        raise ValueError(f"DFT matrix size must be a power of two, got {n}")
    return _dft_matrix_np(n, inverse)


@functools.lru_cache(maxsize=256)
def _twiddle_grid_np(
    n1: int, n2: int, inverse: bool
) -> tuple[np.ndarray, np.ndarray]:
    n = n1 * n2
    k1 = np.arange(n1, dtype=np.int64)[:, None]
    m2 = np.arange(n2, dtype=np.int64)[None, :]
    ang = (2.0 * np.pi / n) * ((k1 * m2) % n).astype(np.float64)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


def twiddle_grid(
    n1: int, n2: int, inverse: bool = False
) -> tuple[np.ndarray, np.ndarray]:
    """Four-step inter-factor twiddle T[k1, m2] = exp(∓2πi·k1·m2/(n1·n2))."""
    return _twiddle_grid_np(n1, n2, inverse)


def pass_twiddle(
    n_bins: int, n_phases: int, inverse: bool = False
) -> tuple[np.ndarray, np.ndarray]:
    """Inter-factor twiddle grid for one pass of the linearized program.

    ``T[k, p] = exp(∓2πi·k·p / (n_bins·n_phases))`` — multiplied into bin
    ``k`` of pencil ``p`` as the pass kernel's VMEM epilogue.  Host-cached
    once per (bins, phases) pair and served to the kernel chunk-by-chunk
    through a BlockSpec, so the table is built once and streamed at HBM
    bandwidth exactly once per pass (the paper's texture table, §2.3.1).
    Identical values to :func:`twiddle_grid` — the four-step in-VMEM grid and
    the program-level grid are the same object at different tiers.
    """
    return _twiddle_grid_np(n_bins, n_phases, inverse)


@functools.lru_cache(maxsize=512)
def stage_twiddle(l: int, inverse: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Stockham stage twiddle w[j] = exp(∓πi·j/l), j ∈ [0, l) — radix-2."""
    ang = (np.pi / l) * np.arange(l, dtype=np.float64)
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )


def traced_twiddle(n1: int, n2: int, inverse: bool = False):
    """On-device twiddle grid for sizes too large to embed as constants.

    Uses broadcasted iota + mod-n reduction in int32 so the trig argument is
    exact; returns (real, imag) float32 planes of shape (n1, n2).
    """
    import jax.numpy as jnp

    n = n1 * n2
    k1 = jnp.arange(n1, dtype=jnp.int64 if n > 2**31 else jnp.int32)[:, None]
    m2 = jnp.arange(n2, dtype=k1.dtype)[None, :]
    red = ((k1 * m2) % n).astype(jnp.float32)
    ang = (2.0 * np.pi / n) * red
    sign = 1.0 if inverse else -1.0
    return jnp.cos(ang), sign * jnp.sin(ang)


@functools.lru_cache(maxsize=128)
def rfft_recomb_twiddle(n: int, inverse: bool = False):
    """Recombination twiddles for real-FFT even/odd packing.

    For rfft of a length-``n`` real signal computed via a length-``n/2``
    complex FFT: X[k] = E[k] + e^{∓2πik/n}·O[k].  Returns the unit phasor
    e^{∓2πik/n} for k ∈ [0, n/2] as float32 planes (length n//2 + 1).
    """
    k = np.arange(n // 2 + 1, dtype=np.float64)
    ang = (2.0 * np.pi / n) * k
    sign = 1.0 if inverse else -1.0
    return (
        np.cos(ang).astype(np.float32),
        (sign * np.sin(ang)).astype(np.float32),
    )
