"""Logical-axis → mesh-axis rules (t5x/MaxText style).

Model code names array axes logically ('batch', 'heads', 'ff', ...); this
module maps them to physical mesh axes given a :class:`ParallelConfig`.
Activations are annotated through :func:`ann` (a no-op outside a mesh
context, so the same model code runs on a single CPU device in tests).

Parallelism coverage:
  DP    batch        → ('pod', 'data')
  TP    heads/ff/vocab/experts → 'model'
  FSDP  embed (params' largest replicated axis) → 'data' when enabled
  EP    experts      → 'model'
  SP    kv_seq / long sequences → 'data' when sequence_parallel
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ParallelConfig

__all__ = ["rules_for", "spec_for", "ann", "mesh_context", "current_mesh"]

_state = threading.local()


def rules_for(par: ParallelConfig) -> dict[str, Optional[tuple]]:
    batch_axes = (
        (par.pod_axis, par.data_axis) if par.pod_axis else (par.data_axis,)
    )
    if par.decode_weight_stationary:
        # One-token decode with FSDP weights: replicate the (tiny) batch and
        # contract the data-sharded embed dim locally — small all-reduces
        # instead of per-layer full weight all-gathers.
        return {
            "batch": None,
            "seq": None,
            "kv_seq": (par.data_axis,) if par.sequence_parallel else None,
            "embed": batch_axes,
            "heads": (par.model_axis,),
            "kv_heads": (par.model_axis,),
            "head_dim": None,
            "ff": (par.model_axis,),
            "vocab": (par.model_axis,),
            "experts": (par.model_axis,),
            "expert_ff": None,
            "state": None,
            "conv": None,
            "filter": None,
            "frames": None,
        }
    rules: dict[str, Optional[tuple]] = {
        "batch": batch_axes,
        "seq": None,
        "kv_seq": (par.data_axis,) if par.sequence_parallel else None,
        # FSDP shards params over every data-parallel axis (pod included on
        # the multi-pod mesh); activations never see it ('batch' claims the
        # data axes first and duplicates are dropped).
        "embed": batch_axes if par.fsdp else None,
        "heads": (par.model_axis,),
        "kv_heads": (par.model_axis,),
        "head_dim": None,
        "ff": (par.model_axis,),
        "vocab": (par.model_axis,),
        "experts": (par.model_axis,),
        "expert_ff": None,
        "state": None,
        "conv": None,
        "filter": None,
        "frames": None,
    }
    return rules


def spec_for(axes: tuple, par: ParallelConfig) -> PartitionSpec:
    """PartitionSpec for a tuple of logical axis names (None = replicated)."""
    rules = rules_for(par)
    entries = []
    used: set[str] = set()
    for name in axes:
        if name is None:
            entries.append(None)
            continue
        phys = rules.get(name)
        if phys is None:
            entries.append(None)
            continue
        # A mesh axis may appear at most once in a spec.
        phys = tuple(p for p in phys if p not in used)
        if not phys:
            entries.append(None)
            continue
        used.update(phys)
        entries.append(phys if len(phys) > 1 else phys[0])
    return PartitionSpec(*entries)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, par: ParallelConfig):
    """Activate activation-annotation within a mesh for model code."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, par)
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[tuple]:
    return getattr(_state, "ctx", None)


def data_shard_count() -> int:
    """Number of data-parallel shards (pod·data) in the active mesh (1 if none)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return 1
    mesh, par = ctx
    n = mesh.shape[par.data_axis]
    if par.pod_axis:
        n *= mesh.shape[par.pod_axis]
    return int(n)


def ann(x, *axes):
    """Annotate an activation with logical axes (no-op without a mesh)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, par = ctx
    spec = spec_for(axes, par)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
