"""Parameter PartitionSpecs: logical axes → NamedSharding with divisibility
fallback (a mesh axis that does not divide a dim is dropped to replication —
e.g. kv_heads=8 on a model=16 axis)."""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ParallelConfig
from repro.sharding.logical import rules_for

__all__ = ["param_specs", "param_shardings", "batch_specs", "check_divisible"]


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, str):
        return mesh.shape[phys]
    return int(np.prod([mesh.shape[p] for p in phys]))


def _spec_entry(name, dim, mesh, rules, used):
    if name is None:
        return None
    phys = rules.get(name)
    if phys is None:
        return None
    phys = tuple(p for p in phys if p not in used)
    if not phys:
        return None
    # drop trailing axes until the product divides the dim
    while phys and dim % _axis_size(mesh, phys) != 0:
        phys = phys[:-1]
    if not phys:
        return None
    used.update(phys)
    return phys if len(phys) > 1 else phys[0]


def spec_for_shape(axes: tuple, shape: tuple, mesh: Mesh, par: ParallelConfig) -> PartitionSpec:
    rules = rules_for(par)
    used: set = set()
    entries = [
        _spec_entry(name, dim, mesh, rules, used)
        for name, dim in zip(axes, shape)
    ]
    return PartitionSpec(*entries)


def param_specs(axes_tree, shapes_tree, mesh: Mesh, par: ParallelConfig):
    """PartitionSpec tree for parameters (axes + value shapes in lockstep)."""

    def one(axes, val):
        return spec_for_shape(tuple(axes), tuple(val.shape), mesh, par)

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(axes_tree, shapes_tree, mesh: Mesh, par: ParallelConfig):
    specs = param_specs(axes_tree, shapes_tree, mesh, par)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def batch_specs(batch_tree, mesh: Mesh, par: ParallelConfig):
    """Shard every batch input over ('pod','data') on dim 0 when divisible."""
    rules = rules_for(par)
    batch_axes = rules["batch"]

    def one(x):
        if x.ndim == 0:
            return PartitionSpec()
        used: set = set()
        entry = _spec_entry("batch", x.shape[0], mesh, {"batch": batch_axes}, used)
        return PartitionSpec(entry, *([None] * (x.ndim - 1)))

    return jax.tree.map(one, batch_tree)


def check_divisible(shape, spec: PartitionSpec, mesh: Mesh) -> bool:
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            continue
        if dim % _axis_size(mesh, entry) != 0:
            return False
    return True
