"""Generate the EXPERIMENTS.md roofline / dry-run tables from artifacts."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

ORDER = [
    "gemma3-12b", "h2o-danube-1.8b", "yi-6b", "phi4-mini-3.8b", "arctic-480b",
    "deepseek-moe-16b", "musicgen-large", "xlstm-125m", "zamba2-2.7b",
    "qwen2-vl-72b", "fftbench",
]
SHAPE_ORDER = [
    "train_4k", "prefill_32k", "decode_32k", "long_500k",
    "table1_4096", "table1_16384", "table1_65536", "pod_1m", "pod_16m",
    "sar_4kx8k", "conv_512k",
]


def load(mesh: str):
    recs = []
    for f in glob.glob(os.path.join(ART, f"*__{mesh}.json")):
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"])))
    return recs


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def roofline_table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | C (ms) | M (ms) | X (ms) | bound | step LB (ms) | "
        "useful/HLO | mem GB | fits |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|---|",
    ]
    for r in load(mesh):
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAILED: {r.get('error','')[:40]} | | | | |")
            continue
        t = r["roofline"]
        uf = r.get("useful_flops_frac", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(t['compute_s'])} | "
            f"{fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s'])} | "
            f"{t['bound']} | {fmt_ms(t['step_lower_bound_s'])} | "
            f"{uf:.0%} | {r['per_chip']['peak_memory_bytes']/1e9:.1f} | "
            f"{'✓' if r['fits_hbm'] else '✗'} |"
        )
    return "\n".join(rows)


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | compile s | per-chip GFLOPs | per-chip GB moved | "
        "coll. GB | coll. ops | status |",
        "|---|---|---:|---:|---:|---:|---:|---|",
    ]
    for r in load(mesh):
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | | | | | | FAILED |")
            continue
        pc = r["per_chip"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} | "
            f"{pc['flops']/1e9:.1f} | {pc['hbm_bytes']/1e9:.2f} | "
            f"{pc['collective_bytes']/1e9:.3f} | {int(pc['collective_ops'])} | ok |"
        )
    return "\n".join(rows)


def summary(mesh: str) -> dict:
    recs = load(mesh)
    ok = [r for r in recs if r["status"] == "ok"]
    return {
        "cells": len(recs),
        "compiled": len(ok),
        "fits": sum(1 for r in ok if r["fits_hbm"]),
        "bounds": {
            b: sum(1 for r in ok if r["roofline"]["bound"] == b)
            for b in ("compute", "memory", "collective")
        },
    }


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(summary(mesh))
    print(roofline_table(mesh))
