"""Mini HLO cost analyzer — loop-aware FLOPs / bytes / collective bytes.

XLA's ``compiled.cost_analysis()`` counts every computation **once**, so a
``lax.scan`` over 8 layer-groups under-reports FLOPs by 8× (verified
empirically in this repo).  Since the dry-run leans on scan-over-layers to
keep compiles tractable, we parse the optimized HLO text ourselves and walk
the call graph, multiplying while-loop bodies by their
``known_trip_count`` backend config (XLA annotates every counted loop that
jax.lax.scan produces).

Costs per instruction:
  * ``dot``            → 2 · |result| · K   (K = product of lhs contracting
                          dims, looked up from the operand's defining type)
  * ``convolution``    → 2 · |result| · K_window · C_in (rare here)
  * elementwise arith  → |result| (1 flop/element; matmuls dominate)
  * bytes              → result + operand bytes of *top-level* instructions
                          (fusion internals are on-chip, not HBM traffic)
  * collectives        → result bytes of all-reduce / all-gather /
                          reduce-scatter / all-to-all / collective-permute
                          (‑start variants counted, ‑done skipped)

All numbers are per-device (the HLO is the SPMD-partitioned module).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128|s4|u4)\[([0-9,]*)\]"
)

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "select", "compare", "and", "or", "xor", "not", "clamp", "sign",
    "exponential-minus-one", "log-plus-one", "atan2", "cbrt", "erf",
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_ops: float = 0.0
    dot_flops: float = 0.0
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            flops=self.flops * k,
            bytes=self.bytes * k,
            collective_bytes=self.collective_bytes * k,
            collective_by_type={t: v * k for t, v in self.collective_by_type.items()},
            collective_ops=self.collective_ops * k,
            dot_flops=self.dot_flops * k,
            unknown_trip_loops=self.unknown_trip_loops,
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for t, v in other.collective_by_type.items():
            self.collective_by_type[t] = self.collective_by_type.get(t, 0.0) + v
        self.collective_ops += other.collective_ops
        self.dot_flops += other.dot_flops
        self.unknown_trip_loops += other.unknown_trip_loops


def _shape_info(type_str: str) -> Tuple[int, int, List[int]]:
    """(total_elems, total_bytes, dims-of-first-shape) for a type string."""
    total_e, total_b = 0, 0
    first_dims: Optional[List[int]] = None
    for m in _TYPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        dims = [int(d) for d in dims_s.split(",")] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = dims
    return total_e, total_b, first_dims or []


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str  # operands + attrs


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.instrs: List[_Instr] = []
        self.param_types: Dict[str, str] = {}


def _parse(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
    for line in text.splitlines():
        if line and not line.startswith(" ") and "{" in line and "->" in line:
            m = header_re.match(line)
            if m:
                current = _Computation(m.group(1))
                comps[m.group(1)] = current
                for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}\s/]+?))(?:,\s*%|$)", m.group(2)):
                    pass  # parameter names resolved via the parameter instrs
                continue
        if current is None or not line.startswith(" "):
            if line.startswith("}"):
                current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            current.instrs.append(
                _Instr(name=m.group(1), type_str=m.group(2), op=m.group(3), rest=m.group(4))
            )
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are before the closing paren of the op call
    depth = 1
    out = []
    cur = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    for m in re.finditer(r"%([\w.\-]+)", cur):
        out.append(m.group(1))
    return out


def _entry_name(text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    return m.group(1) if m else None


def _fusion_io_bytes(comp: "_Computation") -> float:
    """Effective HBM traffic (reads + writes) of one fusion execution.

    Two in-place patterns matter for scan bodies:
    * a parameter whose every use is a slicing op (dynamic-slice / slice /
      gather) streams only the sliced elements — the fused per-iteration
      parameter slice of scan-over-layers;
    * a root (or root-tuple element) that is a dynamic-update-slice writes
      only the update region, and the buffer parameter it updates is
      aliased in place (zero read) — the fused ys-accumulation of scans.
    """
    types = {i.name: i.type_str for i in comp.instrs}
    uses: Dict[str, List[Tuple[_Instr, int]]] = {}
    for ins in comp.instrs:
        for idx, on in enumerate(_operand_names(ins.rest)):
            uses.setdefault(on, []).append((ins, idx))
    root = comp.instrs[-1] if comp.instrs else None
    # names of root-level instructions (root itself, or tuple elements)
    root_set = set()
    if root is not None:
        root_set.add(root.name)
        if root.op == "tuple":
            root_set.update(_operand_names(root.rest))
    dus_roots = {
        i.name: i for i in comp.instrs
        if i.op == "dynamic-update-slice" and i.name in root_set
    }

    total = 0.0
    # ---- reads: parameters -------------------------------------------------
    for ins in comp.instrs:
        if ins.op != "parameter":
            continue
        _, full_bytes, _ = _shape_info(ins.type_str)
        users = uses.get(ins.name, [])
        if users and all(u.op in ("dynamic-slice", "slice", "gather") for u, _ in users):
            eff = sum(_shape_info(u.type_str)[1] for u, _ in users)
            total += min(eff, full_bytes)
        elif users and all(
            u.name in dus_roots and idx == 0 for u, idx in users
        ):
            pass  # in-place updated buffer: no read traffic
        else:
            total += full_bytes
    # ---- writes: root outputs ----------------------------------------------
    if root is not None:
        outs = _operand_names(root.rest) if root.op == "tuple" else [root.name]
        for oname in outs:
            if oname in dus_roots:
                dus = dus_roots[oname]
                ops_ = _operand_names(dus.rest)
                upd = 0.0
                if len(ops_) >= 2 and ops_[1] in types:
                    _, upd, _ = _shape_info(types[ops_[1]])
                total += upd
            elif oname in types:
                total += _shape_info(types[oname])[1]
        if root.op != "tuple" and root.name not in types:
            _, rb, _ = _shape_info(root.type_str)
            total += rb
    return total


def analyze(text: str) -> HloCost:
    comps = _parse(text)
    entry = _entry_name(text)
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(name: str, count_bytes: bool) -> HloCost:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        total = HloCost()
        types = {i.name: i.type_str for i in comp.instrs}
        for ins in comp.instrs:
            elems, nbytes, dims = _shape_info(ins.type_str)
            op = ins.op
            if op == "dot":
                k = 1
                lhs_ops = _operand_names(ins.rest)
                mdim = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
                if lhs_ops and mdim and lhs_ops[0] in types:
                    _, _, lhs_dims = _shape_info(types[lhs_ops[0]])
                    for di in mdim.group(1).split(","):
                        if di != "" and int(di) < len(lhs_dims):
                            k *= lhs_dims[int(di)]
                total.flops += 2.0 * elems * k
                total.dot_flops += 2.0 * elems * k
            elif op == "convolution":
                mdim = re.search(r"window=\{size=([0-9x]+)", ins.rest)
                k = 1
                if mdim:
                    for d in mdim.group(1).split("x"):
                        k *= int(d)
                total.flops += 2.0 * elems * k
            elif op in _ELEMWISE:
                total.flops += float(elems)
            elif any(op.startswith(c) for c in _COLLECTIVES):
                if op.endswith("-done"):
                    pass
                else:
                    base = next(c for c in _COLLECTIVES if op.startswith(c))
                    total.collective_bytes += nbytes
                    total.collective_by_type[base] = (
                        total.collective_by_type.get(base, 0.0) + nbytes
                    )
                    total.collective_ops += 1

            if count_bytes:
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the slice, not the full operand
                    total.bytes += 2.0 * nbytes
                elif op in ("dynamic-update-slice", "scatter"):
                    # traffic ≈ the update region (read + write), not the buffer
                    upd = 0
                    ops_ = _operand_names(ins.rest)
                    if len(ops_) >= 2 and ops_[1] in types:
                        _, upd, _ = _shape_info(types[ops_[1]])
                    total.bytes += 2.0 * upd
                elif op == "fusion":
                    mcalls = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                    sub = comps.get(mcalls.group(1)) if mcalls else None
                    if sub is not None:
                        total.bytes += _fusion_io_bytes(sub)
                    else:
                        total.bytes += 2.0 * nbytes
                elif op not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "while", "after-all", "custom-call",
                ):
                    opbytes = 0
                    for on in _operand_names(ins.rest):
                        if on in types:
                            _, ob, _ = _shape_info(types[on])
                            opbytes += ob
                    total.bytes += nbytes + opbytes

            # --- recurse into called computations --------------------------
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
                trips = int(mt.group(1)) if mt else 1
                sub = HloCost()
                if mb:
                    sub.add(comp_cost(mb.group(1), count_bytes))
                if mc:
                    sub.add(comp_cost(mc.group(1), count_bytes))
                scaled = sub.scaled(trips)
                if not mt:
                    scaled.unknown_trip_loops += 1
                total.add(scaled)
            elif op == "fusion":
                mcalls = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if mcalls:
                    # flops recurse; bytes don't (fusion internals are on-chip)
                    total.add(comp_cost(mcalls.group(1), False))
            elif op in ("call", "async-start"):
                mcalls = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.rest)
                if mcalls:
                    total.add(comp_cost(mcalls.group(1), count_bytes))
            elif op == "conditional":
                for mb in re.finditer(r"%([\w.\-]+)", ins.rest):
                    if mb.group(1) in comps and mb.group(1) != name:
                        total.add(comp_cost(mb.group(1), count_bytes))

        memo[key] = total
        return total

    if entry is None:
        return HloCost()
    return comp_cost(entry, True)
