"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds, TPU v5e constants:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``cost_analysis()`` is per-device after SPMD partitioning (verified
empirically), so the per-chip forms above equal the prompt's
``global / (chips × rate)`` forms.  collective_bytes is parsed from the
post-optimisation HLO: result-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op (all-reduce payload ==
result; all-gather wire traffic ≈ result·(D−1)/D ≤ result — we take the
conservative result size), times any enclosing while-loop trip count when
derivable (scan-over-layers bodies).
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Optional

__all__ = [
    "HW",
    "V5E",
    "A100",
    "COLLECTIVE_LAUNCH_S",
    "collective_bytes",
    "roofline_terms",
    "model_flops",
    "summarize_cell",
    "fft_pass_report",
    "fft2_fallback_report",
    "conv_report",
    "pencil_report",
    "prune_candidates",
    "gpu_program_report",
    "gpu_plan_report",
    "xla_gpu_fft_bytes",
    "bluestein_report",
]

#: Fixed per-collective launch/dispatch charge (seconds).  Wire bytes are
#: identical whether the split-complex pair rides one stacked all-to-all or
#: two, so without a launch term the model could never prefer packing; 10 µs
#: is the right order for a TPU ICI collective dispatch and is deliberately
#: hardware-vague — it separates "fewer collectives" from "same bytes", not
#: v5e from v5p.
COLLECTIVE_LAUNCH_S = 10e-6


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops_bf16: float
    peak_flops_f32: float
    hbm_bw: float
    link_bw: float
    hbm_bytes: float


V5E = HW(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=49.3e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16e9,
)

A100 = HW(
    name="gpu-a100",
    peak_flops_bf16=312e12,
    peak_flops_f32=19.5e12,
    hbm_bw=1.555e12,
    link_bw=600e9,
    hbm_bytes=40e9,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\w+\[[^\]]*\][^ ]*|\()[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_WHILE_TRIP_RE = re.compile(r"trip_count=\"?(\d+)\"?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective payload bytes by op type, from optimized HLO.

    Ops inside while-loop computations (scan-over-layers) are multiplied by
    the loop trip count when XLA recorded one (known_trip_count backend
    config); otherwise counted once (conservative lower bound, flagged).
    """
    # Map computation name → trip count for while bodies.
    trip_counts: dict[str, int] = {}
    for m in re.finditer(
        r"while\([^)]*\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+).*?(?:trip_count=\"?(\d+)\"?)?",
        hlo_text,
    ):
        body = m.group(2)
        tc = m.group(3)
        if tc:
            trip_counts[body] = int(tc)
    # Fallback: backend_config known_trip_count appears on the while line.
    for line in hlo_text.splitlines():
        if " while(" in line:
            bm = re.search(r"body=%?([\w.\-]+)", line)
            tm = re.search(r'known_trip_count[^0-9]*(\d+)', line)
            if bm and tm:
                trip_counts[bm.group(1)] = int(tm.group(1))

    by_type: dict[str, float] = {}
    count = 0
    unrolled_unknown = 0
    current_comp = None
    comp_re = re.compile(r"^(?:%?([\w.\-]+))\s*(?:\([^)]*\))?\s*->.*{\s*$")
    for line in hlo_text.splitlines():
        mhead = re.match(r"^%?([\w.\-]+)\s+\(.*\)\s+->", line.strip())
        if line and not line.startswith(" ") and "{" in line and ("->" in line or line.startswith("ENTRY")):
            mm = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            current_comp = mm.group(1) if mm else None
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(result_type)
        mult = trip_counts.get(current_comp, 1)
        if current_comp and current_comp not in trip_counts and ".body" in (current_comp or ""):
            unrolled_unknown += 1
        by_type[op] = by_type.get(op, 0.0) + nbytes * mult
        count += 1
    total = sum(by_type.values())
    return {
        "per_device_bytes": total,
        "by_type": by_type,
        "num_ops": count,
        "unknown_trip_loops": unrolled_unknown,
    }


def model_flops(n_params_active: int, tokens: int) -> float:
    """6·N·D — the useful-FLOPs yardstick (N = active params)."""
    return 6.0 * n_params_active * tokens


def fft_pass_report(
    n: int, batch: int = 1, hw: HW = V5E, n2: Optional[int] = None
) -> dict:
    """Modeled HBM traffic of an FFT's linearized pass program.

    One entry per pass (the plan's HBM round trips, literally), plus the
    total and its roofline memory term — so the paper's kernel-call count is
    not just asserted by tests but observable in every dry-run artifact and
    benchmark row.  With ``n2`` the report covers the joint multi-axis 2-D
    program of an ``(..., n2, n)`` image: each pass entry carries its
    transform ``axis`` and every pass is charged the whole image it streams.
    """
    from repro.core import plan as plan_lib  # local: analysis stays lazy

    plan = plan_lib.plan_fft2(n, n2) if n2 is not None else plan_lib.plan_fft(n)
    shape2d = (n2, n) if n2 is not None else None
    passes = []
    for i, p in enumerate(plan.passes):
        nbytes = plan_lib.pass_hbm_bytes(p, batch, plan_lib.pass_other(p, plan))
        pencils, stride, f = p.view_in if p.view_in else (1, 1, p.n)
        passes.append(
            {
                "pass": i,
                "kind": p.kind,
                "axis": p.axis,
                "n": p.n,
                "view": [pencils, stride, f],
                "twiddle": list(p.twiddle_after) if p.twiddle_after else None,
                "order": p.order,
                "hbm_bytes": nbytes,
            }
        )
    total = plan_lib.program_hbm_bytes(plan.passes, batch, shape2d)
    report = {
        "n": n,
        "batch": batch,
        "hbm_round_trips": plan.hbm_round_trips,
        "passes": passes,
        "modeled_hbm_bytes": total,
        "memory_s": total / hw.hbm_bw,
    }
    if n2 is not None:
        report["n2"] = n2
    return report


def bluestein_report(
    n: int, batch: int = 1, pad: Optional[int] = None, hw: HW = V5E
) -> dict:
    """Modeled cost of the Bluestein chirp-conv program for a non-pow2 ``n``
    against a *hypothetical* native mixed-radix transform of the same length.

    The chirp-conv route runs two transforms of the pow2 pad length
    ``M = bluestein_pad(n)`` (the B̂ spectrum is interned at plan time, so
    only the forward pad-FFT and pad-IFFT cost runtime arithmetic) plus the
    O(n + M) chirp multiplies.  Against a native 5·n·log₂n yardstick that is
    a ~2·(M/n)·(log M / log n) arithmetic overhead — the classic "up to 3×
    pad, ~6× flops" Bluestein tax, reported here per size so the choice is
    observable in every dry-run artifact rather than folklore.
    """
    from repro.core import limits, plan as plan_lib  # local: analysis stays lazy

    if n > 1 and not (n & (n - 1)):
        raise ValueError(
            f"n={n} is a power of two — it runs the native schedules; the "
            f"Bluestein report covers the non-pow2 route"
        )
    m_pad = limits.bluestein_pad(n) if pad is None else pad
    prog = plan_lib.compile_bluestein(n, pad)
    passes = []
    total = 0
    for i, p in enumerate(prog):
        nbytes = plan_lib.pass_hbm_bytes(p, batch)
        passes.append(
            {
                "pass": i,
                "kind": p.kind,
                "stage": p.stage,
                "n": p.n,
                "hbm_bytes": nbytes,
            }
        )
        total += nbytes
    f32 = 4
    log2 = math.log2
    flops = batch * (2 * 5.0 * m_pad * log2(m_pad) + 8.0 * (2 * n + m_pad))
    mixed_flops = batch * 5.0 * n * max(log2(n), 1.0)
    mixed_bytes = 2 * batch * n * 2 * f32  # one signal round trip
    return {
        "n": n,
        "pad": m_pad,
        "batch": batch,
        "pad_ratio": m_pad / n,
        "hbm_round_trips": len(prog),
        "passes": passes,
        "modeled_hbm_bytes": total,
        "memory_s": total / hw.hbm_bw,
        "modeled_flops": flops,
        "mixed_radix_flops": mixed_flops,
        "mixed_radix_hbm_bytes": mixed_bytes,
        "flops_overhead": flops / mixed_flops,
        "hbm_overhead": total / mixed_bytes,
    }


def _gpu_fallback_round_trips(p) -> int:
    """Global-memory round trips of one *unclaimed* pass traced through the
    XLA fallback: the transform itself plus every transpose the fallback
    materializes (the fused kernels' whole advantage is not paying these)."""
    if p.kind == "reorder":
        return 1
    pencils, stride, f = p.view_in if p.view_in else (1, 1, p.n)
    if pencils == 1:
        return 1
    if stride == 1:
        # Natural-order row fallback materializes its output transpose.
        return 2 if p.view_out != p.view_in else 1
    # Strided-column fallback: swapaxes in + transform + swapaxes out.
    return 3


def gpu_program_report(
    passes,
    claims,
    *,
    batch: int = 1,
    batch_tiles: Optional[dict] = None,
    shape2d: Optional[tuple] = None,
    device_kind: Optional[str] = None,
    hw: HW = A100,
) -> dict:
    """The paper's metric for a pass program on CUDA-class hardware:
    per-pass **shared-memory bytes** (the per-block working set staged in
    the SM's fast tier) and **global-memory round trips** (claimed leaves
    touch the signal once; unclaimed passes pay the XLA fallback's
    materialized transposes on top).

    ``claims`` is the backend's per-leaf predicate
    (:func:`repro.kernels.fft_gpu.gpu_claims` for the ``pallas_gpu``
    backend); ``batch_tiles`` maps leaf length → batch tile (a plan's
    negotiated tiles), defaulting to the shared-memory-budget pick.
    """
    from repro.core import limits, plan as plan_lib  # local: analysis stays lazy

    budget = limits.memory_budget(device_kind)
    rows = []
    trips = 0
    global_total = 0
    smem_max = 0
    for i, p in enumerate(passes):
        claimed = bool(claims(p))
        other = 1
        if shape2d is not None:
            n2, n = shape2d
            other = n if p.axis == -2 else n2
        gbytes = plan_lib.pass_hbm_bytes(p, batch, other)
        if claimed:
            tile = (batch_tiles or {}).get(p.n) or plan_lib.pick_batch_tile_gpu(
                p, budget
            )
            smem = plan_lib.gpu_smem_bytes(p, tile)
            t = 1
        else:
            tile, smem = None, 0  # XLA manages its own staging
            t = _gpu_fallback_round_trips(p)
            gbytes += (t - 1) * 2 * batch * other * p.n * 2 * 4  # transposes
        rows.append(
            {
                "pass": i,
                "kind": p.kind,
                "axis": p.axis,
                "n": p.n,
                "claimed": claimed,
                "backend": "pallas_gpu" if claimed else "xla",
                "batch_tile": tile,
                "smem_bytes": smem,
                "global_bytes": gbytes,
                "global_round_trips": t,
            }
        )
        trips += t
        global_total += gbytes
        smem_max = max(smem_max, smem)
    return {
        "batch": batch,
        "smem_budget": budget,
        "passes": rows,
        "claims": tuple(r["backend"] for r in rows),
        "global_round_trips": trips,
        "smem_bytes_max": smem_max,
        "modeled_global_bytes": global_total,
        "memory_s": global_total / hw.hbm_bw,
    }


def gpu_plan_report(
    planned,
    batch: int = 1,
    *,
    device_kind: Optional[str] = None,
    hw: HW = A100,
) -> dict:
    """:func:`gpu_program_report` for a :class:`~repro.core.fft.PlannedFFT`
    handle — pulls the pass program, the backend's claim surface and the
    negotiated batch tiles off the plan (this is what ``describe()``/dryrun
    surface for GPU plans)."""
    claims = planned.backend.claims
    if claims is None:
        from repro.kernels import fft_gpu  # lazy: kernel layer

        claims = fft_gpu.gpu_claims
    spec = planned.spec
    shape2d = (spec.n2, spec.n) if spec.n2 is not None else None
    return gpu_program_report(
        planned.passes,
        claims,
        batch=batch,
        batch_tiles=dict(planned.batch_tiles),
        shape2d=shape2d,
        device_kind=device_kind,
        hw=hw,
    )


def xla_gpu_fft_bytes(n: int, batch: int = 1) -> int:
    """Modeled global-memory traffic of the plain-XLA four-step path on GPU
    — the crossover comparison point for the backend tuner.

    Per four-step level XLA materializes what the fused kernel keeps on-chip:
    two GEMM round trips, a twiddle cmul round trip and an output transpose
    — against the fused leaf's single round trip.  Direct-regime sizes are
    one GEMM either way (the crossover only opens past ``DIRECT_MAX``).
    """
    from repro.core import plan as plan_lib  # local: analysis stays lazy

    f32 = 4
    sig = batch * n * 2 * f32
    fft_plan = plan_lib.plan_fft(n)
    total = 0
    for p in fft_plan.passes:
        luts = (
            p.n * p.n * 2 * f32
            if p.kind == "direct"
            else (p.n1 * p.n1 + p.n2 * p.n2 + p.n1 * p.n2) * 2 * f32
        )
        if p.kind == "direct":
            total += 2 * sig + luts
        else:
            total += 4 * 2 * sig + luts  # 2 GEMMs + cmul + transpose, r/w each
        if p.twiddle_after is not None:
            total += 2 * sig  # materialized inter-factor cmul
    return total


def prune_candidates(candidates: list, tol: float = 0.2, vmem_budget: Optional[int] = None) -> list:
    """Roofline pruning of a tuning space — the model half of the autotuner.

    ``candidates``: ordered ``(config, modeled_hbm_bytes, vmem_bytes)``
    triples, the fixed heuristic FIRST.  Keeps candidates whose working set
    fits the VMEM budget and whose modeled HBM traffic is within ``tol`` of
    the feasible minimum — the only ones a measurement pass could ever
    crown — returned sorted by modeled bytes (stable, so the heuristic
    wins modeled ties; where the model is strictly cheaper, the modeled
    pick deviates from the heuristic by design).
    """
    from repro.core.limits import VMEM_BUDGET  # local: analysis stays lazy

    budget = VMEM_BUDGET if vmem_budget is None else vmem_budget
    feasible = [c for c in candidates if c[2] <= budget]
    if not feasible:
        feasible = candidates  # degenerate: nothing fits, measure anyway
    floor = min(c[1] for c in feasible)
    kept = [c for c in feasible if c[1] <= floor * (1.0 + tol)]
    return sorted(kept, key=lambda c: c[1])


def fft2_fallback_report(n: int, n2: int, batch: int = 1, hw: HW = V5E) -> dict:
    """Joint strip-mined 2-D program vs the per-axis composition it replaced.

    For ``n2 > FUSED_MAX`` images the pre-tuner code composed a row plan
    with an ``axis=-2`` column plan; a multi-pass column plan executes
    through a transpose sandwich — two extra whole-image HBM round trips
    the joint program's width-broadcast strided passes do not pay.  Both
    schedules' modeled bytes, so the acceptance criterion (joint strictly
    below fallback) is observable, not just asserted.
    """
    from repro.core import plan as plan_lib  # local: analysis stays lazy

    f32 = 4
    joint_plan = plan_lib.plan_fft2(n, n2)
    joint = plan_lib.program_hbm_bytes(joint_plan.passes, batch, (n2, n))
    row = plan_lib.program_hbm_bytes(plan_lib.plan_fft(n).passes, batch * n2)
    col_passes = plan_lib.plan_fft(n2).passes
    col = plan_lib.program_hbm_bytes(col_passes, batch * n)
    img = batch * n2 * n * 2 * f32  # split-complex image
    transposes = 2 * 2 * img if len(col_passes) > 1 else 0  # swapaxes sandwich
    fallback = row + col + transposes
    return {
        "n": n,
        "n2": n2,
        "batch": batch,
        "joint_hbm_bytes": joint,
        "joint_passes": len(joint_plan.passes),
        "fallback_hbm_bytes": fallback,
        "fallback_transpose_bytes": transposes,
        "bytes_ratio": fallback / joint if joint else float("inf"),
        "joint_memory_s": joint / hw.hbm_bw,
        "fallback_memory_s": fallback / hw.hbm_bw,
    }


def _rfft_conv_bytes(n: int, batch: int, plan_lib) -> int:
    """Modeled HBM traffic of one rfft → ⊙H → irfft pair at length ``n``.

    The packed complex programs (length n/2) at signal batch, the filter's
    forward transform once, the Hermitian recombination epilogues (read m /
    write m+1 planes per direction) and the spectrum multiply (two reads,
    one write).  Split-complex float32 — the same conventions as
    :func:`~repro.core.plan.pass_hbm_bytes`.
    """
    f32 = 4
    m = n // 2
    prog = plan_lib.plan_fft(max(m, 1)).passes
    sig_fwd = plan_lib.program_hbm_bytes(prog, batch)
    sig_inv = plan_lib.program_hbm_bytes(prog, batch)
    filt_fwd = plan_lib.program_hbm_bytes(prog, 1)
    # Recombination read+write per transform: 2·batch signal passes + the
    # filter's one; spectrum multiply reads batch X planes + the broadcast
    # H once and writes batch Y planes.
    recomb = (2 * batch + 1) * (2 * m + 1) * 2 * f32
    cmul_b = (2 * batch + 1) * (m + 1) * 2 * f32
    return sig_fwd + sig_inv + filt_fwd + recomb + cmul_b


def conv_report(L: int, Lh: int, batch: int = 1, hw: HW = V5E, block=None) -> dict:
    """One-shot vs overlap-save modeled HBM traffic for an FFT convolution.

    The one-shot path pads to ``next_pow2(L + Lh - 1)`` — beyond the fused
    regime that is a split-regime pass program per transform.  Overlap-save
    frames the signal into ``num_blocks`` blocks of ``block`` samples
    (fused regime by construction) and batches them through one plan pair;
    its extra costs — the framing gather, the tail scatter, and the
    ``block/(block - Lh + 1)`` redundancy factor — are charged explicitly,
    so the report shows where the crossover actually is rather than
    asserting it.
    """
    from repro.core import overlap as ov  # local: analysis stays lazy
    from repro.core import plan as plan_lib
    from repro.core.conv import next_pow2

    f32 = 4
    n_one = next_pow2(L + Lh - 1)
    one_bytes = _rfft_conv_bytes(n_one, batch, plan_lib)
    one = {
        "n": n_one,
        "hbm_round_trips": 2 * plan_lib.plan_fft(n_one // 2).hbm_round_trips,
        "hbm_bytes": one_bytes,
        "memory_s": one_bytes / hw.hbm_bw,
    }

    B = ov.pick_block(Lh, block)
    step = B - (Lh - 1)
    nb = -(-L // step)
    os_bytes = _rfft_conv_bytes(B, batch * nb, plan_lib)
    # Framing gather (read L, write nb·B) + tail scatter (read nb·step,
    # write L), real float32.
    os_bytes += batch * (L + nb * B + nb * step + L) * f32
    osd = {
        "block": B,
        "num_blocks": nb,
        "valid_per_block": step,
        "max_plan_n": B,
        "hbm_bytes": os_bytes,
        "memory_s": os_bytes / hw.hbm_bw,
    }
    return {
        "L": L,
        "Lh": Lh,
        "batch": batch,
        "one_shot": one,
        "overlap_save": osd,
        "bytes_ratio": one_bytes / os_bytes if os_bytes else float("inf"),
    }


def pencil_report(
    n: int,
    d: int,
    batch: int = 1,
    *,
    n1: Optional[int] = None,
    n2: Optional[int] = None,
    pack: bool = True,
    chunks: int = 1,
    natural_order: bool = True,
    hw: HW = V5E,
) -> dict:
    """Modeled cost decomposition of the distributed pencil FFT.

    The paper's argument one level up: across a pod the slow tier is the
    interconnect, and the pencil schedule's cost is its all-to-all
    transposes against the local column/row FFT passes.  This report
    charges both sides explicitly so the distributed tuner
    (:meth:`repro.core.tuning.TuningSpace.for_pencil`) can trade them:

    * per-step **comm bytes**: every transpose moves the device's whole
      slab, ``(d-1)/d`` of it over the wire;
    * **local HBM bytes**: the n1 column program (at batch·q pencils), the
      twiddle multiply (slab read+write + the per-device table), the n2 row
      program (at batch·p pencils), and the natural-order reorder;
    * a fixed :data:`COLLECTIVE_LAUNCH_S` per collective *call* — what
      packing the split-complex pair into one stacked all-to-all halves,
      and what strip-mining into ``chunks`` pieces pays more of;
    * the pipelined middle: with ``chunks=K`` the two inner transposes
      overlap the column FFT + twiddle chunk-by-chunk, so the modeled
      middle is ``cc + fc + (K-1)·max(cc, fc)`` (per-chunk comm ``cc``,
      per-chunk compute ``fc``) instead of their sum.

    ``modeled_s`` is the config's total; ``serial_s`` is the unpacked
    ``K=1`` baseline of the same factorization, so ``overlap_win`` is
    directly the speedup the tuner is claiming.
    """
    from repro.core import plan as plan_lib  # local: analysis stays lazy

    if n1 is None or n2 is None:
        from repro.core import distributed as dist  # lazy: avoids cycle

        n1, n2 = dist.pencil_factors(n, d)
    if n1 * n2 != n:
        raise ValueError(f"pencil factors {n1}x{n2} != n={n}")
    p, q = n1 // max(d, 1), n2 // max(d, 1)
    f32, planes = 4, 2
    slab = batch * (n // max(d, 1))  # elements per plane per device
    slab_bytes = slab * planes * f32
    wire_step = slab_bytes * (d - 1) / max(d, 1)  # one transpose, per device
    a2a_steps = (3 if natural_order else 2) if d > 1 else 0
    K = max(1, chunks) if (pack and d > 1) else 1
    # Collective call count: the two inner transposes are K calls each, the
    # natural-order reorder is always one packed call; unpacked pays two
    # calls (xr, xi) per step, serially.
    if d <= 1:
        a2a_calls = 0
    elif pack:
        a2a_calls = 2 * K + (1 if natural_order else 0)
    else:
        a2a_calls = 2 * a2a_steps

    fft1_bytes = plan_lib.program_hbm_bytes(
        plan_lib.plan_fft(n1).passes, batch * q
    )
    fft2_bytes = plan_lib.program_hbm_bytes(
        plan_lib.plan_fft(n2).passes, batch * p
    )
    twiddle_bytes = 2 * slab_bytes + n1 * q * planes * f32  # slab r/w + table
    reorder_bytes = 2 * slab_bytes if (natural_order and d > 1) else 0
    local_bytes = fft1_bytes + twiddle_bytes + fft2_bytes + reorder_bytes

    t_step = wire_step / hw.link_bw
    t_mid_compute = (fft1_bytes + twiddle_bytes) / hw.hbm_bw
    if d > 1:
        cc, fc = 2 * t_step / K, t_mid_compute / K
        t_middle = cc + fc + (K - 1) * max(cc, fc)
    else:
        t_middle = t_mid_compute
    t_tail = fft2_bytes / hw.hbm_bw + reorder_bytes / hw.hbm_bw
    if natural_order and d > 1:
        t_tail += t_step
    modeled = t_middle + t_tail + a2a_calls * COLLECTIVE_LAUNCH_S
    serial = (
        a2a_steps * t_step
        + local_bytes / hw.hbm_bw
        + (2 * a2a_steps) * COLLECTIVE_LAUNCH_S
    )
    return {
        "n": n,
        "d": d,
        "batch": batch,
        "n1": n1,
        "n2": n2,
        "pack": pack,
        "chunks": K,
        "natural_order": natural_order,
        "a2a_steps": a2a_steps,
        "a2a_calls": a2a_calls,
        "comm_bytes_per_step": wire_step,
        "comm_bytes_total": wire_step * a2a_steps,
        "fft1_bytes": fft1_bytes,
        "fft2_bytes": fft2_bytes,
        "twiddle_bytes": twiddle_bytes,
        "local_hbm_bytes": local_bytes,
        "comm_s": a2a_steps * t_step,
        "memory_s": local_bytes / hw.hbm_bw,
        "modeled_s": modeled,
        "serial_s": serial,
        "overlap_win": serial / modeled if modeled else float("inf"),
    }


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_bytes_per_chip: float,
    hw: HW = V5E,
    dtype: str = "bf16",
) -> dict:
    peak = hw.peak_flops_bf16 if dtype == "bf16" else hw.peak_flops_f32
    t_c = flops_per_chip / peak
    t_m = bytes_per_chip / hw.hbm_bw
    t_x = coll_bytes_per_chip / hw.link_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "bound": dom[0],
        "step_lower_bound_s": dom[1],
        # fraction of roofline the *dominant* resource achieves if the other
        # two overlap perfectly; the perf loop drives the dominant term down.
        "balance": {
            "compute": t_c / dom[1] if dom[1] else 0.0,
            "memory": t_m / dom[1] if dom[1] else 0.0,
            "collective": t_x / dom[1] if dom[1] else 0.0,
        },
    }


def summarize_cell(record: dict, hw: HW = V5E) -> str:
    r = record
    t = r["roofline"]
    return (
        f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:9s} "
        f"C={t['compute_s']*1e3:9.2f}ms M={t['memory_s']*1e3:9.2f}ms "
        f"X={t['collective_s']*1e3:9.2f}ms bound={t['bound']:10s} "
        f"useful={r.get('useful_flops_frac', 0):5.1%}"
    )
