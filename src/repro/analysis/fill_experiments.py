"""Fill EXPERIMENTS.md placeholders from dry-run artifacts."""

import os
import re

from repro.analysis.report import dryrun_table, roofline_table, summary

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
PATH = os.path.join(ROOT, "EXPERIMENTS.md")


def main():
    with open(PATH) as f:
        text = f.read()
    s16 = summary("16x16")
    s512 = summary("2x16x16")
    summ = (
        f"* single pod (16×16, 256 chips): **{s16['compiled']}/{s16['cells']} cells "
        f"compile**, {s16['fits']}/{s16['compiled']} fit 16 GB HBM; bounds: "
        f"{s16['bounds']['memory']} memory / {s16['bounds']['collective']} "
        f"collective / {s16['bounds']['compute']} compute.\n"
        f"* multi-pod (2×16×16, 512 chips): **{s512['compiled']}/{s512['cells']} "
        f"cells compile** (the pod axis shards), {s512['fits']}/{s512['compiled']} "
        f"fit 16 GB HBM."
    )
    repl = {
        "<!-- DRYRUN_SUMMARY -->": summ,
        "<!-- DRYRUN_TABLE_16x16 -->": dryrun_table("16x16"),
        "<!-- DRYRUN_TABLE_2x16x16 -->": dryrun_table("2x16x16"),
        "<!-- ROOFLINE_16x16 -->": roofline_table("16x16"),
        "<!-- ROOFLINE_2x16x16 -->": roofline_table("2x16x16"),
    }
    for k, v in repl.items():
        text = text.replace(k, v)
    with open(PATH, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md filled:", s16, s512)


if __name__ == "__main__":
    main()
