"""Checkpoint manager: atomic, async, keep-N, elastic restore.

Fault-tolerance contract (the multi-pod story):

* **Atomicity** — state is written to ``step_XXXXXXXX.tmp`` and renamed;
  a crash mid-save can never corrupt the latest checkpoint.
* **Async** — ``save(..., blocking=False)`` hands the (host-local) arrays
  to a writer thread so the step loop is not blocked on I/O.
* **Keep-N** — old checkpoints are garbage-collected.
* **Elastic restore** — arrays are stored *unsharded* together with the
  parameter tree structure and the data-iterator state; ``restore`` then
  re-shards onto whatever mesh the restarted job has (different pod count /
  chip count), which is what lets a 512-chip job resume on 256 chips.
* **Auto-resume** — ``latest_step`` finds the newest complete checkpoint.

Storage is a directory of ``.npz`` files (flattened pytree leaves) plus a
JSON manifest; on a real cluster this would be a distributed FS or object
store — the protocol (tmp+rename, manifest-last) is the same.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[dict] = None, *, blocking: bool = True):
        """Snapshot ``state`` (pytree) + ``extra`` (JSON-able) at ``step``."""
        # Materialise on host *now* so the trainer can mutate its state.
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(x) for x in leaves]
        payload = (step, host_leaves, treedef, extra or {})
        if blocking:
            self._write(payload)
        else:
            self._ensure_worker()
            self._q.put(payload)

    def wait(self):
        """Block until all async saves are durable."""
        if self._worker is not None:
            self._q.join()
        if self._error:
            raise self._error

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            payload = self._q.get()
            try:
                self._write(payload)
            except BaseException as e:  # surfaced on wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, payload):
        step, host_leaves, treedef, extra = payload
        name = f"step_{step:08d}"
        # unique tmp dir: concurrent saves of the same step must not race
        tmp = os.path.join(
            self.dir, f"{name}.tmp{os.getpid()}_{threading.get_ident()}"
        )
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(
            os.path.join(tmp, "arrays.npz"),
            **{f"leaf_{i}": a for i, a in enumerate(host_leaves)},
        )
        manifest = {
            "step": step,
            "num_leaves": len(host_leaves),
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *, shardings: Any = None):
        """Restore the pytree saved at ``step``.

        ``like`` supplies the tree structure (and dtypes).  ``shardings``
        (optional pytree of NamedSharding, same structure) re-shards each
        leaf onto the *current* mesh — the elastic-restart path: the stored
        arrays are topology-free, so any mesh works.
        """
        name = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(name, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(name, "arrays.npz"))
        leaves, treedef = jax.tree.flatten(like)
        assert manifest["num_leaves"] == len(leaves), (
            f"checkpoint has {manifest['num_leaves']} leaves, "
            f"model expects {len(leaves)} — architecture mismatch"
        )
        restored = []
        shard_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
        )
        for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"leaf_{i}"]
            ref_dtype = getattr(ref, "dtype", None)
            if ref_dtype is not None:
                arr = arr.astype(ref_dtype)
            if shd is not None:
                restored.append(jax.device_put(arr, shd))
            else:
                restored.append(jax.numpy.asarray(arr))
        return treedef.unflatten(restored), manifest["extra"]
