"""Unified decoder LM covering all assigned architectures.

`init` / `forward` / `loss_fn` / `prefill` / `decode_step` over a single
parameter tree: embed → stack (pattern-driven blocks) → final norm → head.

Modality frontends are stubs per the assignment: ``audio`` replaces token
embedding with precomputed frame embeddings (B, S, D); ``vision`` scatters
precomputed patch embeddings over the first ``frontend_len`` positions and
feeds M-RoPE (B, 3, S) position ids.  Loss is chunked over the sequence so
(B, S, vocab) logits are never materialised (vocab up to 262k).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import stack as stack_lib
from repro.models.layers import embedding as emb_lib
from repro.models.layers.norms import rms_norm, rms_norm_init
from repro.sharding.logical import ann
from repro.utils.params import unzip

__all__ = [
    "init",
    "init_unzipped",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "cache_init",
]


def _cdtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    pd = _pdtype(cfg)
    params = {
        "embed": emb_lib.embed_init(ks[0], cfg, pd),
        "stack": stack_lib.stack_init(ks[1], cfg, pd),
        "final_norm": rms_norm_init(cfg.d_model),
        "head": emb_lib.head_init(ks[2], cfg, pd),
    }
    return params


def init_unzipped(key, cfg):
    """(values, logical_axes) — what the training/launch code consumes."""
    return unzip(init(key, cfg))


def _embed_inputs(params, batch, cfg):
    cd = _cdtype(cfg)
    if cfg.frontend == "audio":
        x = batch["frame_embeds"].astype(cd)
    else:
        x = emb_lib.embed_apply(params["embed"], batch["tokens"], cfg, cd)
        if cfg.frontend == "vision" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(cd)
            x = jax.lax.dynamic_update_slice_in_dim(x, ve, 0, axis=1)
    return x


def _positions(batch, cfg):
    if "positions" in batch:
        return batch["positions"]
    tokens = batch.get("tokens", batch.get("frame_embeds"))
    b, s = tokens.shape[0], tokens.shape[1]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))


def forward(params, batch, cfg):
    """Full-sequence forward → (hidden (B,S,D), aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    positions = _positions(batch, cfg)
    mrope = batch.get("mrope_positions")
    x, _, aux = stack_lib.stack_forward(
        params["stack"],
        x,
        cfg=cfg,
        positions=positions,
        mrope_positions=mrope,
        return_cache=False,
    )
    x = rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    return x, aux


def logits_fn(params, batch, cfg):
    """(B, S, vocab) logits — small-model / test path only."""
    x, aux = forward(params, batch, cfg)
    return emb_lib.head_apply(params["head"], params["embed"], x, cfg), aux


def _chunk_ce(params, hidden, targets, mask, cfg):
    """Chunked cross-entropy: scan over sequence chunks.

    hidden: (B,S,D); targets/mask: (B,S).  Returns (sum_nll, sum_z2, count).
    """
    b, s, d = hidden.shape
    c = min(cfg.loss_chunk, s)
    nchunk = s // c
    rem = s - nchunk * c

    def one(hs, ts, ms):
        logits = emb_lib.head_apply(params["head"], params["embed"], hs, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)  # (B,C)
        tgt = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * ms
        z2 = jnp.square(lse) * ms
        return nll.sum(), z2.sum(), ms.sum()

    if nchunk > 0:
        hs = jnp.moveaxis(hidden[:, : nchunk * c].reshape(b, nchunk, c, d), 1, 0)
        ts = jnp.moveaxis(targets[:, : nchunk * c].reshape(b, nchunk, c), 1, 0)
        ms = jnp.moveaxis(mask[:, : nchunk * c].reshape(b, nchunk, c), 1, 0)

        def body(carry, xs):
            nll, z2, cnt = one(*xs)
            return (carry[0] + nll, carry[1] + z2, carry[2] + cnt), None

        (nll, z2, cnt), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hs, ts, ms)
        )
    else:
        nll = z2 = cnt = jnp.zeros(())
    if rem:
        n2, zz2, c2 = one(hidden[:, -rem:], targets[:, -rem:], mask[:, -rem:])
        nll, z2, cnt = nll + n2, z2 + zz2, cnt + c2
    return nll, z2, cnt


def loss_fn(params, batch, cfg, train_cfg=None):
    """Scalar LM loss + metrics.  batch needs 'targets' (B,S) int32.

    'loss_mask' optional (B,S) float/bool; z-loss and MoE aux included.
    """
    hidden, aux = forward(params, batch, cfg)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    nll, z2, cnt = _chunk_ce(params, hidden, targets, mask, cfg)
    cnt = jnp.maximum(cnt, 1.0)
    ce = nll / cnt
    z_coef = getattr(train_cfg, "z_loss", 1e-4) if train_cfg else 1e-4
    loss = ce + z_coef * (z2 / cnt) + aux
    metrics = {"loss": loss, "ce": ce, "aux": aux, "tokens": cnt}
    return loss, metrics


def cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return stack_lib.stack_cache_init(cfg, batch, max_len, dtype)


def prepare_decode_caches(caches, cfg, prefill_len: int, max_len: int):
    """Convert prefill caches (natural order, length S) into decode layout.

    Global-attention layers: pad the KV axis out to ``max_len`` slots.
    Sliding-window layers: re-scatter the last ``window`` positions into the
    ring-buffer slot order (slot = pos % window) used by ``attn_decode``.
    Recurrent caches (SSM/xLSTM/spectral ring AND stream) pass through
    unchanged — the spectral stream cache is already in decode layout when
    ``spectral_forward(return_cache=True)`` builds it.  jit-safe, so the
    serving engine runs it inside its compiled prefill phase.
    """
    from repro.models.layers.attention import KVCache

    pattern = cfg.pattern()
    unit = stack_lib.find_unit(pattern)

    from repro.models.layers.attention import _quant_tok

    def convert(kind, cache):
        if not isinstance(cache, KVCache):
            return cache
        window = cfg.sliding_window if kind == "attn_local" else None
        k, v = cache.k, cache.v  # (R, B, S, KV, hd)
        s = k.shape[2]
        if window:
            keep = min(window, s)
            pos = jnp.arange(s - keep, s)
            slots = pos % window
            kw = jnp.zeros(k.shape[:2] + (window,) + k.shape[3:], k.dtype)
            vw = jnp.zeros_like(kw)
            kw = kw.at[:, :, slots].set(k[:, :, s - keep :])
            vw = vw.at[:, :, slots].set(v[:, :, s - keep :])
            k, v = kw, vw
        else:
            pad = max_len - s
            if pad > 0:
                padw = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
                k = jnp.pad(k, padw)
                v = jnp.pad(v, padw)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quant_tok(k)
            vq, vs = _quant_tok(v)
            return KVCache(k=kq, v=vq, k_scale=ks, v_scale=vs)
        return KVCache(k=k, v=v)

    return [convert(kind, c) for kind, c in zip(unit, caches)]


def prefill(params, batch, cfg):
    """Forward that also returns decode caches and last-position logits."""
    x = _embed_inputs(params, batch, cfg)
    positions = _positions(batch, cfg)
    mrope = batch.get("mrope_positions")
    x, caches, _ = stack_lib.stack_forward(
        params["stack"],
        x,
        cfg=cfg,
        positions=positions,
        mrope_positions=mrope,
        return_cache=True,
    )
    x = rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    last = x[:, -1:, :]
    logits = emb_lib.head_apply(params["head"], params["embed"], last, cfg)
    return logits[:, 0], caches


def decode_step(params, tokens, caches, t, cfg, *, embeds=None, mrope_positions=None):
    """One decode step.  tokens: (B,) int32 (or embeds (B,1,D) for audio).

    t: int32 — the position being *written* (0-based), a scalar for a
    single shared timeline or a (B,) vector of per-slot positions (the
    serving engine's continuous-batching state, where each slot keeps its
    own length).  Returns (logits (B, vocab), new_caches).
    """
    cd = _cdtype(cfg)
    if cfg.frontend == "audio" and embeds is not None:
        x = embeds.astype(cd)
    else:
        x = emb_lib.embed_apply(params["embed"], tokens[:, None], cfg, cd)
    x, caches = stack_lib.stack_decode(
        params["stack"], x, caches, t, cfg=cfg, mrope_positions=mrope_positions
    )
    x = rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = emb_lib.head_apply(params["head"], params["embed"], x, cfg)
    return logits[:, 0], caches
