"""Layer stack: scan-over-repeating-units with shared-block support.

The per-layer pattern (cfg.pattern()) is factored into its smallest
repeating *unit* (e.g. gemma3: 5×attn_local + 1×attn; zamba2: 6×mamba2 +
1×shared_attn).  Parameters for each position in the unit are stacked over
repeats (``vmap`` at init) and the forward is a single ``lax.scan`` over
repeats — keeping HLO size O(unit) instead of O(layers), which matters for
48–80-layer dry-run compiles.  ``shared_attn`` positions use one unstacked
parameter set closed over by the scan body (zamba2's weight sharing).

Caches ride through the scan as stacked xs/ys (leading dim = repeats).
MoE aux losses accumulate in the carry.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.utils.params import Param, map_params

__all__ = ["find_unit", "stack_init", "stack_forward", "stack_decode", "stack_cache_init"]


def find_unit(pattern: tuple) -> tuple:
    n = len(pattern)
    for u in range(1, n + 1):
        if n % u == 0 and tuple(pattern[:u]) * (n // u) == tuple(pattern):
            return tuple(pattern[:u])
    return tuple(pattern)


def _repeats(cfg) -> int:
    pattern = cfg.pattern()
    return len(pattern) // len(find_unit(pattern))


def stack_init(key, cfg, dtype) -> dict:
    pattern = cfg.pattern()
    unit = find_unit(pattern)
    reps = len(pattern) // len(unit)
    out = {"unit": {}}
    keys = jax.random.split(key, len(unit) + 1)
    for i, kind in enumerate(unit):
        if kind == "shared_attn":
            if "shared" not in out:
                out["shared"] = blocks.block_init(keys[-1], kind, cfg, dtype)
            out["unit"][f"b{i}"] = {}
            continue
        rep_keys = jax.random.split(keys[i], reps)
        stacked = jax.vmap(
            lambda k, kind=kind: blocks.block_init(k, kind, cfg, dtype)
        )(rep_keys)
        # vmap stacked the values; record the new leading 'layers' axis.
        out["unit"][f"b{i}"] = map_params(
            lambda p: Param(p.value, ("layers",) + p.axes), stacked
        )
    return out


def _split_unit(params, unit, r: Optional[int] = None):
    """Per-repeat slice (r=None keeps the stacked leading dim)."""
    res = []
    for i, kind in enumerate(unit):
        p = params["unit"][f"b{i}"]
        if kind == "shared_attn":
            res.append(params["shared"])
        elif r is not None:
            res.append(jax.tree.map(lambda x: x[r], p))
        else:
            res.append(p)
    return res


def stack_forward(
    params,
    x,
    *,
    cfg,
    positions,
    mrope_positions=None,
    return_cache: bool = False,
):
    """x: (B,S,D) → (x, caches (stacked per repeat) | None, aux)."""
    pattern = cfg.pattern()
    unit = find_unit(pattern)
    reps = len(pattern) // len(unit)
    shared = params.get("shared")

    def unit_body(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for i, kind in enumerate(unit):
            p = shared if kind == "shared_attn" else unit_params[f"b{i}"]
            x, cache, a = blocks.block_forward(
                p,
                x,
                kind=kind,
                cfg=cfg,
                positions=positions,
                mrope_positions=mrope_positions,
                return_cache=return_cache,
            )
            caches.append(cache)
            aux = aux + a
        return x, caches, aux

    if cfg.remat:
        unit_body = jax.checkpoint(unit_body)

    if not cfg.scan_layers or reps == 1:
        aux_total = jnp.zeros((), jnp.float32)
        all_caches = []
        for r in range(reps):
            up = {
                f"b{i}": (
                    {} if unit[i] == "shared_attn"
                    else jax.tree.map(lambda v: v[r], params["unit"][f"b{i}"])
                )
                for i in range(len(unit))
            }
            x, caches, aux = unit_body(x, up)
            all_caches.append(caches)
            aux_total = aux_total + aux
        caches_out = None
        if return_cache:
            caches_out = jax.tree.map(lambda *xs: jnp.stack(xs), *all_caches)
        return x, caches_out, aux_total

    scanned = {
        f"b{i}": params["unit"][f"b{i}"]
        for i in range(len(unit))
        if unit[i] != "shared_attn"
    }

    def scan_body(carry, unit_params_r):
        x, aux = carry
        up = dict(unit_params_r)
        for i, kind in enumerate(unit):
            if kind == "shared_attn":
                up[f"b{i}"] = {}
        x, caches, a = unit_body(x, up)
        caches = [c for c in caches] if return_cache else None
        return (x, aux + a), caches

    (x, aux), caches = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), scanned
    )
    return x, (caches if return_cache else None), aux


def stack_cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked caches: one entry per unit position, leading dim = repeats."""
    pattern = cfg.pattern()
    unit = find_unit(pattern)
    reps = len(pattern) // len(unit)
    caches = []
    for kind in unit:
        one = blocks.block_cache_init(kind, cfg, batch, max_len, dtype)
        caches.append(jax.tree.map(lambda x: jnp.stack([x] * reps), one))
    return caches


def stack_decode(params, x, caches, t, *, cfg, mrope_positions=None):
    """One decode step through the whole stack.  caches: stacked list.

    The caches ride in the scan *carry*, updated per repeat with a
    dynamic-update-slice at the loop index: XLA aliases while-loop carries
    in place, so the (potentially tens-of-GB) cache is held **once**.
    Passing caches as xs/ys instead double-buffers them (measured +1× the
    full KV cache of temp on the 32k decode cells).
    """
    pattern = cfg.pattern()
    unit = find_unit(pattern)
    reps = len(pattern) // len(unit)
    shared = params.get("shared")

    scanned_params = {
        f"b{i}": params["unit"][f"b{i}"]
        for i in range(len(unit))
        if unit[i] != "shared_attn"
    }

    def apply_unit(x, unit_params_r, caches_r):
        new_caches = []
        for i, kind in enumerate(unit):
            p = shared if kind == "shared_attn" else unit_params_r[f"b{i}"]
            x, c = blocks.block_decode(
                p,
                x,
                caches_r[i],
                t,
                kind=kind,
                cfg=cfg,
                mrope_positions=mrope_positions,
            )
            new_caches.append(c)
        return x, new_caches

    if not cfg.scan_layers or reps == 1:
        new_caches = []
        for r in range(reps):
            up = {
                k: jax.tree.map(lambda v: v[r], v_)
                for k, v_ in scanned_params.items()
            }
            cr = jax.tree.map(lambda v: v[r], caches)
            x, nc = apply_unit(x, up, cr)
            new_caches.append(nc)
        caches_out = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        return x, caches_out

    if cfg.decode_cache_mode == "ys":
        # xs/ys form: double-buffers the cache but never reshards it inside
        # the loop — wins when kv_heads don't divide the model axis (§Perf).
        def ys_body(x, xs):
            unit_params_r, caches_r = xs
            x, nc = apply_unit(x, unit_params_r, caches_r)
            nc = jax.tree.map(lambda buf, c: c.astype(buf.dtype), caches_r, nc)
            return x, nc

        x, new_caches = jax.lax.scan(ys_body, x, (scanned_params, caches))
        return x, new_caches

    def scan_body(carry, unit_params_r):
        x, caches, r = carry
        caches_r = jax.tree.map(
            lambda v: jax.lax.dynamic_index_in_dim(v, r, 0, keepdims=False),
            caches,
        )
        x, new_r = apply_unit(x, unit_params_r, caches_r)
        caches = jax.tree.map(
            lambda buf, nc: jax.lax.dynamic_update_index_in_dim(
                buf, nc.astype(buf.dtype), r, 0
            ),
            caches,
            new_r,
        )
        return (x, caches, r + 1), None

    (x, caches, _), _ = jax.lax.scan(
        scan_body, (x, caches, jnp.asarray(0, jnp.int32)), scanned_params
    )
    return x, caches
