"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, strictly recurrent).

mLSTM (Beck et al. 2024): per head, matrix state C ∈ ℝ^{P×P} and normaliser
n ∈ ℝ^P with exponentially-gated updates

    m_t = max(lf_t + m_{t-1}, li_t)                       (stabiliser)
    C_t = e^{lf_t + m_{t-1} - m_t} C_{t-1} + e^{li_t - m_t} k_t v_tᵀ
    n_t = e^{lf_t + m_{t-1} - m_t} n_{t-1} + e^{li_t - m_t} k_t
    y_t = C_tᵀ q_t / max(|n_tᵀ q_t|, e^{-m_t})

The stabiliser recurrence is an associative (max-plus) scan, so the whole
layer parallelises: m is computed with ``lax.associative_scan``, after which
the gated recurrence is a standard chunked gated-linear-attention (same
machinery as the SSD block).  Decode carries (C, n, m) explicitly.

sLSTM keeps per-unit scalar state with recurrent (hidden→gate) weights —
inherently sequential, implemented as a ``lax.scan`` over time (the
assignment's xlstm-125m is small enough that this is fine; decode is O(1)).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import rms_norm, rms_norm_init
from repro.sharding.logical import ann
from repro.utils.params import Param, normal, ones, zeros

__all__ = [
    "mlstm_init",
    "mlstm_forward",
    "mlstm_decode",
    "init_mlstm_cache",
    "slstm_init",
    "slstm_forward",
    "slstm_decode",
    "init_slstm_cache",
    "MLSTMCache",
    "SLSTMCache",
]


class MLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, P, P)
    n: jax.Array  # (B, H, P)
    m: jax.Array  # (B, H)


class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, D)
    n: jax.Array  # (B, D)
    h: jax.Array  # (B, D)
    m: jax.Array  # (B, D)


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def _mlstm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.resolved_ssm_heads
    return d_inner, h, d_inner // h


def mlstm_init(key, cfg, dtype) -> dict:
    D = cfg.d_model
    d_inner, h, p = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": normal(ks[0], (D, 2 * d_inner), ("embed", "ff"), dtype=dtype),
        "w_qkv": normal(ks[1], (d_inner, 3 * d_inner), ("ff", "ff"), scale=d_inner**-0.5, dtype=dtype),
        "w_if": normal(ks[2], (d_inner, 2 * h), ("ff", "heads"), scale=0.02, dtype=jnp.float32),
        "b_if": Param(
            jnp.concatenate([jnp.zeros((h,)), jnp.linspace(3.0, 6.0, h)]).astype(
                jnp.float32
            ),
            ("heads",),
        ),
        "norm": rms_norm_init(d_inner, jnp.float32),
        "w_down": normal(ks[3], (d_inner, D), ("ff", "embed"), scale=d_inner**-0.5, dtype=dtype),
    }


def _mlstm_gates(params, u, h):
    """u: (B,S,d_inner) → log input/forget gates (B,S,H) float32."""
    gf = jnp.einsum("bse,eh->bsh", u.astype(jnp.float32), params["w_if"]) + params["b_if"]
    li = gf[..., :h]  # log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(gf[..., h:])  # log forget gate
    return li, lf


def _stab_scan(li, lf, m0):
    """m_t = max(lf_t + m_{t-1}, li_t) via associative max-plus scan.

    li/lf: (B,S,H); m0: (B,H).  The recurrence is affine in the tropical
    semiring: composing (a, b)∘(a', b') = (a+a', max(b+a', b')) gives
    cumulative (A_t, B_t) with m_t = max(m_0 + A_t, B_t).
    """

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax + ay, jnp.maximum(bx + ay, by)

    a_cum, b_cum = jax.lax.associative_scan(combine, (lf, li), axis=1)
    return jnp.maximum(m0[:, None, :] + a_cum, b_cum)


def mlstm_forward(params, x, *, cfg, return_cache: bool = False):
    bsz, s, _ = x.shape
    d_inner, h, p = _mlstm_dims(cfg)
    cd = x.dtype
    up = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(cd))
    u, z = up[..., :d_inner], up[..., d_inner:]
    qkv = jnp.einsum("bse,ef->bsf", u, params["w_qkv"].astype(cd))
    q = qkv[..., :d_inner].reshape(bsz, s, h, p)
    k = qkv[..., d_inner : 2 * d_inner].reshape(bsz, s, h, p) * (p**-0.5)
    v = qkv[..., 2 * d_inner :].reshape(bsz, s, h, p)
    li, lf = _mlstm_gates(params, u, h)

    # m0 = 0 (not -inf): C/n start at zero so any finite stabiliser seed
    # is valid, and a -1e30 sentinel would absorb the small decay terms
    # in the float32 cumsum telescoping inside the chunked GLA.
    m0 = jnp.zeros((bsz, h), jnp.float32)
    m = _stab_scan(li, lf, m0)  # (B,S,H)
    m_prev = jnp.concatenate([m0[:, None, :], m[:, :-1, :]], axis=1)
    ldecay = lf + m_prev - m  # log of stabilised forget factor
    lin = li - m  # log of stabilised input factor

    y, (c_f, n_f) = _gla_chunked(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        ldecay,
        jnp.exp(lin),
        cfg.chunk_size,
    )
    # normaliser denominator: max(|n_tᵀ q_t|, e^{-m_t})
    denom = jnp.maximum(jnp.abs(y["nq"]), jnp.exp(-m))  # (B,S,H)
    out = y["cv"] / denom[..., None]  # (B,S,H,P)
    out = out.reshape(bsz, s, d_inner).astype(cd)
    out = rms_norm(params["norm"], out, eps=cfg.norm_eps) * jax.nn.silu(z)
    res = jnp.einsum("bse,ed->bsd", out, params["w_down"].astype(cd))
    res = ann(res, "batch", "seq", "embed")
    if return_cache:
        m_last = m[:, -1, :]
        return res, MLSTMCache(c=c_f, n=n_f, m=m_last)
    return res


def _gla_chunked(q, k, v, ldecay, b_in, chunk):
    """Chunked gated linear attention with normaliser.

    q/k/v: (B,S,H,P); ldecay/b_in: (B,S,H) (log decay, input scale).
    Returns dict with 'cv' = Σ decayed k vᵀ read by q, 'nq' = normaliser
    read, and the final (C, n) state.
    """
    bsz, s, h, p = q.shape
    qq = min(chunk, s)
    nc = s // qq
    assert nc * qq == s

    def chunked(t):
        return jnp.moveaxis(t.reshape(bsz, nc, qq, *t.shape[2:]), 1, 0)

    q_c, k_c, v_c = chunked(q), chunked(k), chunked(v)
    ld_c, b_c = chunked(ldecay), chunked(b_in)
    causal = jnp.tril(jnp.ones((qq, qq), bool))

    c0 = jnp.zeros((bsz, h, p, p), jnp.float32)
    n0 = jnp.zeros((bsz, h, p), jnp.float32)

    @jax.checkpoint  # recompute the (B,Q,Q,H) gate tensors in backward
    def body(carry, inp):
        c_prev, n_prev = carry
        qc, kc, vc, ld, bc = inp
        cum = jnp.cumsum(ld, axis=1)  # (B,Q,H)
        tot = cum[:, -1, :]
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        m = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0) * bc[:, None, :, :]
        scores = jnp.einsum("bqhp,bkhp->bqkh", qc, kc) * m
        cv_intra = jnp.einsum("bqkh,bkhp->bqhp", scores, vc)
        # normaliser intra: Σ_s M[t,s]·(q_t·k_s) — the scores row-summed.
        nq_intra = scores.sum(axis=2)  # (B,Q,H)
        w_q = jnp.exp(cum)
        cv_inter = jnp.einsum("bqhp,bhpo,bqh->bqho", qc, c_prev, w_q)
        nq_inter = jnp.einsum("bqhp,bhp,bqh->bqh", qc, n_prev, w_q)
        w_s = jnp.exp(tot[:, None, :] - cum) * bc  # (B,Q,H)
        c_new = c_prev * jnp.exp(tot)[:, :, None, None] + jnp.einsum(
            "bqh,bqhp,bqho->bhpo", w_s, kc, vc
        )
        n_new = n_prev * jnp.exp(tot)[:, :, None] + jnp.einsum(
            "bqh,bqhp->bhp", w_s, kc
        )
        return (c_new, n_new), (cv_intra + cv_inter, nq_intra + nq_inter)

    (c_f, n_f), (cv, nq) = jax.lax.scan(
        body, (c0, n0), (q_c, k_c, v_c, ld_c, b_c)
    )
    cv = jnp.moveaxis(cv, 0, 1).reshape(bsz, s, h, p)
    nq = jnp.moveaxis(nq, 0, 1).reshape(bsz, s, h)
    return {"cv": cv, "nq": nq}, (c_f, n_f)


def init_mlstm_cache(cfg, batch, dtype=jnp.float32) -> MLSTMCache:
    d_inner, h, p = _mlstm_dims(cfg)
    return MLSTMCache(
        c=jnp.zeros((batch, h, p, p), jnp.float32),
        n=jnp.zeros((batch, h, p), jnp.float32),
        m=jnp.zeros((batch, h), jnp.float32),
    )


def mlstm_decode(params, x, cache: MLSTMCache, *, cfg) -> Tuple[jax.Array, MLSTMCache]:
    bsz = x.shape[0]
    d_inner, h, p = _mlstm_dims(cfg)
    cd = x.dtype
    up = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(cd))
    u, z = up[..., :d_inner], up[..., d_inner:]
    qkv = jnp.einsum("bse,ef->bsf", u, params["w_qkv"].astype(cd))
    q = qkv[..., :d_inner].reshape(bsz, h, p).astype(jnp.float32)
    k = (qkv[..., d_inner : 2 * d_inner].reshape(bsz, h, p) * (p**-0.5)).astype(jnp.float32)
    v = qkv[..., 2 * d_inner :].reshape(bsz, h, p).astype(jnp.float32)
    li, lf = _mlstm_gates(params, u, h)
    li, lf = li[:, 0], lf[:, 0]  # (B,H)
    m_new = jnp.maximum(lf + cache.m, li)
    fdec = jnp.exp(lf + cache.m - m_new)
    iin = jnp.exp(li - m_new)
    c = cache.c * fdec[..., None, None] + iin[..., None, None] * jnp.einsum(
        "bhp,bho->bhpo", k, v
    )
    n = cache.n * fdec[..., None] + iin[..., None] * k
    cv = jnp.einsum("bhp,bhpo->bho", q, c)
    nq = jnp.einsum("bhp,bhp->bh", q, n)
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_new))
    out = (cv / denom[..., None]).reshape(bsz, 1, d_inner).astype(cd)
    out = rms_norm(params["norm"], out, eps=cfg.norm_eps) * jax.nn.silu(z)
    res = jnp.einsum("bse,ed->bsd", out, params["w_down"].astype(cd))
    return res, MLSTMCache(c=c, n=n, m=m_new)


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, cfg, dtype) -> dict:
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    # 4 gates (i, f, z, o), input and recurrent weights.
    return {
        "w_x": normal(ks[0], (D, 4 * D), ("embed", "ff"), dtype=dtype),
        "w_h": normal(ks[1], (D, 4 * D), ("embed", "ff"), scale=D**-0.5, dtype=dtype),
        "bias": Param(
            jnp.concatenate(
                [jnp.zeros((D,)), jnp.full((D,), 4.0), jnp.zeros((2 * D,))]
            ).astype(jnp.float32),
            ("ff",),
        ),
        "norm": rms_norm_init(D, jnp.float32),
        "w_out": normal(ks[2], (D, D), ("embed", "embed"), scale=D**-0.5, dtype=dtype),
    }


def _slstm_cell(params, xt, carry, cfg):
    """One step.  xt: (B, 4D) pre-projected input contribution."""
    c, n, hid, m = carry
    d = c.shape[-1]
    g = xt + jnp.einsum("bd,de->be", hid, params["w_h"].astype(jnp.float32)) + params["bias"]
    li = g[..., :d]  # log-space input gate
    lf = jax.nn.log_sigmoid(g[..., d : 2 * d])
    zt = jnp.tanh(g[..., 2 * d : 3 * d])
    ot = jax.nn.sigmoid(g[..., 3 * d :])
    m_new = jnp.maximum(lf + m, li)
    fdec = jnp.exp(lf + m - m_new)
    iin = jnp.exp(li - m_new)
    c_new = fdec * c + iin * zt
    n_new = fdec * n + iin
    h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(params, x, *, cfg, return_cache: bool = False):
    bsz, s, d = x.shape
    cd = x.dtype
    xg = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_x"].astype(jnp.float32))
    carry0 = (
        jnp.zeros((bsz, d), jnp.float32),
        jnp.zeros((bsz, d), jnp.float32),
        jnp.zeros((bsz, d), jnp.float32),
        jnp.full((bsz, d), -1e30, jnp.float32),
    )

    def body(carry, xt):
        new = _slstm_cell(params, xt, carry, cfg)
        return new, new[2]

    carry_f, hs = jax.lax.scan(body, carry0, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(cd)  # (B,S,D)
    h = rms_norm(params["norm"], h, eps=cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h, params["w_out"].astype(cd))
    out = ann(out, "batch", "seq", "embed")
    if return_cache:
        return out, SLSTMCache(*carry_f)
    return out


def init_slstm_cache(cfg, batch, dtype=jnp.float32) -> SLSTMCache:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)  # distinct buffers (donation)
    return SLSTMCache(c=z(), n=z(), h=z(), m=jnp.full((batch, d), -1e30, jnp.float32))


def slstm_decode(params, x, cache: SLSTMCache, *, cfg) -> Tuple[jax.Array, SLSTMCache]:
    cd = x.dtype
    xg = jnp.einsum("bd,de->be", x[:, 0].astype(jnp.float32), params["w_x"].astype(jnp.float32))
    new = _slstm_cell(params, xg, tuple(cache), cfg)
    h = rms_norm(params["norm"], new[2][:, None, :].astype(cd), eps=cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h, params["w_out"].astype(cd))
    return out, SLSTMCache(*new)
