"""Spectral mixer — the paper's FFT as an LM layer (Hyena-style long conv).

Token mixing by causal convolution with a learned per-channel global filter,
computed as rfft → pointwise → irfft through :mod:`repro.core` — i.e. every
transform uses the paper's memory-optimized plan (fused Pallas kernels on
TPU, four-step XLA elsewhere).  A multiplicative gate keeps it competitive
as a drop-in replacement for attention in the ablation configs.

Decode has two exactly-equivalent state layouts (``cfg.spectral_decode_mode``):

* ``"stream"`` (default) — the serving path.  The cache carries the
  overlap-save tail (:class:`repro.core.overlap.StreamingConv`'s state) plus
  a chunk accumulator and a precomputed *lookahead*: the history-only half
  of the next ``C`` outputs, refreshed once per ``C`` tokens by ONE cached
  block-plan conv (:func:`repro.core.overlap.stream_lookahead`).  Per token
  the layer only adds the direct head — taps ``j ≤ phase`` against the
  accumulating chunk, an O(C·D) dot — so FFT cost is amortized to
  ``O(block·log block / C)`` per token and every transform stays on the
  plan prefill already cached.
* ``"ring"`` — a ring buffer of the last ``Lf`` inputs and the O(Lf·D)
  direct dot per token; the exactness oracle the stream path is tested
  against.

Prefill routes through :func:`repro.core.conv.fft_conv`, which auto-routes
to overlap-save (``fft_conv_os``) whenever the one-shot padded length would
leave the fused regime — long prompts never plan past ``FUSED_MAX``.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import overlap as ov_lib
from repro.core.conv import fft_conv
from repro.core.limits import next_pow2
from repro.sharding.logical import ann
from repro.utils.params import Param, normal

__all__ = [
    "spectral_init",
    "spectral_forward",
    "spectral_decode",
    "spectral_stream_decode",
    "spectral_stream_rephase",
    "init_spectral_cache",
    "init_spectral_stream_cache",
    "stream_grain",
    "stream_plan_info",
    "SpectralCache",
    "SpectralStreamCache",
]


class SpectralCache(NamedTuple):
    buf: jax.Array  # (B, Lf, D) ring buffer of recent inputs
    t: jax.Array    # scalar step counter (for ring indexing)


class SpectralStreamCache(NamedTuple):
    """StreamingConv-carried decode state (amortized FFT serving path).

    The decode window boundary ``B0`` is the stream position where the
    current lookahead was computed; ``phase`` counts decode *steps* since
    (global across batch slots, so batched decode flushes in lockstep under
    one jitted scan — per-slot timelines live in the attention caches).

    hist:   (B, D, Lf−1+C) float32 — the last ``Lf−1+C`` mixer inputs
            before ``B0``.  Only the trailing ``Lf−1`` (the overlap-save
            tail) feed flushes; the extra leading ``C`` slots carry enough
            history that a freshly-prefilled request can be re-phased into
            a running batch at ANY global phase
            (:func:`spectral_stream_rephase`).
    chunk:  (B, D, C) float32 — inputs accumulated since ``B0``
            (slots ``[0, phase)`` live, the rest zero).
    future: (B, D, C) float32 — history-only contribution to outputs
            ``B0 … B0+C−1`` (filter taps ``j > i`` for entry ``i``),
            computed once per window by one cached block-plan conv.
    phase:  () int32 in ``[0, C)`` — next chunk slot to fill.
    """

    hist: jax.Array
    chunk: jax.Array
    future: jax.Array
    phase: jax.Array


def stream_grain(cfg) -> Tuple[int, int]:
    """(chunk C, flush block) for the streaming decode state.

    ``C`` balances the per-token direct head (O(C·D)) against the amortized
    flush (O(block·log block·D / C) per token): ``max(8, next_pow2(Lf)/4)``
    keeps both well under the ring path's O(Lf·D) for Lf ≥ 64 and is
    overridable via ``cfg.spectral_decode_chunk``.  The block is the
    smallest power of two covering one flush input (tail + chunk =
    ``Lf−1+C`` samples), so every flush is a SINGLE frame through one
    cached rfft/irfft plan pair.
    """
    lf = cfg.spectral_filter_len
    c = cfg.spectral_decode_chunk or max(8, next_pow2(lf) // 4)
    block = next_pow2(max(lf - 1 + c, 2))
    return c, block


def spectral_init(key, cfg, dtype) -> dict:
    D, Lf = cfg.d_model, cfg.spectral_filter_len
    ks = jax.random.split(key, 4)
    # Smooth decaying filter init: h[d, j] ~ N(0, 1/Lf) · exp(-j/τ_d).
    j = np.arange(Lf, dtype=np.float32)
    tau = np.logspace(1.0, np.log10(Lf), D, dtype=np.float32)
    envelope = np.exp(-j[None, :] / tau[:, None])  # (D, Lf)
    base = jax.random.normal(ks[0], (D, Lf), jnp.float32) * (Lf**-0.5)
    return {
        "filt": Param((base * envelope).astype(jnp.float32), ("embed", "filter")),
        "w_gate": normal(ks[1], (D, D), ("embed", "ff"), dtype=dtype),
        "w_in": normal(ks[2], (D, D), ("embed", "ff"), dtype=dtype),
        "w_out": normal(ks[3], (D, D), ("ff", "embed"), dtype=dtype),
    }


def _stream_state_from_u(u32: jax.Array, filt: jax.Array, cfg) -> SpectralStreamCache:
    """Build the streaming decode state after a prefill of ``u32`` (B,S,D)
    float32 mixer inputs: window boundary at position S, empty chunk, and
    the lookahead for the next C outputs through the cached block plan."""
    b, s, d = u32.shape
    lf = cfg.spectral_filter_len
    c, block = stream_grain(cfg)
    cap = lf - 1 + c
    uT = jnp.moveaxis(u32, 1, 2)  # (B, D, S)
    pos = np.arange(s - cap, s)   # static: prompt shorter than cap → zeros
    hist = uT[..., np.clip(pos, 0, s - 1)] * (pos >= 0)
    Hr, Hi = ov_lib.filter_spectrum(filt, block)
    future = ov_lib.stream_lookahead(hist[..., c:], Hr, Hi, window=c, block=block)
    return SpectralStreamCache(
        hist=hist,
        chunk=jnp.zeros((b, d, c), jnp.float32),
        future=future,
        phase=jnp.asarray(0, jnp.int32),
    )


def spectral_forward(params, x, *, cfg, return_cache: bool = False):
    """x: (B, S, D) → (B, S, D) via gated FFT long convolution."""
    b, s, d = x.shape
    cd = x.dtype
    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cd))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(cd)))
    # axis-aware planned conv over the sequence axis; per-channel filters
    # broadcast once the conv axis is moved last inside fft_conv.  fft_conv
    # auto-routes to overlap-save past the fused regime, so prefill never
    # plans a transform larger than FUSED_MAX.
    y = fft_conv(u.astype(jnp.float32), params["filt"], axis=1)  # (B, S, D)
    y = y.astype(cd) * g
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cd))
    out = ann(out, "batch", "seq", "embed")
    if return_cache:
        if getattr(cfg, "spectral_decode_mode", "stream") == "ring":
            lf = cfg.spectral_filter_len
            keep = min(lf, s)
            pos = jnp.arange(s - keep, s)
            buf = jnp.zeros((b, lf, d), jnp.float32)
            # ring layout: buf[p % lf] = u[position p] (decode's convention).
            buf = buf.at[:, pos % lf, :].set(u.astype(jnp.float32)[:, s - keep :, :])
            return out, SpectralCache(buf=buf, t=jnp.asarray(s, jnp.int32))
        return out, _stream_state_from_u(
            u.astype(jnp.float32), params["filt"], cfg
        )
    return out


def init_spectral_cache(cfg, batch, dtype=jnp.float32) -> SpectralCache:
    return SpectralCache(
        buf=jnp.zeros((batch, cfg.spectral_filter_len, cfg.d_model), jnp.float32),
        t=jnp.asarray(0, jnp.int32),
    )


def init_spectral_stream_cache(cfg, batch, dtype=jnp.float32) -> SpectralStreamCache:
    d = cfg.d_model
    c, _ = stream_grain(cfg)
    cap = cfg.spectral_filter_len - 1 + c
    return SpectralStreamCache(
        hist=jnp.zeros((batch, d, cap), jnp.float32),
        chunk=jnp.zeros((batch, d, c), jnp.float32),
        future=jnp.zeros((batch, d, c), jnp.float32),
        phase=jnp.asarray(0, jnp.int32),
    )


def spectral_decode(params, x, cache: SpectralCache, *, cfg) -> Tuple[jax.Array, SpectralCache]:
    """One token.  Direct dot with the filter over the ring buffer."""
    b, _, d = x.shape
    lf = cfg.spectral_filter_len
    cd = x.dtype
    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cd))[:, 0]  # (B,D)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(cd)))[:, 0]
    slot = cache.t % lf
    buf = jax.lax.dynamic_update_slice_in_dim(
        cache.buf, u.astype(jnp.float32)[:, None, :], slot, axis=1
    )
    # Filter tap j multiplies input from j steps ago = slot - j (mod Lf).
    ages = (slot - jnp.arange(lf)) % lf  # index of the input j steps back
    hist = jnp.take(buf, ages, axis=1)  # (B, Lf, D) newest-first
    valid = jnp.arange(lf) <= jnp.minimum(cache.t, lf - 1)
    hist = hist * valid[None, :, None]
    y = jnp.einsum("blD,Dl->bD", hist, params["filt"])  # Σ_j h[d,j]·u[t-j,d]
    y = (y.astype(cd) * g)[:, None, :]
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cd))
    return out, SpectralCache(buf=buf, t=cache.t + 1)


def _head_taps(filt: jax.Array, c: int) -> jax.Array:
    """Filter taps 0..C−1 as (D, C): the direct-head coefficients (taps
    past the filter length are zero)."""
    lf = filt.shape[-1]
    if lf >= c:
        return filt[..., :c]
    return jnp.pad(filt, [(0, 0)] * (filt.ndim - 1) + [(0, c - lf)])


def spectral_stream_decode(
    params, x, cache: SpectralStreamCache, *, cfg
) -> Tuple[jax.Array, SpectralStreamCache]:
    """One token through the StreamingConv-carried state.

    Output = ``future[phase]`` (history half, precomputed at the last
    flush) + the direct head Σ_{j≤phase} h[j]·chunk[phase−j] — together
    exactly Σ_j h[j]·u[t−j], the ring path's answer.  When the chunk fills
    (``phase == C−1``) the window advances: the tail shifts by C and one
    :func:`repro.core.overlap.stream_lookahead` through the cached block
    plan precomputes the next window's history half.
    """
    b, _, d = x.shape
    cd = x.dtype
    c, block = stream_grain(cfg)
    filt = params["filt"]
    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cd))[:, 0]  # (B,D)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(cd)))[:, 0]
    i = cache.phase
    chunk = jax.lax.dynamic_update_slice_in_dim(
        cache.chunk, u.astype(jnp.float32)[..., None], i, axis=2
    )
    # Direct head: slot (i−j) mod C holds u[t−j] for j ≤ i; later slots are
    # zero (flush/insert clears them) — the mask is cheap insurance.
    ages = (i - jnp.arange(c)) % c
    recent = jnp.take(chunk, ages, axis=2) * (jnp.arange(c) <= i)  # (B,D,C)
    y = jnp.einsum("bdc,dc->bd", recent, _head_taps(filt, c))
    y = y + jnp.take(cache.future, i, axis=-1)
    y = (y.astype(cd) * g)[:, None, :]
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cd))

    def _flush(args):
        hist, chunk = args
        hist2 = jnp.concatenate([hist[..., c:], chunk], axis=-1)
        Hr, Hi = ov_lib.filter_spectrum(filt, block)
        fut2 = ov_lib.stream_lookahead(
            hist2[..., c:], Hr, Hi, window=c, block=block
        )
        return hist2, jnp.zeros_like(chunk), fut2, jnp.asarray(0, jnp.int32)

    def _advance(args):
        hist, chunk = args
        return hist, chunk, cache.future, i + 1

    hist2, chunk2, fut2, phase2 = jax.lax.cond(
        i == c - 1, _flush, _advance, (cache.hist, chunk)
    )
    return out, SpectralStreamCache(
        hist=hist2, chunk=chunk2, future=fut2, phase=phase2
    )


def spectral_stream_rephase(
    filt: jax.Array, cache: SpectralStreamCache, phase, *, cfg
) -> SpectralStreamCache:
    """Re-align a freshly-prefilled stream cache (phase 0, boundary at its
    own prompt end S) to a running batch's global ``phase`` f ∈ [0, C).

    The joined slot's window boundary moves back to ``S − f``: its last
    ``f`` prompt inputs become live chunk slots ``[0, f)`` and the tail is
    re-cut at the new boundary (the extra ``C`` history slots in ``hist``
    exist exactly so this slice is always available).  One lookahead conv
    rebuilds ``future`` for the shifted window; leading ``hist`` slots the
    shift exposes are zeroed — they are only ever dropped by later flushes.
    All ops address the trailing axis, so this maps over stacked
    (repeats-leading) caches unchanged.
    """
    lf = cfg.spectral_filter_len
    c, block = stream_grain(cfg)
    cap = lf - 1 + c
    f = jnp.asarray(phase, jnp.int32)
    lead = cache.hist.shape[:-1]
    histp = jnp.pad(
        cache.hist, [(0, 0)] * (cache.hist.ndim - 1) + [(0, c)]
    )  # index m ↦ u[S − cap + m], zeros for m ≥ cap
    tail = jax.lax.dynamic_slice_in_dim(histp, c - f, lf - 1, axis=-1)
    chunk = jax.lax.dynamic_slice_in_dim(histp, cap - f, c, axis=-1)
    chunk = chunk * (jnp.arange(c) < f)
    hist = jnp.concatenate(
        [jnp.zeros((*lead, c), jnp.float32), tail], axis=-1
    )
    Hr, Hi = ov_lib.filter_spectrum(filt, block)
    future = ov_lib.stream_lookahead(tail, Hr, Hi, window=c, block=block)
    return SpectralStreamCache(hist=hist, chunk=chunk, future=future, phase=f)


def stream_plan_info(cfg, batch: int = 1) -> dict:
    """Streaming-conv plan metadata for artifacts (dry-run decode cells):
    the decode grain, the flush plan's schedule, and the modeled HBM bytes
    of one flush at that grain."""
    from repro.analysis import roofline as rl
    from repro.core import plan as plan_lib

    lf = cfg.spectral_filter_len
    c, block = stream_grain(cfg)
    report = rl.conv_report(lf - 1 + c, lf, batch=batch, block=block)
    return {
        "filter_len": lf,
        "chunk": c,
        "block": block,
        "flushes_per_token": 1.0 / c,
        "flush_schedule": plan_lib.describe(block),
        "flush_hbm_bytes": report["overlap_save"]["hbm_bytes"],
    }
