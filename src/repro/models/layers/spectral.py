"""Spectral mixer — the paper's FFT as an LM layer (Hyena-style long conv).

Token mixing by causal convolution with a learned per-channel global filter,
computed as rfft → pointwise → irfft through :mod:`repro.core` — i.e. every
transform uses the paper's memory-optimized plan (fused Pallas kernels on
TPU, four-step XLA elsewhere).  A multiplicative gate keeps it competitive
as a drop-in replacement for attention in the ablation configs.

Decode uses a ring buffer of the last ``filter_len`` inputs and computes the
direct dot product (O(Lf) per token) — exactly equivalent to the FFT path.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conv import fft_conv
from repro.sharding.logical import ann
from repro.utils.params import Param, normal

__all__ = [
    "spectral_init",
    "spectral_forward",
    "spectral_decode",
    "init_spectral_cache",
    "SpectralCache",
]


class SpectralCache(NamedTuple):
    buf: jax.Array  # (B, Lf, D) ring buffer of recent inputs
    t: jax.Array    # scalar step counter (for ring indexing)


def spectral_init(key, cfg, dtype) -> dict:
    D, Lf = cfg.d_model, cfg.spectral_filter_len
    ks = jax.random.split(key, 4)
    # Smooth decaying filter init: h[d, j] ~ N(0, 1/Lf) · exp(-j/τ_d).
    j = np.arange(Lf, dtype=np.float32)
    tau = np.logspace(1.0, np.log10(Lf), D, dtype=np.float32)
    envelope = np.exp(-j[None, :] / tau[:, None])  # (D, Lf)
    base = jax.random.normal(ks[0], (D, Lf), jnp.float32) * (Lf**-0.5)
    return {
        "filt": Param((base * envelope).astype(jnp.float32), ("embed", "filter")),
        "w_gate": normal(ks[1], (D, D), ("embed", "ff"), dtype=dtype),
        "w_in": normal(ks[2], (D, D), ("embed", "ff"), dtype=dtype),
        "w_out": normal(ks[3], (D, D), ("ff", "embed"), dtype=dtype),
    }


def spectral_forward(params, x, *, cfg, return_cache: bool = False):
    """x: (B, S, D) → (B, S, D) via gated FFT long convolution."""
    b, s, d = x.shape
    cd = x.dtype
    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cd))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(cd)))
    # axis-aware planned conv over the sequence axis; per-channel filters
    # broadcast once the conv axis is moved last inside fft_conv.
    y = fft_conv(u.astype(jnp.float32), params["filt"], axis=1)  # (B, S, D)
    y = y.astype(cd) * g
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cd))
    out = ann(out, "batch", "seq", "embed")
    if return_cache:
        lf = cfg.spectral_filter_len
        keep = min(lf, s)
        pos = jnp.arange(s - keep, s)
        buf = jnp.zeros((b, lf, d), jnp.float32)
        # ring layout: buf[p % lf] = u[position p] (decode's convention).
        buf = buf.at[:, pos % lf, :].set(u.astype(jnp.float32)[:, s - keep :, :])
        return out, SpectralCache(buf=buf, t=jnp.asarray(s, jnp.int32))
    return out


def init_spectral_cache(cfg, batch, dtype=jnp.float32) -> SpectralCache:
    return SpectralCache(
        buf=jnp.zeros((batch, cfg.spectral_filter_len, cfg.d_model), jnp.float32),
        t=jnp.asarray(0, jnp.int32),
    )


def spectral_decode(params, x, cache: SpectralCache, *, cfg) -> Tuple[jax.Array, SpectralCache]:
    """One token.  Direct dot with the filter over the ring buffer."""
    b, _, d = x.shape
    lf = cfg.spectral_filter_len
    cd = x.dtype
    u = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cd))[:, 0]  # (B,D)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(cd)))[:, 0]
    slot = cache.t % lf
    buf = jax.lax.dynamic_update_slice_in_dim(
        cache.buf, u.astype(jnp.float32)[:, None, :], slot, axis=1
    )
    # Filter tap j multiplies input from j steps ago = slot - j (mod Lf).
    ages = (slot - jnp.arange(lf)) % lf  # index of the input j steps back
    hist = jnp.take(buf, ages, axis=1)  # (B, Lf, D) newest-first
    valid = jnp.arange(lf) <= jnp.minimum(cache.t, lf - 1)
    hist = hist * valid[None, :, None]
    y = jnp.einsum("blD,Dl->bD", hist, params["filt"])  # Σ_j h[d,j]·u[t-j,d]
    y = (y.astype(cd) * g)[:, None, :]
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cd))
    return out, SpectralCache(buf=buf, t=cache.t + 1)
