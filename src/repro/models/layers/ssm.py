"""Mamba2 (SSD) block — chunked matmul form + O(1) decode state.

Implements the state-space-duality block of Mamba2 (Dao & Gu 2024), as used
by zamba2: input projection → short causal conv (width 4) → SSD scan with
per-head scalar decay → gated RMSNorm → output projection.

The SSD scan runs in *chunked* form: within a chunk of length Q everything
is dense matmuls (MXU-friendly), across chunks a ``lax.scan`` carries the
(H, P, N) state — the TPU-native balance between a pure recurrence (too
sequential) and the quadratic kernel (too much memory).  Decode keeps the
recurrent state explicitly: one token costs O(H·P·N).

Shapes: d_inner = expand·d_model, H heads of head dim P = d_inner/H,
state dim N = cfg.ssm_state, n_groups = 1 (B/C shared across heads).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import rms_norm, rms_norm_init
from repro.sharding.logical import ann
from repro.utils.params import Param, normal, ones, zeros

__all__ = ["mamba2_init", "mamba2_forward", "mamba2_decode", "init_ssm_cache", "SSMCache"]


class SSMCache(NamedTuple):
    state: jax.Array  # (B, H, P, N)
    conv: jax.Array   # (B, W-1, conv_dim) last inputs for the causal conv


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.resolved_ssm_heads
    p = d_inner // h
    n = cfg.ssm_state
    return d_inner, h, p, n


def mamba2_init(key, cfg, dtype) -> dict:
    D = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 6)
    return {
        # fused in-proj: [z gate | xBC (conv path) | dt]
        "w_in": normal(
            ks[0],
            (D, d_inner + conv_dim + h),
            ("embed", "ff"),
            dtype=dtype,
        ),
        "conv_w": normal(
            ks[1], (cfg.conv_width, conv_dim), ("conv", "ff"), scale=cfg.conv_width**-0.5, dtype=dtype
        ),
        "conv_b": zeros((conv_dim,), ("ff",), dtype=dtype),
        "a_log": Param(
            jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32), ("heads",)
        ),
        "dt_bias": Param(
            jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, h))).astype(jnp.float32),
            ("heads",),
        ),
        "d_skip": ones((h,), ("heads",), dtype=jnp.float32),
        "norm": rms_norm_init(d_inner, jnp.float32),
        "w_out": normal(
            ks[2], (d_inner, D), ("ff", "embed"), scale=d_inner**-0.5, dtype=dtype
        ),
    }


def _in_proj(params, x, cfg):
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    cd = x.dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cd))
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim :]
    return z, xbc, dt_raw


def _conv_apply(params, xbc, cfg, *, carry=None):
    """Causal depthwise conv width W over (B, S, conv_dim)."""
    w = params["conv_w"].astype(xbc.dtype)  # (W, C)
    width = w.shape[0]
    if carry is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = carry.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    out = out + params["conv_b"].astype(xbc.dtype)
    new_carry = xp[:, -(width - 1) :, :] if width > 1 else pad
    return jax.nn.silu(out), new_carry


def _gates(params, dt_raw, cfg):
    """Returns (log_decay, dt) per (B, S, H) in float32."""
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])  # (H,) negative continuous-time decay
    log_decay = a * dt  # log exp(a·dt) = a·dt  (≤ 0)
    return log_decay, dt


def _ssd_chunked(xh, b_in, c_in, log_a, dt, h0, chunk: int):
    """Chunked SSD.  xh: (B,S,H,P); b_in/c_in: (B,S,N); log_a/dt: (B,S,H).

    Recurrence per head: h_t = exp(log_a_t)·h_{t-1} + dt_t·b_t xh_tᵀ;
    y_t = c_tᵀ h_t.  Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    bsz, s, h, p = xh.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    nc = s // q
    assert nc * q == s, (s, q)

    # Chunk-major layouts for the scan: (nc, B, Q, ...).
    xh_c = jnp.moveaxis(xh.reshape(bsz, nc, q, h, p), 1, 0)
    b_c = jnp.moveaxis(b_in.reshape(bsz, nc, q, n), 1, 0)
    c_c = jnp.moveaxis(c_in.reshape(bsz, nc, q, n), 1, 0)
    la_c = jnp.moveaxis(log_a.reshape(bsz, nc, q, h), 1, 0)
    dt_c = jnp.moveaxis(dt.reshape(bsz, nc, q, h), 1, 0)
    causal = jnp.tril(jnp.ones((q, q), bool))

    @jax.checkpoint  # recompute the (B,Q,Q,H) decay tensors in backward
    def body(h_prev, inp):
        """One chunk: intra (dense matmuls) + inter (vs. carried state)."""
        xc, bc, cc, la, dtc = inp  # (B,Q,H,P), (B,Q,N), (B,Q,N), (B,Q,H)×2
        cum = jnp.cumsum(la, axis=1)  # (B,Q,H) inclusive
        tot = cum[:, -1, :]  # (B,H)
        # intra: ((C Bᵀ) ⊙ M) X, M[t,s] = e^{cum[t]-cum[s]}·dt[s], s ≤ t
        scores = jnp.einsum("bqn,bkn->bqk", cc, bc)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        m = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        m = m * dtc[:, None, :, :]
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, m, xc)
        # inter: y[t] += e^{cum[t]} · c_t · h_prev
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cc, h_prev, jnp.exp(cum))
        # state: h = e^{tot}·h_prev + Σ_s e^{tot-cum[s]}·dt[s]·b_s x_sᵀ
        w_s = jnp.exp(tot[:, None, :] - cum) * dtc  # (B,Q,H)
        h_new = h_prev * jnp.exp(tot)[:, :, None, None] + jnp.einsum(
            "bqh,bqn,bqhp->bhpn", w_s, bc, xc
        )
        return h_new, y_intra + y_inter

    h_final, y_c = jax.lax.scan(body, h0, (xh_c, b_c, c_c, la_c, dt_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(bsz, s, h, p)
    return y, h_final


def mamba2_forward(params, x, *, cfg, return_cache: bool = False):
    """x: (B, S, D) → y (B, S, D) [, SSMCache]."""
    bsz, s, _ = x.shape
    d_inner, h, p, n = _dims(cfg)
    z, xbc, dt_raw = _in_proj(params, x, cfg)
    xbc, conv_carry = _conv_apply(params, xbc, cfg)
    xh = xbc[..., :d_inner].reshape(bsz, s, h, p)
    b_in = xbc[..., d_inner : d_inner + n]
    c_in = xbc[..., d_inner + n :]
    log_a, dt = _gates(params, dt_raw, cfg)
    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    y, h_final = _ssd_chunked(
        xh.astype(jnp.float32),
        b_in.astype(jnp.float32),
        c_in.astype(jnp.float32),
        log_a,
        dt,
        h0,
        cfg.chunk_size,
    )
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    out = ann(out, "batch", "seq", "embed")
    if return_cache:
        return out, SSMCache(state=h_final, conv=conv_carry)
    return out


def init_ssm_cache(cfg, batch, dtype=jnp.float32) -> SSMCache:
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return SSMCache(
        state=jnp.zeros((batch, h, p, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    )


def mamba2_decode(params, x, cache: SSMCache, *, cfg) -> Tuple[jax.Array, SSMCache]:
    """One token: x (B, 1, D) → (y (B, 1, D), new cache)."""
    bsz = x.shape[0]
    d_inner, h, p, n = _dims(cfg)
    z, xbc, dt_raw = _in_proj(params, x, cfg)
    xbc, conv_carry = _conv_apply(params, xbc, cfg, carry=cache.conv)
    xh = xbc[..., :d_inner].reshape(bsz, h, p)
    b_in = xbc[..., 0, d_inner : d_inner + n]
    c_in = xbc[..., 0, d_inner + n :]
    log_a, dt = _gates(params, dt_raw, cfg)  # (B,1,H)
    decay = jnp.exp(log_a[:, 0, :])  # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0, :], b_in.astype(jnp.float32), xh.astype(jnp.float32))
    state = cache.state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c_in.astype(jnp.float32), state)
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, SSMCache(state=state, conv=conv_carry)
