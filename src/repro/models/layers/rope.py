"""Rotary position embeddings: standard (llama-style) and M-RoPE (qwen2-vl).

Functional: callers pass integer position ids, we return rotated q/k.  For
M-RoPE, ``positions`` has shape (B, 3, S) — (temporal, height, width) — and
the rotary half-dim is partitioned into ``sections`` driven by the respective
position component (text tokens supply t = h = w = sequence index).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["apply_rope", "apply_mrope", "rope_freqs"]


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    half = head_dim // 2
    return (theta ** (-np.arange(0, half, dtype=np.float64) / half)).astype(
        np.float32
    )


def _rotate(x, sin, cos):
    # llama-style: split halves.
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd/2)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)  # (B, S, 1, hd/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    return _rotate(x, sin, cos)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Sequence[int],
) -> jax.Array:
    """M-RoPE: x (B, S, H, hd); positions (B, 3, S) for (t, h, w).

    The half-dim frequency bands are partitioned into ``sections`` (summing
    to hd/2); band i rotates by the position component assigned to it
    (qwen2-vl assigns [t, h, w] in order).
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (half,)
    # Select which of the 3 position streams drives each frequency band.
    comp = np.concatenate(
        [np.full((s,), i, dtype=np.int32) for i, s in enumerate(sections)]
    )
    # (B, half, S): position component comp[f] drives frequency band f.
    pos_sel = positions.astype(jnp.float32)[:, comp, :]
    ang = jnp.swapaxes(pos_sel, 1, 2) * freqs  # (B, S, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    return _rotate(x, sin, cos)
