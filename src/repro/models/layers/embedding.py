"""Token embedding + output head (optionally tied), with chunked loss helper."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.logical import ann
from repro.utils.params import normal

__all__ = ["embed_init", "embed_apply", "head_init", "head_apply"]


def embed_init(key, cfg, dtype) -> dict:
    return {
        "table": normal(
            key,
            (cfg.vocab_size, cfg.d_model),
            ("vocab", "embed"),
            scale=1.0,
            dtype=dtype,
        )
    }


def embed_apply(params, tokens, cfg, compute_dtype):
    x = jnp.take(params["table"], tokens, axis=0).astype(compute_dtype)
    x = x * jnp.asarray(cfg.d_model**0.5, compute_dtype)  # gemma-style scale
    return ann(x, "batch", "seq", "embed")


def head_init(key, cfg, dtype) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {
        "w": normal(
            key,
            (cfg.d_model, cfg.vocab_size),
            ("embed", "vocab"),
            dtype=dtype,
        )
    }


def head_apply(head_params, embed_params, x, cfg):
    """Logits in float32 (optionally final-softcapped)."""
    if cfg.tie_embeddings:
        w = embed_params["table"].astype(jnp.float32).T
    else:
        w = head_params["w"].astype(jnp.float32)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32), w)
    if cfg.final_logit_softcap:
        cap = cfg.final_logit_softcap
        logits = jnp.tanh(logits / cap) * cap
    return ann(logits, "batch", "seq", "vocab")
