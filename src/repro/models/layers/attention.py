"""GQA attention: full, chunked (long-context), sliding-window, decode.

Modes
-----
* ``attn_forward``  — train/prefill.  Exact causal attention; above
  ``cfg.attn_chunk_threshold`` query positions it switches to a q-block scan
  (bounded score memory, exact softmax per block).  Sliding-window layers
  restrict each q block to its KV band (gathered with a dynamic slice, so
  compute and memory scale with the window, not the sequence).
* ``attn_decode``   — single-token step against a KV cache.  Global layers
  keep the full cache; sliding-window layers keep a ring buffer of
  ``window`` slots (keys stored pre-rotated at absolute positions).

GQA K/V are *expanded to the full head count* before the score einsums
(broadcast, not copy, until XLA materialises it): with kv_heads as small as
4 and a 16-way tensor axis, the grouped (B, KV, G, Sq, Sk) form leaves the
score tensor replicated over the model axis — at train_4k that is a >30 GB
per-chip tensor.  The expanded (B, H, Sq, Sk) form shards cleanly on heads.
The KV *cache* stays in compact kv_heads form.  Logit softcap (gemma-style)
where configured; softmax always float32.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import rope as rope_lib
from repro.sharding.logical import ann
from repro.utils.params import normal

__all__ = ["attn_init", "attn_forward", "attn_decode", "init_kv_cache", "KVCache"]

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Decode KV cache; optionally int8-quantized (per-slot, per-kv-head).

    k/v: (B, S_slots, KV, hd) — bf16, or int8 with k_scale/v_scale
    (B, S_slots, KV) float32 absmax scales.  int8 halves the dominant
    memory term of the big decode cells (qwen2-vl-72b's 1.4 TB cache) and
    turns the score/PV contractions into int8 MXU dots.
    """

    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None


def _quant_tok(x):
    """x: (B, S, KV, hd) → int8 + per-(B,S,KV) absmax scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-9
    scale = amax / 127.0
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def attn_init(key, cfg, dtype) -> dict:
    D = cfg.d_model
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": normal(ks[0], (D, H, hd), ("embed", "heads", "head_dim"), dtype=dtype),
        "wk": normal(ks[1], (D, KV, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": normal(ks[2], (D, KV, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": normal(
            ks[3],
            (H, hd, D),
            ("heads", "head_dim", "embed"),
            scale=(H * hd) ** -0.5,
            dtype=dtype,
        ),
    }


def _qkv(params, x, cfg, positions, mrope_positions):
    """Project + rotate.  x: (B, S, D) → q (B,S,H,hd), k/v (B,S,KV,hd)."""
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dnk->bsnk", x, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dnk->bsnk", x, params["wv"].astype(cd))
    q = ann(q, "batch", "seq", "heads", "head_dim")
    k = ann(k, "batch", "seq", "kv_heads", "head_dim")
    v = ann(v, "batch", "seq", "kv_heads", "head_dim")
    if cfg.rope_kind == "mrope" and mrope_positions is not None:
        q = rope_lib.apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = rope_lib.apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(x, g: int):
    """(B, S, KV, hd) → (B, S, KV·g, hd), annotated to shard on heads."""
    if g == 1:
        return ann(x, "batch", "seq", "heads", "head_dim")
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, g, hd))
    x = x.reshape(b, s, kv * g, hd)
    return ann(x, "batch", "seq", "heads", "head_dim")


def _attend(q, k_full, v_full, cfg, mask):
    """q: (B,Sq,H,hd); k/v already head-expanded: (B,Sk,H,hd).

    mask: (Sq, Sk) bool.  Returns (B,Sq,H,hd).
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k_full).astype(jnp.float32)
    scores = scores * (hd**-0.5)
    if cfg.attn_logit_softcap:
        cap = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / cap) * cap
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    scores = ann(scores, "batch", "heads", None, None)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v_full.dtype), v_full)
    return ann(out, "batch", "seq", "heads", "head_dim")


def attn_forward(
    params,
    x,
    *,
    cfg,
    positions,
    window: Optional[int] = None,
    mrope_positions=None,
    return_cache: bool = False,
):
    """Causal (optionally banded) attention over a full sequence."""
    b, s, d = x.shape
    h, kv = cfg.num_heads, cfg.num_kv_heads
    g = h // kv
    q, k, v = _qkv(params, x, cfg, positions, mrope_positions)

    if s <= cfg.attn_chunk_threshold:
        pos = positions[0]
        mask = pos[None, :] <= pos[:, None]
        if window:
            mask &= pos[None, :] > (pos[:, None] - window)
        out = _attend(q, _expand_kv(k, g), _expand_kv(v, g), cfg, mask)
    else:
        out = _chunked_attention(q, k, v, cfg, window, g)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    y = ann(y, "batch", "seq", "embed")
    if return_cache:
        return y, KVCache(k=k, v=v)
    return y


def _chunked_attention(q, k, v, cfg, window, g):
    """Exact attention via a scan over q blocks (bounded score memory).

    For sliding-window layers only the KV band [blk·C − w, blk·C + C) is
    gathered per block, so both score memory and FLOPs scale with the
    window — the banded-SWA path that makes the long-context cells
    sub-quadratic.
    """
    b, s, h, hd = q.shape
    c = cfg.attn_chunk
    pad = (-s) % c
    if pad:
        # Pad to a whole number of q blocks; padded keys sit at positions
        # ≥ s so the causal mask excludes them from every real query row,
        # and padded query rows are sliced off below.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = s + pad
    nblk = s_pad // c
    banded = window is not None and window < s_pad
    band = None
    if banded:
        band = ((window + c - 1) // c + 1) * c  # KV band width, chunk-aligned

    k_full = _expand_kv(k, g)
    v_full = _expand_kv(v, g)

    # checkpoint the chunk body: without it, differentiating the scan saves
    # every chunk's (B, H, C, S) float32 probs — ~1 GB × chunks × layers on
    # the 72B train cell (measured 267 GB of temp).  Flash-attention-style
    # recompute instead.
    @jax.checkpoint
    def body(_, blk):
        start = blk * c
        qc = jax.lax.dynamic_slice_in_dim(q, start, c, axis=1)
        q_pos = start + jnp.arange(c)
        if banded:
            k_start = jnp.maximum(start + c - band, 0)
            kc = jax.lax.dynamic_slice_in_dim(k_full, k_start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v_full, k_start, band, axis=1)
            k_pos = k_start + jnp.arange(band)
        else:
            kc, vc = k_full, v_full
            k_pos = jnp.arange(s_pad)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        return None, _attend(qc, kc, vc, cfg, mask)

    _, outs = jax.lax.scan(body, None, jnp.arange(nblk))
    # outs: (nblk, B, C, H, hd) → (B, S_pad, H, hd) → drop padded rows
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s_pad, h, hd)
    return out[:, :s]


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------


def init_kv_cache(cfg, batch, max_len, *, window: Optional[int] = None, dtype=jnp.bfloat16):
    slots = min(window, max_len) if window else max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, slots, kv, hd)
    if getattr(cfg, "kv_cache_dtype", "bf16") == "int8":
        return KVCache(
            k=jnp.zeros(shape, jnp.int8),
            v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(shape[:3], jnp.float32),
            v_scale=jnp.zeros(shape[:3], jnp.float32),
        )
    # distinct buffers so cache donation never aliases k and v
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attn_decode(
    params,
    x,
    cache: KVCache,
    t,
    *,
    cfg,
    window: Optional[int] = None,
    mrope_positions=None,
):
    """One decode step.  x: (B, 1, D); t: int32 current position — a scalar
    (whole batch at one timeline) or a (B,) vector of per-slot positions
    (continuous batching: each serving slot keeps its OWN timeline, so a
    request inserted mid-stream decodes at its own ``t`` with no position
    shifting).

    Returns (y, new_cache).  Sliding-window layers use a ring buffer of
    ``window`` slots (t mod window); keys are stored already rotated at
    their absolute position so lookups are position-independent.
    """
    b, _, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kv
    t = jnp.asarray(t, jnp.int32)
    per_slot = t.ndim == 1
    t_vec = t if per_slot else jnp.full((b,), t, jnp.int32)
    positions = t_vec[:, None]
    q, k_new, v_new = _qkv(params, x, cfg, positions, mrope_positions)

    slots = cache.k.shape[1]
    quantized = cache.k.dtype == jnp.int8
    # No explicit sharding annotation here: the cache arrives with the
    # launcher-chosen sharding (e.g. seq over ('data','model') for long
    # contexts) and the update must inherit it — a fixed kv_seq constraint
    # forces SPMD into a full rematerialisation of the cache (measured:
    # +17 GB temp on gemma3 long_500k).
    if per_slot:
        # Per-row scatter at each slot's own write position.
        rows = jnp.arange(b)
        slot_vec = (t_vec % slots) if window else t_vec

        def upd(buf, new):
            return buf.at[rows, slot_vec].set(new[:, 0].astype(buf.dtype))

    else:
        slot = (t % slots) if window else t

        def upd(buf, new):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, new.astype(buf.dtype), slot, axis=1
            )

    if quantized:
        kq_new, ks_new = _quant_tok(k_new)
        vq_new, vs_new = _quant_tok(v_new)
        k, v = upd(cache.k, kq_new), upd(cache.v, vq_new)
        k_scale = upd(cache.k_scale, ks_new)
        v_scale = upd(cache.v_scale, vs_new)
    else:
        k, v = upd(cache.k, k_new), upd(cache.v, v_new)
        k_scale = v_scale = None

    # Grouped read against the compact cache: q (B,KV,G,hd).  The query is
    # tiny (one token) — pin it to batch-only sharding so the contraction
    # happens in the *cache's* layout.  Leaving q heads-sharded makes SPMD
    # all-to-all the entire seq-sharded KV cache into head-sharded layout
    # every layer (measured 142 GB/chip/step on yi-6b decode_32k).
    qg = ann(q.reshape(b, kv, g, hd), "batch", None, None, None)
    if quantized:
        # int8 × int8 MXU dot; scales folded back per (b, kv[, slot]).
        q_amax = jnp.max(jnp.abs(qg.astype(jnp.float32)), axis=-1) + 1e-9
        q_s = q_amax / 127.0  # (B,KV,G)
        q_q = jnp.clip(
            jnp.round(qg.astype(jnp.float32) / q_s[..., None]), -127, 127
        ).astype(jnp.int8)
        scores = jnp.einsum(
            "bngh,bknh->bngk", q_q, k, preferred_element_type=jnp.int32
        ).astype(jnp.float32)
        ks_t = jnp.swapaxes(k_scale, 1, 2)  # (B,KV,S)
        scores = scores * q_s[..., None] * ks_t[:, :, None, :]
    else:
        scores = jnp.einsum("bngh,bknh->bngk", qg, k).astype(jnp.float32)
    scores = scores * (hd**-0.5)
    if cfg.attn_logit_softcap:
        cap = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / cap) * cap
    slot_idx = jnp.arange(slots)
    if window:
        # Ring buffer: once t >= slots every slot holds a live key.
        lim = jnp.minimum(t_vec, slots - 1)
    else:
        lim = t_vec
    valid = slot_idx[None, :] <= lim[:, None]  # (B, slots) per-row mask
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if quantized:
        # Fold the per-slot v scale into the probs *before* quantising them
        # (the scale rides the contracted axis), then int8 × int8 again.
        vs_t = jnp.swapaxes(v_scale, 1, 2)  # (B,KV,S)
        pv = probs * vs_t[:, :, None, :]
        pv_amax = jnp.max(jnp.abs(pv), axis=-1) + 1e-12
        pv_s = pv_amax / 127.0
        pv_q = jnp.clip(jnp.round(pv / pv_s[..., None]), -127, 127).astype(jnp.int8)
        out = jnp.einsum(
            "bngk,bknh->bngh", pv_q, v, preferred_element_type=jnp.int32
        ).astype(jnp.float32) * pv_s[..., None]
        out = out.astype(x.dtype)
    else:
        out = jnp.einsum("bngk,bknh->bngh", probs.astype(v.dtype), v)
    out = out.reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, KVCache(k=k, v=v, k_scale=k_scale, v_scale=v_scale)
