"""Mixture-of-Experts layer: top-k routing, shared experts, dense residual.

Covers both assigned MoE archs:
  * arctic-480b       — 128 experts, top-2, plus a *dense residual* MLP in
                        parallel with the MoE branch;
  * deepseek-moe-16b  — 64 fine-grained routed experts, top-6, plus 2
                        *shared* experts that every token passes through.

Dispatch is sort-free scatter/gather ("megablocks-lite"): tokens are placed
into per-expert capacity slots via a cumsum-over-one-hot position assignment
(slots are unique by construction, so a single scatter suffices), expert
FFNs run as one batched einsum over stacked (E, D, F) weights — which shards
cleanly over the 'experts'/'model' mesh axis (EP) — and results are gathered
back with the normalised top-k router weights.  Overflow tokens are dropped
(standard capacity-factor semantics); the router aux loss (load balancing,
Switch-style) is returned for the training loop.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.mlp import _ACT, mlp_init, mlp_apply
from repro.sharding.logical import ann
from repro.utils.params import normal

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": normal(ks[0], (D, E), ("embed", "experts"), scale=0.02, dtype=jnp.float32),
        "wi_gate": normal(ks[1], (E, D, F), ("experts", "embed", "expert_ff"), scale=D**-0.5, dtype=dtype),
        "wi_up": normal(ks[2], (E, D, F), ("experts", "embed", "expert_ff"), scale=D**-0.5, dtype=dtype),
        "wo": normal(ks[3], (E, F, D), ("experts", "expert_ff", "embed"), scale=F**-0.5, dtype=dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], D, F * cfg.num_shared_experts, dtype, act=cfg.act)
    if cfg.moe_dense_residual:
        p["dense"] = mlp_init(ks[5], D, F, dtype, act=cfg.act)
    return p


def _capacity(tokens: int, cfg) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    cap = max(8, (cap + 7) // 8 * 8)  # sublane-aligned
    # Never more slots than a row can assign: `pos < cap` cannot bind beyond
    # tokens·k, so this clamp changes no routing decision — it only stops the
    # aligned floor from blowing the decode-step (tokens=1) dispatch buffer
    # and expert-GEMM rows up by 8/k per expert.
    return min(cap, tokens * cfg.top_k)


def moe_apply(params, x, *, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y, aux_loss).

    Group-local dispatch (§Perf hillclimb 3): all routing bookkeeping
    (cumsum position assignment, capacity, scatter, gather) is per-group,
    with one group per **batch row**.  Rows are contiguous on a data shard,
    so the bookkeeping stays shard-local (the property that fixed the
    995 GB/chip/step all-reduce on deepseek train_4k); the only cross-shard
    traffic is the (E, G·C_g, D) buffer re-sharding from group-sharded to
    expert-sharded around the expert GEMMs (a true all-to-all of the token
    payload).

    Row-local groups also make routing *batch-invariant and prefix-causal*:
    a token's capacity slot depends only on earlier tokens of its own
    sequence, never on other requests in the batch or on padding beyond it —
    the property the serving path's decode-equivalence tests assert (the
    earlier flat (T/G)-token grouping let row 0's tail displace row 1's
    tokens, so prefill logits changed with batch composition).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cd = x.dtype
    t = b * s
    ng = b  # one group per batch row: shard-local AND batch-invariant
    tl = s
    cg = _capacity(tl, cfg)  # per-row expert capacity

    xt = ann(x.reshape(ng, tl, d), "batch", None, "embed")

    # --- routing (float32 for a stable softmax), group-local -------------
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), params["router"])
    logits = ann(logits, "batch", None, "experts")
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tl, E)
    weights, idx = jax.lax.top_k(probs, k)  # (G, Tl, k)
    weights = weights / (weights.sum(-1, keepdims=True) + 1e-9)

    # --- per-group capacity slots via local cumsum ------------------------
    flat_e = idx.reshape(ng, tl * k)  # (G, Tl·k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, Tl·k, E)
    pos = (jnp.cumsum(onehot, axis=1) - 1) * onehot  # local prefix per group
    pos = pos.sum(-1)  # (G, Tl·k)
    keep = pos < cg
    slot = flat_e * cg + jnp.minimum(pos, cg - 1)  # within-group slot

    # --- group-local scatter to (G, E·C_g, D) ------------------------------
    tok_idx = jnp.tile(jnp.repeat(jnp.arange(tl), k)[None], (ng, 1))
    contrib = jnp.take_along_axis(xt, tok_idx[..., None], axis=1).astype(cd)
    contrib = contrib * keep[..., None].astype(cd)

    def scatter_one(c_, s_):
        return jnp.zeros((e * cg, d), cd).at[s_].add(c_)

    buf = jax.vmap(scatter_one)(contrib, slot)  # (G, E·C_g, D), group-local
    buf = ann(buf, "batch", None, "embed")
    # (G, E, C_g, D) → (E, G·C_g, D): the honest expert-parallel all-to-all.
    h = jnp.swapaxes(buf.reshape(ng, e, cg, d), 0, 1).reshape(e, ng * cg, d)
    h = ann(h, "experts", None, "embed")

    # --- batched expert FFN (shards over 'experts' = EP) ----------------
    g = jnp.einsum("ecd,edf->ecf", h, params["wi_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", h, params["wi_up"].astype(cd))
    act = _ACT[cfg.act](g) * u
    act = ann(act, "experts", None, "expert_ff")
    y_e = jnp.einsum("ecf,efd->ecd", act, params["wo"].astype(cd))

    # --- back to group-sharded layout (all-to-all #2), local gather ------
    y_g = jnp.swapaxes(y_e.reshape(e, ng, cg, d), 0, 1)  # (G, E, C_g, D)
    y_g = ann(y_g.reshape(ng, e * cg, d), "batch", None, "embed")
    y_tok = jnp.take_along_axis(y_g, slot[..., None], axis=1)  # (G, Tl·k, D)
    w = (weights.reshape(ng, tl * k) * keep.astype(jnp.float32)).astype(cd)
    y = (y_tok * w[..., None]).reshape(ng, tl, k, d).sum(axis=2)  # (G, Tl, D)
    y = y.reshape(t, d)

    # --- shared experts / dense residual ---------------------------------
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, act=cfg.act).reshape(t, d)
    if "dense" in params:
        y = y + mlp_apply(params["dense"], x, act=cfg.act).reshape(t, d)

    # --- Switch-style load-balance aux loss -------------------------------
    me = probs.mean(axis=(0, 1))  # mean router prob per expert
    ce = onehot.reshape(t, k, e).sum(1).astype(jnp.float32).mean(axis=0)
    aux = (me * ce).sum() * e * cfg.router_aux_loss
    y = ann(y.reshape(b, s, d), "batch", "seq", "embed")
    return y, aux
