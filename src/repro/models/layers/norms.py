"""Normalisation layers (functional, dict-param style)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm_init", "rms_norm", "layer_norm_init", "layer_norm"]


def rms_norm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params, x, *, eps: float = 1e-6):
    # Variance in float32 (fuses into the reduce), but the scaling multiply
    # stays in the compute dtype: materialising the full activation in f32
    # costs 2× bytes per norm × 2 norms/layer × fwd+bwd — measured as the
    # dominant temp-memory term on the 72B train cell.
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def layer_norm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)
