"""Gated MLP (SwiGLU / GeGLU) and the plain variant."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.logical import ann
from repro.utils.params import normal

__all__ = ["mlp_init", "mlp_apply"]

_ACT = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def mlp_init(key, d_model: int, d_ff: int, dtype, *, act: str = "silu") -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": normal(ks[0], (d_model, d_ff), ("embed", "ff"), dtype=dtype),
        "wi_up": normal(ks[1], (d_model, d_ff), ("embed", "ff"), dtype=dtype),
        "wo": normal(
            ks[2], (d_ff, d_model), ("ff", "embed"), scale=d_ff**-0.5, dtype=dtype
        ),
    }


def mlp_apply(params, x, *, act: str = "silu"):
    cd = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(cd))
    u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(cd))
    h = _ACT[act](g) * u
    h = ann(h, "batch", "seq", "ff")
    y = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(cd))
    return ann(y, "batch", "seq", "embed")
