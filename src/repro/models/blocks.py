"""Residual blocks: one mixer (+ MLP where the family uses one) per kind.

Kinds
-----
attn          pre-norm global attention + pre-norm MLP
attn_local    same, sliding-window (cfg.sliding_window)
moe           pre-norm attention + pre-norm MoE FFN
mamba2        pre-norm Mamba2 (self-contained, no MLP)
mlstm         pre-norm mLSTM (self-contained, no MLP)
slstm         pre-norm sLSTM + pre-norm MLP
shared_attn   structurally == attn; the stack shares its params
spectral      pre-norm FFT long-conv mixer + pre-norm MLP

All forwards return ``(x, cache_or_None, aux_loss)``; decodes return
``(x, new_cache)``.  Caches are NamedTuples from the layer modules.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import attention as attn_lib
from repro.models.layers import spectral as spec_lib
from repro.models.layers import ssm as ssm_lib
from repro.models.layers import xlstm as xlstm_lib
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.layers.moe import moe_apply, moe_init
from repro.models.layers.norms import rms_norm, rms_norm_init

__all__ = [
    "block_init",
    "block_forward",
    "block_decode",
    "block_cache_init",
    "ATTN_KINDS",
]

ATTN_KINDS = ("attn", "attn_local", "moe", "shared_attn")


def _ff_dim(cfg) -> int:
    return cfg.d_ff if cfg.d_ff > 0 else 2 * cfg.d_model


def block_init(key, kind: str, cfg, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn", "attn_local", "shared_attn"):
        return {
            "norm1": rms_norm_init(d),
            "mixer": attn_lib.attn_init(k1, cfg, dtype),
            "norm2": rms_norm_init(d),
            "mlp": mlp_init(k2, d, _ff_dim(cfg), dtype, act=cfg.act),
        }
    if kind == "moe":
        return {
            "norm1": rms_norm_init(d),
            "mixer": attn_lib.attn_init(k1, cfg, dtype),
            "norm2": rms_norm_init(d),
            "moe": moe_init(k2, cfg, dtype),
        }
    if kind == "mamba2":
        return {"norm1": rms_norm_init(d), "mixer": ssm_lib.mamba2_init(k1, cfg, dtype)}
    if kind == "mlstm":
        return {"norm1": rms_norm_init(d), "mixer": xlstm_lib.mlstm_init(k1, cfg, dtype)}
    if kind == "slstm":
        return {
            "norm1": rms_norm_init(d),
            "mixer": xlstm_lib.slstm_init(k1, cfg, dtype),
            "norm2": rms_norm_init(d),
            "mlp": mlp_init(k2, d, _ff_dim(cfg), dtype, act=cfg.act),
        }
    if kind == "spectral":
        return {
            "norm1": rms_norm_init(d),
            "mixer": spec_lib.spectral_init(k1, cfg, dtype),
            "norm2": rms_norm_init(d),
            "mlp": mlp_init(k2, d, _ff_dim(cfg), dtype, act=cfg.act),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def _window(kind, cfg) -> Optional[int]:
    return cfg.sliding_window if kind == "attn_local" else None


def block_forward(
    params,
    x,
    *,
    kind: str,
    cfg,
    positions,
    mrope_positions=None,
    return_cache: bool = False,
):
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = rms_norm(params["norm1"], x, eps=cfg.norm_eps)
    if kind in ATTN_KINDS:
        res = attn_lib.attn_forward(
            params["mixer"],
            h,
            cfg=cfg,
            positions=positions,
            window=_window(kind, cfg),
            mrope_positions=mrope_positions,
            return_cache=return_cache,
        )
        if return_cache:
            res, cache = res
        x = x + res
        h2 = rms_norm(params["norm2"], x, eps=cfg.norm_eps)
        if kind == "moe":
            y, aux = moe_apply(params["moe"], h2, cfg=cfg)
        else:
            y = mlp_apply(params["mlp"], h2, act=cfg.act)
        return x + y, cache, aux
    if kind == "mamba2":
        res = ssm_lib.mamba2_forward(params["mixer"], h, cfg=cfg, return_cache=return_cache)
        if return_cache:
            res, cache = res
        return x + res, cache, aux
    if kind == "mlstm":
        res = xlstm_lib.mlstm_forward(params["mixer"], h, cfg=cfg, return_cache=return_cache)
        if return_cache:
            res, cache = res
        return x + res, cache, aux
    if kind == "slstm":
        res = xlstm_lib.slstm_forward(params["mixer"], h, cfg=cfg, return_cache=return_cache)
        if return_cache:
            res, cache = res
        x = x + res
        h2 = rms_norm(params["norm2"], x, eps=cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h2, act=cfg.act), cache, aux
    if kind == "spectral":
        res = spec_lib.spectral_forward(params["mixer"], h, cfg=cfg, return_cache=return_cache)
        if return_cache:
            res, cache = res
        x = x + res
        h2 = rms_norm(params["norm2"], x, eps=cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h2, act=cfg.act), cache, aux
    raise ValueError(f"unknown block kind {kind!r}")


def block_cache_init(kind: str, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    if kind in ATTN_KINDS:
        return attn_lib.init_kv_cache(
            cfg, batch, max_len, window=_window(kind, cfg), dtype=dtype
        )
    if kind == "mamba2":
        return ssm_lib.init_ssm_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_lib.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm_lib.init_slstm_cache(cfg, batch, dtype)
    if kind == "spectral":
        if getattr(cfg, "spectral_decode_mode", "stream") == "ring":
            return spec_lib.init_spectral_cache(cfg, batch, dtype)
        return spec_lib.init_spectral_stream_cache(cfg, batch, dtype)
    raise ValueError(f"unknown block kind {kind!r}")


def block_decode(params, x, cache, t, *, kind: str, cfg, mrope_positions=None):
    h = rms_norm(params["norm1"], x, eps=cfg.norm_eps)
    if kind in ATTN_KINDS:
        res, cache = attn_lib.attn_decode(
            params["mixer"],
            h,
            cache,
            t,
            cfg=cfg,
            window=_window(kind, cfg),
            mrope_positions=mrope_positions,
        )
        x = x + res
        h2 = rms_norm(params["norm2"], x, eps=cfg.norm_eps)
        if kind == "moe":
            y, _ = moe_apply(params["moe"], h2, cfg=cfg)
        else:
            y = mlp_apply(params["mlp"], h2, act=cfg.act)
        return x + y, cache
    if kind == "mamba2":
        res, cache = ssm_lib.mamba2_decode(params["mixer"], h, cache, cfg=cfg)
        return x + res, cache
    if kind == "mlstm":
        res, cache = xlstm_lib.mlstm_decode(params["mixer"], h, cache, cfg=cfg)
        return x + res, cache
    if kind == "slstm":
        res, cache = xlstm_lib.slstm_decode(params["mixer"], h, cache, cfg=cfg)
        x = x + res
        h2 = rms_norm(params["norm2"], x, eps=cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h2, act=cfg.act), cache
    if kind == "spectral":
        # dispatch on the cache layout, not cfg: prepared caches may come
        # from either mode and both must decode (ring is the oracle path).
        if isinstance(cache, spec_lib.SpectralStreamCache):
            res, cache = spec_lib.spectral_stream_decode(
                params["mixer"], h, cache, cfg=cfg
            )
        else:
            res, cache = spec_lib.spectral_decode(params["mixer"], h, cache, cfg=cfg)
        x = x + res
        h2 = rms_norm(params["norm2"], x, eps=cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h2, act=cfg.act), cache
    raise ValueError(f"unknown block kind {kind!r}")
