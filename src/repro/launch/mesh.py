"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init and only
then calls it.
"""

from __future__ import annotations

import jax

from repro.configs.base import ParallelConfig

__all__ = ["make_production_mesh", "parallel_config_for", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (4, 2) on 8 CPU devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def parallel_config_for(mesh, *, fsdp: bool = False, sequence_parallel: bool = False) -> ParallelConfig:
    axis_names = mesh.axis_names
    return ParallelConfig(
        data_axis="data" if "data" in axis_names else axis_names[0],
        model_axis="model" if "model" in axis_names else axis_names[-1],
        pod_axis="pod" if "pod" in axis_names else None,
        fsdp=fsdp,
        sequence_parallel=sequence_parallel,
    )
