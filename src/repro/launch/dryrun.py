import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the production mesh, the sharded train /
prefill / decode function, lowers it against ShapeDtypeStruct inputs (zero
allocation), compiles, and records:

  * memory_analysis()       → per-chip bytes (proves it fits 16 GB HBM)
  * cost_analysis()         → per-chip FLOPs / bytes (roofline C and M terms)
  * HLO collective parse    → per-chip collective bytes (roofline X term)

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs import specs as specs_lib
from repro.configs.base import (
    LM_SHAPES,
    TrainConfig,
    get_config,
    list_archs,
    shapes_for,
)
from repro.launch import shardings as sh_lib
from repro.launch.mesh import make_production_mesh, parallel_config_for
from repro.models import model as model_lib
from repro.sharding.logical import mesh_context
from repro.train.train_loop import make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

FSDP_THRESHOLD = 2_000_000_000  # params; above this shard params over data too


def train_cfg_for(arch: str) -> TrainConfig:
    # adafactor for the 480B MoE (Adam moments would not fit); adamw elsewhere.
    # microbatches=8: global batch 256 → 2 sequences per chip per microbatch;
    # bounds live activations (measured: 31.7 GB → 9.0 GB on h2o train_4k)
    # and is what enables the DP-overlap of reduce-scatter with compute.
    opt = "adafactor" if arch == "arctic-480b" else "adamw"
    mb = 16 if arch in ("arctic-480b", "qwen2-vl-72b") else 8
    return TrainConfig(optimizer=opt, microbatches=mb)


def _mesh_and_par(cfg, multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = sh_lib.param_count(cfg) > FSDP_THRESHOLD
    par = parallel_config_for(mesh, fsdp=fsdp, sequence_parallel=True)
    return mesh, par


def _lower_train(cfg, shape, mesh, par, arch):
    tc = train_cfg_for(arch)
    state_sds = sh_lib.abstract_train_state(cfg, tc)
    state_sh = sh_lib.train_state_shardings(cfg, tc, mesh, par)
    batch_sds = specs_lib.input_specs(cfg, shape)
    batch_sh = sh_lib.batch_shardings(cfg, shape, mesh, par, batch_sds)
    metrics_sh = {
        k: NamedSharding(mesh, P())
        for k in ("loss", "ce", "aux", "tokens", "grad_norm", "lr")
    }
    step = make_train_step(cfg, tc)

    def wrapped(state, batch):
        with mesh_context(mesh, par):
            return step(state, batch)

    fn = jax.jit(
        wrapped,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
    )
    return fn.lower(state_sds, batch_sds)


def _lower_prefill(cfg, shape, mesh, par):
    batch_sds = specs_lib.input_specs(cfg, shape)
    batch_sh = sh_lib.batch_shardings(cfg, shape, mesh, par, batch_sds)
    params_sds, axes = sh_lib.abstract_params(cfg)
    from repro.sharding.partition import param_shardings

    params_sh = param_shardings(axes, params_sds, mesh, par)

    def wrapped(params, batch):
        with mesh_context(mesh, par):
            return model_lib.prefill(params, batch, cfg)

    # Explicit output shardings: without them XLA may replicate the (large)
    # prefill caches across the mesh.
    out_sds = jax.eval_shape(wrapped, params_sds, batch_sds)
    logits_sh = sh_lib.batch_shardings(cfg, shape, mesh, par, out_sds[0])
    caches_sh = sh_lib.cache_shardings(cfg, mesh, par, out_sds[1])
    fn = jax.jit(
        wrapped, in_shardings=(params_sh, batch_sh), out_shardings=(logits_sh, caches_sh)
    )
    return fn.lower(params_sds, batch_sds)


DECODE_CACHE_MODE = {
    # measured per arch (§Perf hillclimb 2): 'carry' aliases the cache in
    # place but reshards per layer when its sharding conflicts with use;
    # 'ys' double-buffers but never reshards.
    "yi-6b": "ys",
    "phi4-mini-3.8b": "ys",
    "gemma3-12b": "ys",
    "h2o-danube-1.8b": "ys",
}


def _lower_decode(cfg, shape, mesh, par):
    cfg = dataclasses.replace(
        cfg, decode_cache_mode=DECODE_CACHE_MODE.get(cfg.name, "carry")
    )
    params_sds, axes = sh_lib.abstract_params(cfg)
    from repro.sharding.partition import param_shardings

    # Weight-stationary decode for FSDP models (§Perf hillclimb 2): weights
    # keep their 2-D (data × model) sharding; the one-token activations are
    # replicated over data so no weight all-gathers are emitted.  The KV
    # cache keeps the regular batch/SP sharding (computed with `par`).
    par_act = dataclasses.replace(par, decode_weight_stationary=par.fsdp)
    params_sh = param_shardings(axes, params_sds, mesh, par_act)
    tok_sds, cache_sds, t_sds = specs_lib.decode_state_specs(cfg, shape)
    cache_sh = sh_lib.cache_shardings(cfg, mesh, par, cache_sds)
    tok_sh = sh_lib.batch_shardings(cfg, shape, mesh, par_act, tok_sds)

    def wrapped(params, tokens, caches, t):
        with mesh_context(mesh, par_act):
            return model_lib.decode_step(params, tokens, caches, t, cfg)

    out_sds = jax.eval_shape(wrapped, params_sds, tok_sds, cache_sds, t_sds)
    logits_sh = sh_lib.batch_shardings(cfg, shape, mesh, par_act, out_sds[0])
    fn = jax.jit(
        wrapped,
        in_shardings=(params_sh, tok_sh, cache_sh, NamedSharding(mesh, P())),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(2,),
    )
    return fn.lower(params_sds, tok_sds, cache_sds, t_sds)


# ---------------------------------------------------------------------------
# fftbench cells: distributed FFT lowerings (the paper's own workload)
# ---------------------------------------------------------------------------


def _fft_plan_info(fft_shape, model_n: int) -> dict:
    """Plan metadata recorded alongside the lowering, with modeled HBM bytes
    per pass so the round-trip count is observable in every artifact, not
    just asserted by tests.  1-D pencil cells record the per-leaf pass
    programs (one plan per pencil factor); 2-D cells record the ONE joint
    rows+columns program ``pfft2d`` now splits around its all-to-alls.
    Each leaf also carries the GPU-shaped account (``gpu_reports``): per-pass
    shared-memory bytes against the device budget and global-memory round
    trips under the ``pallas_gpu`` claim set, so the pallas↔xla crossover is
    auditable from the artifact alone."""
    from repro.core import distributed as dist
    from repro.core import plan as plan_lib
    from repro.kernels.fft_gpu import gpu_claims

    def _gpu_report(m: int, batch: int) -> dict:
        rep = rl.gpu_program_report(
            plan_lib.plan_fft(m).passes, gpu_claims, batch=batch
        )
        return {
            k: rep[k]
            for k in (
                "global_round_trips",
                "smem_bytes_max",
                "smem_budget",
                "modeled_global_bytes",
                "claims",
            )
        }

    def _bluestein_reports(lengths, batch: int) -> list:
        # Non-pow2 leaves route through the Bluestein chirp-conv program;
        # record its pad/flops overhead vs the hypothetical mixed-radix
        # transform so the tax is observable in the artifact.
        return [
            rl.bluestein_report(m, batch=batch) for m in lengths if m & (m - 1)
        ]

    if fft_shape.kind == "fft2d":
        # (batch, n1, n2) images: last axis n2 rows-first, columns n1.
        n_row, n_col = fft_shape.n2, fft_shape.n
        info = {
            "leaf_lengths": [n_col, n_row],
            "joint_schedule": plan_lib.describe(n_row, n2=n_col),
            "hbm_round_trips": plan_lib.plan_fft2(n_row, n_col).hbm_round_trips,
            "pass_programs": [
                rl.fft_pass_report(n_row, batch=fft_shape.batch, n2=n_col)
            ],
            "gpu_reports": [_gpu_report(n_row, fft_shape.batch * n_col)],
        }
        blu = _bluestein_reports([n_row], fft_shape.batch * n_col)
        if blu:
            info["bluestein_reports"] = blu
        return info
    # The tuned pencil schedule the driver will actually run: modeled-only
    # (`tuning.pencil_config`), so the dry-run host derives the same factors
    # / packing / chunk count as every SPMD host of the real mesh.
    ppl = dist.plan_pencil(fft_shape.n, model_n)
    leaf_ns = [ppl.n1, ppl.n2]
    total = fft_shape.n
    # Schedule facts only — backend negotiation on the dry-run host (CPU)
    # would misstate what the production TPU pencil driver picks.
    info = {
        "leaf_lengths": leaf_ns,
        "leaf_schedules": [plan_lib.describe(m) for m in leaf_ns],
        "pencil_schedule": ppl.describe(),
        "a2a_count": ppl.a2a_count(fft_shape.kind != "fftconv"),
        "comm_report": {
            k: ppl.report[k]
            for k in ("comm_bytes_per_step", "local_hbm_bytes", "modeled_s")
        },
        "hbm_round_trips": max(
            plan_lib.plan_fft(m).hbm_round_trips for m in leaf_ns
        ),
        # A length-m leaf runs over batch × (total/m) pencils — charge the
        # full global pencil count or the modeled bytes understate the real
        # traffic by total/m (the figure bench_table1 reports would disagree).
        "pass_programs": [
            rl.fft_pass_report(m, batch=fft_shape.batch * (total // m))
            for m in leaf_ns
        ],
        "gpu_reports": [
            _gpu_report(m, fft_shape.batch * (total // m)) for m in leaf_ns
        ],
    }
    if fft_shape.kind == "fftconv":
        # One-shot vs overlap-save modeled bytes at a canonical 4k-tap
        # filter, so every conv artifact shows the schedule the single-chip
        # path would pick and what the blocked alternative costs.
        info["conv_report"] = rl.conv_report(
            fft_shape.n, 4097, batch=fft_shape.batch
        )
    blu = _bluestein_reports(
        leaf_ns, fft_shape.batch * (total // max(leaf_ns))
    )
    if blu:
        info["bluestein_reports"] = blu
    return info


def _lower_fft(fft_shape, mesh, par):
    from repro.core import distributed as dist

    batch_axes = ("pod", "data") if par.pod_axis else ("data",)
    model_n = mesh.shape["model"]

    if fft_shape.kind == "fft1d":
        n = fft_shape.n
        spec = P(batch_axes, "model")
        x_sds = jax.ShapeDtypeStruct((fft_shape.batch, n), jnp.float32)

        def body(xr, xi):
            return dist.pfft(
                xr, xi, n=n, axis_name="model", num_shards=model_n
            )

        fn = dist.shard_map_compat(
            body, mesh, in_specs=(spec, spec), out_specs=(spec, spec)
        )
        jfn = jax.jit(fn, in_shardings=(NamedSharding(mesh, spec),) * 2)
        return jfn.lower(x_sds, x_sds)

    if fft_shape.kind == "fft2d":
        n1, n2 = fft_shape.n, fft_shape.n2
        spec = P(batch_axes, "model", None)
        x_sds = jax.ShapeDtypeStruct((fft_shape.batch, n1, n2), jnp.float32)

        def body2(xr, xi):
            return dist.pfft2d(
                xr, xi, n1=n1, n2=n2, axis_name="model", num_shards=model_n
            )

        fn = dist.shard_map_compat(
            body2, mesh, in_specs=(spec, spec), out_specs=(spec, spec)
        )
        jfn = jax.jit(fn, in_shardings=(NamedSharding(mesh, spec),) * 2)
        return jfn.lower(x_sds, x_sds)

    if fft_shape.kind == "fftconv":
        n = fft_shape.n
        spec = P(batch_axes, "model")
        hspec = P("model")
        x_sds = jax.ShapeDtypeStruct((fft_shape.batch, n), jnp.float32)
        h_sds = jax.ShapeDtypeStruct((n,), jnp.float32)

        def bodyc(xr, xi, hr, hi):
            # forward in pencil layout, multiply, inverse from pencil:
            # 4 all-to-alls total instead of 6 (beyond-paper optimisation).
            yr, yi = dist.pfft(
                xr, xi, n=n, axis_name="model", num_shards=model_n,
                natural_order=False,
            )
            pr = yr * hr - yi * hi
            pi = yr * hi + yi * hr
            return dist.pifft(
                pr, pi, n=n, axis_name="model", num_shards=model_n,
                from_pencil=True,
            )

        fn = dist.shard_map_compat(
            bodyc, mesh, in_specs=(spec, spec, hspec, hspec),
            out_specs=(spec, spec),
        )
        jfn = jax.jit(
            fn,
            in_shardings=(NamedSharding(mesh, spec),) * 2
            + (NamedSharding(mesh, hspec),) * 2,
        )
        return jfn.lower(x_sds, x_sds, h_sds, h_sds)

    raise ValueError(fft_shape.kind)


# ---------------------------------------------------------------------------


def active_params(cfg) -> int:
    """Active params per token (MoE: top-k + shared experts only)."""
    total = sh_lib.param_count(cfg)
    if cfg.num_experts and cfg.top_k:
        values, _ = sh_lib.abstract_params(cfg)
        import jax as _jax

        expert = 0
        flat = _jax.tree_util.tree_flatten_with_path(values)[0]
        for path, leaf in flat:
            keys = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
            # stacked-over-layers expert weights are rank 4: (L, E, D, F)
            is_expert = cfg.num_experts in leaf.shape[:2] and leaf.ndim in (3, 4)
            if "moe" in keys and any(k in keys for k in ("wi_gate", "wi_up", "wo")) and is_expert:
                expert += int(leaf.size)
        active = total - expert + expert * cfg.top_k // cfg.num_experts
        return active
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False) -> dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    os.makedirs(ART_DIR, exist_ok=True)
    out_path = os.path.join(ART_DIR, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    t0 = time.time()
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "status": "error",
    }
    try:
        if cfg.family == "fft":
            import repro.configs.fftbench as fb

            fft_shape = next(s for s in fb.FFT_SHAPES if s.name == shape_name)
            mesh = make_production_mesh(multi_pod=multi_pod)
            par = parallel_config_for(mesh)
            lowered = _lower_fft(fft_shape, mesh, par)
            record["fft_plan"] = _fft_plan_info(fft_shape, mesh.shape["model"])
            tokens = 0
            n_active = 0
            dtype = "f32"
        else:
            shape = LM_SHAPES[shape_name]
            mesh, par = _mesh_and_par(cfg, multi_pod)
            if shape.kind == "train":
                lowered = _lower_train(cfg, shape, mesh, par, arch)
                tokens = shape.global_batch * shape.seq_len
            elif shape.kind == "prefill":
                lowered = _lower_prefill(cfg, shape, mesh, par)
                tokens = shape.global_batch * shape.seq_len
            else:
                lowered = _lower_decode(cfg, shape, mesh, par)
                tokens = shape.global_batch  # one new token per sequence
                if "spectral" in cfg.pattern():
                    # streaming-conv decode plan: chunk/block grain, flush
                    # cadence and per-flush HBM traffic of the spectral state
                    from repro.models.layers import spectral as spec_lib

                    record["spectral_stream"] = spec_lib.stream_plan_info(
                        cfg, batch=shape.global_batch
                    )
            n_active = active_params(cfg)
            dtype = "bf16"

        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        # Loop-aware costs from our own HLO walk (XLA's cost_analysis counts
        # while bodies once — verified; see analysis/hlo.py).
        from repro.analysis.hlo import analyze as hlo_analyze

        hc = hlo_analyze(hlo)
        coll = {
            "per_device_bytes": hc.collective_bytes,
            "by_type": hc.collective_by_type,
            "num_ops": hc.collective_ops,
            "unknown_trip_loops": hc.unknown_trip_loops,
        }
        flops = float(hc.flops)
        bytes_acc = float(hc.bytes)
        n_chips = mesh.devices.size

        peak_mem = (
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        )
        terms = rl.roofline_terms(
            flops, bytes_acc, coll["per_device_bytes"], dtype=dtype
        )
        # MODEL_FLOPS: 6·N·D for a train step (fwd+bwd), 2·N·D fwd-only.
        useful = 0.0
        if cfg.family != "fft":
            per_tok = 6 if LM_SHAPES[shape_name].kind == "train" else 2
            useful = float(per_tok) * n_active * tokens
        record.update(
            status="ok",
            compile_s=round(t_compile, 1),
            chips=int(n_chips),
            per_chip=dict(
                flops=flops,
                dot_flops=float(hc.dot_flops),
                hbm_bytes=bytes_acc,
                collective_bytes=coll["per_device_bytes"],
                collective_by_type=coll["by_type"],
                collective_ops=coll["num_ops"],
                unknown_trip_loops=coll["unknown_trip_loops"],
                xla_cost_flops=float(ca.get("flops", 0.0)),
                xla_bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                peak_memory_bytes=int(peak_mem),
                argument_bytes=int(ma.argument_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                aliased_bytes=int(ma.alias_size_in_bytes),
            ),
            fits_hbm=bool(peak_mem < rl.V5E.hbm_bytes),
            roofline=terms,
            useful_flops=useful,
            useful_flops_frac=(useful / n_chips) / flops if flops else 0.0,
            active_params=n_active,
            tokens_per_step=tokens,
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def all_cells(include_fft=True):
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.family == "fft":
            if include_fft:
                import repro.configs.fftbench as fb

                cells += [(arch, s.name) for s in fb.FFT_SHAPES]
            continue
        cells += [(arch, s.name) for s in shapes_for(arch)]
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-fft", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = all_cells(include_fft=not args.no_fft)
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            rec = run_cell(arch, shape_name, mp, force=args.force)
            if rec["status"] == "ok":
                t = rec["roofline"]
                print(
                    f"OK   {arch:18s} {shape_name:12s} {rec['mesh']:8s} "
                    f"compile={rec['compile_s']:6.1f}s "
                    f"C={t['compute_s']*1e3:8.2f}ms M={t['memory_s']*1e3:8.2f}ms "
                    f"X={t['collective_s']*1e3:8.2f}ms bound={t['bound']:10s} "
                    f"mem={rec['per_chip']['peak_memory_bytes']/1e9:5.2f}GB "
                    f"fits={rec['fits_hbm']}",
                    flush=True,
                )
            else:
                failures += 1
                print(f"FAIL {arch:18s} {shape_name:12s} mp={mp}: {rec['error']}", flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
