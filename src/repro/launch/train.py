"""Training launcher: mesh setup, sharded state, checkpoint/restart loop.

The real-cluster entrypoint (works identically on CPU for small configs):

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
      --reduced --steps 200 --mesh 1x1 --ckpt-dir /tmp/run1

Fault-tolerance behaviour exercised here:
  * auto-resume from the newest complete checkpoint (elastic: the stored
    arrays are topology-free, restore re-shards onto the current mesh);
  * async checkpointing every --ckpt-every steps, keep-N garbage collection;
  * a step watchdog that snapshots + aborts on hangs (crash-only restart);
  * straggler stats (EWMA step times) reported at the end.

XLA flags for compute/comm overlap on real TPU pods are set below (no-ops
on CPU): latency-hiding scheduler + async collectives.
"""

from __future__ import annotations

import argparse
import os
import time

# Overlap flags must be set before jax initializes XLA.
_overlap_flags = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
)
if "--dry-overlap-flags" in os.sys.argv:  # documented, applied on TPU only
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + _overlap_flags

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig, get_config
from repro.configs.reduce import make_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import shardings as sh_lib
from repro.launch.mesh import make_mesh, parallel_config_for
from repro.runtime.fault_tolerance import StepWatchdog, StragglerStats, with_retries
from repro.sharding.logical import mesh_context
from repro.train.train_loop import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--stop-at", type=int, default=None,
                    help="stop (simulate a crash) after this step; schedule still spans --steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--watchdog-timeout", type=float, default=600.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)

    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model"))
    par = parallel_config_for(mesh)

    tc = TrainConfig(
        optimizer=args.optimizer,
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(1, args.steps // 20),
        batch_size=args.batch,
        seq_len=args.seq,
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)

    # ---- state: init or elastic resume ---------------------------------
    state_sh = sh_lib.train_state_shardings(cfg, tc, mesh, par)
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    state = None
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            like = sh_lib.abstract_train_state(cfg, tc)
            state, extra = mgr.restore(latest, like, shardings=state_sh)
            start_step = int(extra.get("data_step", latest))
            print(f"[resume] restored step {latest} onto mesh {dshape} "
                  f"({mesh.devices.size} devices)")
    if state is None:
        state = init_train_state(jax.random.PRNGKey(tc.seed), cfg, tc)
        state = jax.device_put(state, state_sh)

    data = SyntheticLM(dcfg, start_step=start_step)

    step_raw = make_train_step(cfg, tc)

    def stepper(s, b):
        with mesh_context(mesh, par):
            return step_raw(s, b)

    step_fn = jax.jit(stepper, in_shardings=(state_sh, None), out_shardings=(state_sh, None), donate_argnums=(0,))

    # ---- loop with watchdog / straggler tracking ------------------------
    def on_hang():
        print("[watchdog] step exceeded timeout — aborting for supervisor restart")
        os._exit(17)

    watchdog = StepWatchdog(args.watchdog_timeout, on_hang)
    stats = StragglerStats()
    losses = []
    stop = min(args.steps, args.stop_at) if args.stop_at else args.steps
    for i in range(start_step, stop):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        watchdog.arm()
        t0 = time.time()
        state, metrics = with_retries(lambda: step_fn(state, batch))
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        watchdog.disarm()
        slow = stats.record(dt)
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(
                f"step {i:5d} loss={losses[-1]:.4f} ce={float(metrics['ce']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} lr={float(metrics['lr']):.2e} "
                f"dt={dt*1e3:.0f}ms{' [straggler]' if slow else ''}",
                flush=True,
            )
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state, extra={"data_step": i + 1}, blocking=False)
    if mgr is not None:
        mgr.save(stop, state, extra={"data_step": stop}, blocking=True)
        mgr.wait()
    watchdog.close()
    print("final:", {"loss_first": losses[0], "loss_last": losses[-1], **stats.summary()})
    return losses


if __name__ == "__main__":
    main()
