"""Serving launcher: batched decode on a mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
      --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.configs.reduce import make_reduced
from repro.models import model as model_lib
from repro.serving.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    params, _ = model_lib.init_unzipped(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_new=args.max_new, temperature=args.temperature))

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 4, cfg.vocab_size
    )
    t0 = time.time()
    out = eng.generate(prompts)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s = {toks/dt:.1f} tok/s")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
