"""Serving launcher: phase-timed batched decode sweeps.

Shares its measurement path (:func:`repro.serving.spectral_serve.sweep_once`)
with ``benchmarks/bench_serve.py``, so the CLI's numbers and the benchmark's
numbers are the same numbers.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
      --batch 4 --prompt-len 32 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --reduced --batch 8 --prompt-len 32,128,512 --phase-times
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config
from repro.core import faults
from repro.configs.reduce import make_reduced
from repro.models import model as model_lib
from repro.serving.engine import Engine, ServeConfig
from repro.serving.spectral_serve import sweep_once


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument(
        "--prompt-len",
        default="32",
        help="prompt length, or a comma-separated sweep (e.g. 32,128,512)",
    )
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument(
        "--phase-times",
        action="store_true",
        help="print per-phase seconds (prefill / insert / generate) per row",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    params, _ = model_lib.init_unzipped(jax.random.PRNGKey(0), cfg)
    engine = Engine(
        cfg,
        params,
        ServeConfig(
            max_new=args.max_new,
            temperature=args.temperature,
            top_k=args.top_k,
            top_p=args.top_p,
            seed=args.seed,
        ),
    )

    rows = []
    for plen in (int(p) for p in str(args.prompt_len).split(",")):
        r = sweep_once(
            engine,
            batch=args.batch,
            prompt_len=plen,
            max_new=args.max_new,
            warmup=args.warmup,
            seed=args.seed,
        )
        rows.append(r)
        line = (
            f"batch={r['batch']} prompt={r['prompt_len']} max_new={r['max_new']} "
            f"decode={r['decode_tok_per_s']} tok/s e2e={r['e2e_tok_per_s']} tok/s"
        )
        if args.phase_times:
            line += (
                f"  [prefill {r['prefill_s']:.4f}s ({r['prefill_s_per_req']:.4f}/req)"
                f" insert {r['insert_s']:.4f}s generate {r['generate_s']:.4f}s]"
            )
        print(line)
    fired = faults.fault_counters()
    if fired or faults.quarantined():
        # Chaos-drill visibility: injected sites that fired (REPRO_FAULTS)
        # and kernels demoted to their XLA fallback this process.
        print(
            "faults: "
            + (
                " ".join(f"{site}x{n}" for site, n in sorted(fired.items()))
                or "none"
            )
            + f"; quarantined={list(faults.quarantined())}"
            + f"; degradations={len(faults.degradation_log())}"
        )
    return rows


if __name__ == "__main__":
    main()
