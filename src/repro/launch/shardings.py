"""Sharding trees for train/serve state — what the dry-run lowers against.

Builds NamedSharding pytrees for: parameters (from logical axes), optimizer
state (AdamW moments mirror params; Adafactor's factored stats drop the
corresponding dims), gradient-compression error state, batches, and
decode caches (rule-based per cache type, with SP fallback for long-context
KV when the batch axis can't be sharded)."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig, TrainConfig
from repro.models import model as model_lib
from repro.models.layers.attention import KVCache
from repro.models.layers.spectral import SpectralCache
from repro.models.layers.ssm import SSMCache
from repro.models.layers.xlstm import MLSTMCache, SLSTMCache
from repro.sharding.partition import spec_for_shape
from repro.train.optimizer import OptState
from repro.train.train_loop import TrainState, init_train_state
from repro.utils.params import unzip

__all__ = [
    "abstract_params",
    "param_count",
    "train_state_shardings",
    "abstract_train_state",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def replicated(mesh, tree):
    return jax.tree.map(lambda _: _ns(mesh, P()), tree)


@functools.lru_cache(maxsize=32)
def _abstract_cached(cfg: ModelConfig):
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    ptree = jax.eval_shape(
        lambda k: model_lib.init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    return unzip(ptree)


def abstract_params(cfg: ModelConfig):
    """(values_SDS, axes) without allocating anything."""
    return _abstract_cached(cfg)


def param_count(cfg: ModelConfig) -> int:
    values, _ = abstract_params(cfg)
    return sum(int(x.size) for x in jax.tree.leaves(values))


def _param_spec_tree(cfg, mesh, par):
    values, axes = abstract_params(cfg)
    return jax.tree.map(
        lambda ax, v: spec_for_shape(tuple(ax), tuple(v.shape), mesh, par),
        axes,
        values,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def _opt_spec_tree(cfg, train_cfg, mesh, par):
    values, axes = abstract_params(cfg)
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    if train_cfg.optimizer == "sgd":
        return ()
    if train_cfg.optimizer == "adamw":
        # optimizer.inner = {"m": <params tree>, "v": <params tree>}
        pspecs = _param_spec_tree(cfg, mesh, par)
        return {"m": pspecs, "v": pspecs}

    # adafactor: per-leaf {"vr","vc"} (factored) or {"v"}.
    def leaf_axes(ax, v):
        ax = tuple(ax)
        shape = tuple(v.shape)
        if len(shape) >= 2:
            return {
                "vr": spec_for_shape(ax[:-1], shape[:-1], mesh, par),
                "vc": spec_for_shape(ax[:-2] + ax[-1:], shape[:-2] + shape[-1:], mesh, par),
            }
        return {"v": spec_for_shape(ax, shape, mesh, par)}

    return jax.tree.map(leaf_axes, axes, values, is_leaf=is_axes)


def abstract_train_state(cfg, train_cfg) -> TrainState:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, train_cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def train_state_shardings(cfg, train_cfg, mesh: Mesh, par: ParallelConfig) -> TrainState:
    pspecs = _param_spec_tree(cfg, mesh, par)
    to_ns = lambda tree: jax.tree.map(
        lambda s: _ns(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    params_sh = to_ns(pspecs)
    opt_sh = OptState(step=_ns(mesh, P()), inner=to_ns(_opt_spec_tree(cfg, train_cfg, mesh, par)))
    err_sh = params_sh if train_cfg.grad_compression else ()
    return TrainState(
        step=_ns(mesh, P()), params=params_sh, opt_state=opt_sh, err_state=err_sh
    )


def batch_shardings(cfg, shape: ShapeConfig, mesh: Mesh, par: ParallelConfig, batch_tree):
    """Batch dim over ('pod','data') where divisible; trailing dims replicated
    (seq stays unsharded for train — activations re-shard internally)."""
    rules_batch = (par.pod_axis, par.data_axis) if par.pod_axis else (par.data_axis,)

    def one(x):
        if x.ndim == 0:
            return _ns(mesh, P())
        axes = ("batch",) + (None,) * (x.ndim - 1)
        return _ns(mesh, spec_for_shape(axes, tuple(x.shape), mesh, par))

    return jax.tree.map(one, batch_tree)


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        return mesh.shape[names]
    import numpy as np

    return int(np.prod([mesh.shape[n] for n in names]))


def cache_shardings(cfg, mesh: Mesh, par: ParallelConfig, caches_sds):
    """Decode-cache shardings.  Batch over data axes when divisible; long
    sequence axes fall back to SP over (data[, model]); heads over model."""
    batch_axes = (par.pod_axis, par.data_axis) if par.pod_axis else (par.data_axis,)
    batch_axes = tuple(a for a in batch_axes if a)
    model_ax = par.model_axis

    def div(n, names):
        return n % _axis_size(mesh, names) == 0

    def kv_spec(leaf):
        if len(leaf.shape) == 4:  # int8 scale planes (R, B, S, KV)
            r, b, s, kv = leaf.shape
            b_sh = batch_axes if div(b, batch_axes) else None
            if kv % mesh.shape[model_ax] == 0:
                return P(None, b_sh, None, model_ax)
            return P(None, b_sh, None, None)
        r, b, s, kv, hd = leaf.shape
        b_sh = batch_axes if div(b, batch_axes) else None
        used_data = b_sh is not None
        if kv % mesh.shape[model_ax] == 0:
            return P(None, b_sh, None, model_ax, None)
        # Preferred fallback: shard head_dim over model — the decode
        # dynamic-update-slice stays local (writing one slot of a
        # *seq*-sharded cache forces SPMD to rematerialise the whole cache
        # every layer: measured 142 GB/chip/step on yi-6b decode_32k) and
        # the score/PV reductions over hd are small all-reduces.
        if used_data and hd % mesh.shape[model_ax] == 0:
            return P(None, b_sh, None, None, model_ax)
        # Last resort (e.g. batch=1 long-context): SP over the seq axis.
        seq_axes = (model_ax,) if used_data else batch_axes + (model_ax,)
        seq_axes = tuple(a for a in seq_axes if a)
        while seq_axes and not div(s, seq_axes):
            seq_axes = seq_axes[1:]
        if seq_axes:
            return P(None, b_sh, seq_axes if len(seq_axes) > 1 else seq_axes[0], None, None)
        return P(None, b_sh, None, None, None)

    def generic_spec(leaf):
        # (R, B, ...) recurrent states: batch over data, widest trailing dim
        # over model when divisible.
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if len(shape) == 1:
            return P(None)
        b_sh = batch_axes if div(shape[1], batch_axes) else None
        entries = [None, b_sh] + [None] * (len(shape) - 2)
        # pick the largest trailing dim divisible by the model axis
        best, best_dim = None, 0
        for i in range(2, len(shape)):
            if shape[i] % mesh.shape[model_ax] == 0 and shape[i] > best_dim:
                best, best_dim = i, shape[i]
        if best is not None:
            entries[best] = model_ax
        return P(*entries)

    def one(path, leaf):
        if not hasattr(leaf, "shape"):
            return _ns(mesh, P())
        names = {getattr(p, "name", None) for p in path}
        if names & {"k", "v", "k_scale", "v_scale"}:
            return _ns(mesh, kv_spec(leaf))
        return _ns(mesh, generic_spec(leaf))

    return jax.tree_util.tree_map_with_path(one, caches_sds)
