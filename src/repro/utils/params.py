"""Param-with-logical-axes utilities.

Every layer ``init`` returns a pytree whose leaves are :class:`Param` —
a value plus the tuple of *logical* axis names that
``repro.sharding.logical`` later maps to mesh ``PartitionSpec``s.  Keeping
value and axes in one leaf means the sharding metadata can never drift out
of sync with the parameter structure (single source of truth).

``Param`` is registered as a pytree node whose only child is ``value`` and
whose ``axes`` ride along as static aux data — so ``jax.vmap`` over an init
function stacks values while preserving axes (the stack layer then prepends
the 'layers' logical axis explicitly).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Param",
    "unzip",
    "normal",
    "zeros",
    "ones",
    "count_params",
    "map_params",
]


class Param:
    """A parameter value + logical axis names (pytree node, axes static)."""

    __slots__ = ("value", "axes")

    def __init__(self, value: Any, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def map_params(fn, tree):
    """tree_map over Param leaves (passes non-Param leaves through)."""
    return jax.tree.map(
        lambda p: fn(p) if isinstance(p, Param) else p, tree, is_leaf=_is_param
    )


def unzip(tree):
    """Split a Param tree into (values, axes) trees of identical structure.

    Plain (non-Param) array leaves are treated as fully replicated.
    """

    def _val(p):
        return p.value if isinstance(p, Param) else p

    def _ax(p):
        if isinstance(p, Param):
            # Stacking (vmap/scan) adds *leading* dims; pad axes at the front
            # so trailing logical names stay aligned with their dims.
            nd = jnp.ndim(p.value)
            ax = tuple(p.axes)
            if len(ax) < nd:
                ax = (None,) * (nd - len(ax)) + ax
            elif len(ax) > nd:
                ax = ax[-nd:]
            return ax
        return (None,) * jnp.ndim(p)

    values = jax.tree.map(_val, tree, is_leaf=_is_param)
    axes = jax.tree.map(_ax, tree, is_leaf=_is_param)
    return values, axes


def normal(key, shape, axes, *, scale=None, dtype=jnp.float32) -> Param:
    if scale is None:
        # fan-in scaling on the first axis (embed/in dim by convention).
        scale = shape[0] ** -0.5
    v = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return Param(v.astype(dtype), tuple(axes))


def zeros(shape, axes, *, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), tuple(axes))


def ones(shape, axes, *, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), tuple(axes))


def count_params(values_tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(values_tree))
