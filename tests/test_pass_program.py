"""The linearized pass program: schedule purity, pencil kernels, epilogues.

The split regime's acceptance criterion (paper §2.3.2 made literal): the
executed schedule is exactly ``len(plan.passes)`` pallas_call round trips
with zero standalone HBM transpose / twiddle-cmul ops between them — glue
lives inside the kernels.  Asserted over the jaxpr, plus numerical
acceptance of the executor and the individual pass kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.core import fft as F
from repro.core import plan as P
from repro.core import twiddle as tw
from repro.kernels import ops, pencil


def _rand(rng, shape):
    return (
        rng.standard_normal(shape).astype(np.float32),
        rng.standard_normal(shape).astype(np.float32),
    )


# ---------------------------------------------------------------------------
# schedule purity: pallas_call round trips only, no HBM glue between them
# ---------------------------------------------------------------------------


def _top_level_primitives(n):
    plan = P.plan_fft(n)

    def run(xr, xi):
        return ops.execute_plan(xr, xi, plan, interpret=True)

    xr = jnp.zeros((1, n), jnp.float32)
    jaxpr = jax.make_jaxpr(run)(xr, xr).jaxpr
    return [e.primitive.name for e in jaxpr.eqns], plan


@pytest.mark.parametrize("n", [2**17, 2**18])
def test_schedule_is_pure_pass_program(n):
    prims, plan = _top_level_primitives(n)
    kernel_calls = prims.count("pallas_call")
    assert kernel_calls == len(plan.passes), (n, prims)
    # Zero standalone HBM relayout or twiddle ops between the kernel calls:
    # the only non-kernel primitives are free row-major reshapes.
    forbidden = {"transpose", "mul", "add", "sub", "gather", "dynamic_slice"}
    assert not forbidden & set(prims), prims
    # device_put: the host-cached LUT constants entering the trace.
    assert set(prims) <= {"pallas_call", "reshape", "device_put"}, prims


def test_n18_schedule_beats_paper_call_count():
    # Paper §2.3.2: ≥ 3 global-memory kernel calls beyond 32K.  The fused
    # program covers N = 2¹⁸ in 2 — twiddle and natural-order transpose ride
    # inside the kernels.
    prims, plan = _top_level_primitives(2**18)
    assert prims.count("pallas_call") == plan.hbm_round_trips == 2


# ---------------------------------------------------------------------------
# executor acceptance (split regime) — natural and pencil order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inverse", [False, True])
def test_execute_program_matches_jnp_2e18(inverse, rng):
    n = 2**18
    xr, xi = _rand(rng, (2, n))
    plan = P.plan_fft(n)
    yr, yi = ops.execute_plan(
        jnp.asarray(xr), jnp.asarray(xi), plan, inverse=inverse, interpret=True
    )
    x = xr + 1j * xi
    ref = np.fft.ifft(x) if inverse else np.fft.fft(x)
    err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
    assert err <= 1e-3 * np.abs(ref).max()


def test_pencil_order_is_k1_major_permutation(rng):
    n = 2**17
    f0, f1 = P.program_factors(n)
    xr, xi = _rand(rng, (1, n))
    plan = P.plan_fft(n)
    nat = ops.execute_plan(jnp.asarray(xr), jnp.asarray(xi), plan, interpret=True)
    pen = ops.execute_plan(
        jnp.asarray(xr), jnp.asarray(xi), plan, interpret=True, order="pencil"
    )
    # pencil[k0, k1] holds X[k0 + f0·k1]: transposing recovers natural order.
    for a, b in zip(pen, nat):
        a = np.asarray(a).reshape(1, f0, f1).transpose(0, 2, 1).reshape(1, n)
        np.testing.assert_allclose(a, np.asarray(b), rtol=0, atol=1e-4)


def test_pencil_program_has_no_reorder_and_uniform_views():
    for n in (2**17, 2**18, 2**20):
        passes = P.compile_passes(n, order="pencil")
        assert all(p.kind != "reorder" for p in passes)
        assert all(p.view_in == p.view_out for p in passes)
        assert passes[-1].order == "pencil"


# ---------------------------------------------------------------------------
# pass kernels in isolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("f,s", [(256, 128), (512, 256)])
def test_cols_pass_matches_axis_fft(f, s, rng):
    xr, xi = _rand(rng, (2, f, s))
    wr, wi = tw.dft_matrix(f)
    yr, yi = pencil.cols_pass_call(
        jnp.asarray(xr), jnp.asarray(xi), (wr, wi), kind="direct",
        chunk=s // 2, interpret=True,
    )
    ref = np.fft.fft(xr + 1j * xi, axis=1)
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(yr), ref.real, atol=3e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), ref.imag, atol=3e-4 * scale)


def test_cols_pass_fused4_kind(rng):
    f, s = 2048, 128  # f > DIRECT_MAX → in-VMEM four-step per pencil
    n1, n2 = P.balanced_split(f)
    xr, xi = _rand(rng, (1, f, s))
    w1r, w1i = tw.dft_matrix(n1)
    tr, ti = tw.twiddle_grid(n1, n2)
    w2r, w2i = tw.dft_matrix(n2)
    yr, yi = pencil.cols_pass_call(
        jnp.asarray(xr), jnp.asarray(xi), (w1r, w1i, tr, ti, w2r, w2i),
        kind="fused4", n1=n1, n2=n2, chunk=s, interpret=True,
    )
    ref = np.fft.fft(xr + 1j * xi, axis=1)
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(yr), ref.real, atol=4e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), ref.imag, atol=4e-4 * scale)


def test_cols_pass_twiddle_epilogue(rng):
    f, s = 128, 128
    xr, xi = _rand(rng, (1, f, s))
    wr, wi = tw.dft_matrix(f)
    twr, twi = tw.pass_twiddle(f, s)
    yr, yi = pencil.cols_pass_call(
        jnp.asarray(xr), jnp.asarray(xi), (wr, wi), (twr, twi),
        kind="direct", chunk=64, interpret=True,
    )
    base = np.fft.fft(xr + 1j * xi, axis=1)
    ref = base * (twr + 1j * twi)[None]
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(yr), ref.real, atol=3e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), ref.imag, atol=3e-4 * scale)


def test_rows_natural_fuses_transpose(rng):
    p, f = 64, 256
    xr, xi = _rand(rng, (2, p, f))
    wr, wi = tw.dft_matrix(f)
    yr, yi = pencil.rows_natural_call(
        jnp.asarray(xr), jnp.asarray(xi), (wr, wi), kind="direct",
        chunk=32, interpret=True,
    )
    assert yr.shape == (2, f, p)
    ref = np.fft.fft(xr + 1j * xi, axis=-1).transpose(0, 2, 1)
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(yr), ref.real, atol=3e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), ref.imag, atol=3e-4 * scale)


# ---------------------------------------------------------------------------
# axis=-2 column execution (the distributed pencil driver's pass)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "xla", "stockham"])
def test_axis_minus2_plan_matches_jnp(backend, rng):
    n, q = 512, 128
    xr, xi = _rand(rng, (2, n, q))
    planned = F.plan(F.FFTSpec(n=n, kind="fft", axis=-2), backend=backend)
    yr, yi = planned.apply_planes(jnp.asarray(xr), jnp.asarray(xi))
    ref = np.fft.fft(xr + 1j * xi, axis=-2)
    err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
    assert err <= 1e-3 * np.abs(ref).max(), backend


def test_axis_minus2_pallas_emits_no_transpose():
    n, q = 512, 128
    planned = F.plan(F.FFTSpec(n=n, kind="fft", axis=-2), backend="pallas")
    x = jnp.zeros((1, n, q), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda a, b: planned.apply_planes(a, b))(x, x).jaxpr
    prims = [e.primitive.name for e in jaxpr.eqns]
    assert "transpose" not in prims, prims
    assert prims.count("pallas_call") == 1, prims


# ---------------------------------------------------------------------------
# rfft/irfft recombination as a kernel epilogue pass
# ---------------------------------------------------------------------------


def test_rfft_irfft_pallas_epilogue_pass(rng):
    n = 4096
    x = rng.standard_normal((3, n)).astype(np.float32)
    planned = F.plan(F.FFTSpec(n=n, kind="rfft"), backend="pallas")
    assert planned.epilogue is not None and planned.epilogue.kind == "rfft_recomb"
    Xr, Xi = planned(jnp.asarray(x))
    ref = np.fft.rfft(x)
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(Xr), ref.real, atol=3e-3 * scale)
    np.testing.assert_allclose(np.asarray(Xi), ref.imag, atol=3e-3 * scale)
    inv = F.plan(F.FFTSpec(n=n, kind="irfft"), backend="pallas")
    assert inv.epilogue is not None and inv.epilogue.kind == "irfft_recomb"
    back = inv((Xr, Xi))
    np.testing.assert_allclose(np.asarray(back), x, atol=2e-4)
    # the epilogue is one extra HBM round trip on top of the inner plan
    assert planned.hbm_round_trips == planned.children[0].fft_plan.hbm_round_trips + 1


# ---------------------------------------------------------------------------
# modeled HBM bytes (dryrun/roofline observability)
# ---------------------------------------------------------------------------


def test_fft_pass_report_models_round_trips():
    rep = rl.fft_pass_report(2**18, batch=2)
    assert rep["hbm_round_trips"] == len(rep["passes"]) == 2
    sig = 2 * (2**18) * 2 * 4  # batch · n · split-complex f32
    for entry in rep["passes"]:
        assert entry["hbm_bytes"] >= 2 * sig  # read + write at least
    assert rep["modeled_hbm_bytes"] == sum(e["hbm_bytes"] for e in rep["passes"])
    assert rep["memory_s"] > 0
    # the twiddle grid is charged to the pass that fuses it
    assert rep["passes"][0]["twiddle"] is not None
    assert rep["passes"][1]["twiddle"] is None
