"""Autotuner: spaces, roofline pruning, measurement discipline, cache
persistence/determinism, plan-log ring buffer."""

import collections
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.core import fft as fft_lib
from repro.core import plan as plan_lib
from repro.core import tuning
from repro.core.overlap import fft_conv_os, pick_block


@pytest.fixture
def fresh_cache(tmp_path, monkeypatch):
    """An isolated, empty persistent cache + clean measurement log."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)
    tuning.cache.clear()
    tuning.clear_measure_log()
    yield path
    tuning.cache.clear()
    tuning.clear_measure_log()


# ---------------------------------------------------------------------------
# mode resolution + pruning
# ---------------------------------------------------------------------------


def test_resolve_mode(monkeypatch):
    assert tuning.resolve_mode("off") == "off"
    assert tuning.resolve_mode(None) == "model"  # zero-measurement default
    monkeypatch.setenv("REPRO_FFT_TUNE", "measure")
    assert tuning.resolve_mode(None) == "measure"
    with pytest.raises(ValueError):
        tuning.resolve_mode("fastest")


def test_prune_candidates_roofline():
    budget = plan_lib.VMEM_BUDGET
    cands = [
        ({"a": 1}, 1000, budget // 2),   # heuristic: 0% over the floor
        ({"a": 2}, 1100, budget // 2),   # within 20% — survives
        ({"a": 3}, 1500, budget // 2),   # 50% over — pruned
        ({"a": 4}, 900, 2 * budget),     # best bytes but does not fit VMEM
    ]
    kept = rl.prune_candidates(cands, tol=0.2)
    assert [c[0]["a"] for c in kept] == [1, 2]
    # stable heuristic-first tie-break: the modeled pick at equal bytes is
    # the fixed-heuristic config, so tune="model" reproduces history
    tied = [({"a": 1}, 1000, 0), ({"a": 2}, 1000, 0)]
    assert rl.prune_candidates(tied)[0][0]["a"] == 1
    # nothing feasible → measure anyway rather than crash
    assert rl.prune_candidates([({"a": 4}, 900, 2 * budget)])


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------


def test_cache_round_trips_json(fresh_cache):
    tuning.cache.put("k1", {"config": {"block": 8192}, "mode": "measure"})
    assert tuning.cache.get("k1")["config"]["block"] == 8192
    # a FRESH cache object re-reads the persisted file — cross-process
    assert os.path.exists(fresh_cache)
    other = tuning.TuningCache()
    assert other.get("k1") == {"config": {"block": 8192}, "mode": "measure"}
    with open(fresh_cache) as f:
        doc = json.load(f)
    assert doc["version"] == tuning.CACHE_SCHEMA_VERSION
    assert doc["entries"]["k1"]["mode"] == "measure"


# ---------------------------------------------------------------------------
# overlap-save block tuning
# ---------------------------------------------------------------------------


def test_os_block_space_heuristic_first_and_valid():
    space = tuning.TuningSpace.for_os_block(2**16, 1025, 2, "xla")
    blocks = [c[0]["block"] for c in space.candidates]
    assert blocks[0] == pick_block(1025)  # the fixed heuristic leads
    assert all(b > 1024 and b <= plan_lib.FUSED_MAX for b in blocks)
    assert all(b & (b - 1) == 0 for b in blocks)
    assert len(set(blocks)) == len(blocks) > 1


def test_tuned_block_off_is_heuristic(fresh_cache):
    assert tuning.tuned_block(2**14, 129, 1, "xla", "off") == pick_block(129)
    assert tuning.measure_log() == ()  # off mode never measures


def test_tuned_block_model_is_deterministic_and_cached(fresh_cache):
    b1 = tuning.tuned_block(2**14, 257, 2, "xla", "model")
    assert tuning.measure_log() == ()  # model mode: zero measurements
    b2 = tuning.tuned_block(2**14, 257, 2, "xla", "model")
    assert b1 == b2
    # the winner is persisted — a fresh cache object sees it
    entries = tuning.TuningCache()._load()
    assert any("os_block" in k for k in entries)


def test_measure_mode_caches_winner_zero_remeasure(fresh_cache, rng):
    L, Lh = 2**13, 129
    x = jnp.asarray(rng.standard_normal((1, L)), jnp.float32)
    h = jnp.asarray(rng.standard_normal((Lh,)), jnp.float32)
    y = fft_conv_os(x, h, backend="xla", tune="measure")
    first = tuning.measure_log()
    assert len(first) >= 1  # the pruned survivors were actually timed
    # ... and the result is still the convolution
    ref = fft_conv_os(x, h, block=pick_block(Lh), backend="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-3)
    # second call: persistent-cache hit, ZERO new measurements
    tuning.clear_measure_log()
    y2 = fft_conv_os(x, h, backend="xla", tune="measure")
    assert tuning.measure_log() == ()
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), atol=1e-6)
    # simulate a new process: fresh in-memory cache, same JSON file
    tuning.cache._mem, tuning.cache._loaded_path = {}, None
    fft_conv_os(x, h, backend="xla", tune="measure")
    assert tuning.measure_log() == ()


# ---------------------------------------------------------------------------
# plan() tuning
# ---------------------------------------------------------------------------


def test_plan_model_mode_zero_measurements_and_dominates_bytes(fresh_cache):
    spec = fft_lib.FFTSpec(n=2**17, kind="fft")
    tuned = fft_lib.plan(spec, backend="pallas", tune="model")
    off = fft_lib.plan(spec, backend="pallas", tune="off")
    assert tuned.tuned is not None and off.tuned is None
    # model mode never touches the device timer ...
    assert tuning.measure_log() == ()
    # ... and its pick can only improve the modeled HBM traffic (here it
    # swaps the 512/256 direct leaves — whose n² DFT matrices dominate the
    # stream — for fused four-step engines)
    assert plan_lib.program_hbm_bytes(tuned.fft_plan.passes) <= (
        plan_lib.program_hbm_bytes(off.fft_plan.passes)
    )
    assert len(tuned.fft_plan.passes) == len(off.fft_plan.passes)
    # tuned chunks cover exactly the chunked passes of the TUNED program
    heur = {
        i: plan_lib.pick_pass_chunk(p)
        for i, p in enumerate(tuned.fft_plan.passes)
        if p.view_in[0] > 1
    }
    assert set(tuned.pass_chunks) == set(heur)
    assert "tuned:" in tuned.describe() and "direct_max=" in tuned.describe()
    # numerics are engine-independent
    x = (np.random.default_rng(1).standard_normal((2, 2**17))).astype(np.float32)
    y_t = tuned((jnp.asarray(x), jnp.zeros((2, 2**17), jnp.float32)))
    y_o = off((jnp.asarray(x), jnp.zeros((2, 2**17), jnp.float32)))
    scale = float(np.abs(np.asarray(y_o[0])).max())
    np.testing.assert_allclose(
        np.asarray(y_t[0]), np.asarray(y_o[0]), atol=1e-3 * scale
    )
    assert fft_lib.plan(spec, backend="pallas", tune="model") is tuned


def test_plan_measure_zero_measurements_on_second_plan(fresh_cache):
    # The acceptance criterion: second plan() of the same spec performs
    # zero measurements — asserted via the plan log AND the measure log.
    spec = fft_lib.FFTSpec(n=4096, kind="fft", batch_hint=2)
    p1 = fft_lib.plan(spec, backend="pallas", tune="measure")
    assert len(tuning.measure_log()) >= 1
    log_snapshot = fft_lib.plan_log()
    tuning.clear_measure_log()
    p2 = fft_lib.plan(spec, backend="pallas", tune="measure")
    assert p2 is p1  # interned
    assert fft_lib.plan_log() == log_snapshot  # no new schedule forced
    assert tuning.measure_log() == ()
    # simulate a new process: the interning cache is cold but the
    # persistent tuning cache is warm → re-planning measures NOTHING
    cfg1 = p1.tuned
    fft_lib._plan_cached.cache_clear()
    p3 = fft_lib.plan(spec, backend="pallas", tune="measure")
    assert tuning.measure_log() == ()
    assert p3.tuned == cfg1  # same spec → same config, deterministically


def test_plan_tuned_strip_mined_chunks_cover_column_passes(fresh_cache):
    spec = fft_lib.FFTSpec(n=64, kind="fft2", n2=2**17)
    planned = fft_lib.plan(spec, backend="pallas", tune="model")
    col_idx = [i for i, p in enumerate(planned.fft_plan.passes) if p.axis == -2]
    assert col_idx and all(i in planned.pass_chunks for i in col_idx)
    # tuned chunks execute: same result as the untuned handle
    x = (np.random.default_rng(3).standard_normal((1, 2**17, 64))).astype(np.float32)
    y_t = planned((jnp.asarray(x), jnp.zeros_like(jnp.asarray(x))))
    off = fft_lib.plan(spec, backend="pallas", tune="off")
    y_o = off((jnp.asarray(x), jnp.zeros_like(jnp.asarray(x))))
    scale = float(np.abs(np.asarray(y_o[0])).max())
    np.testing.assert_allclose(
        np.asarray(y_t[0]), np.asarray(y_o[0]), atol=1e-4 * scale
    )


# ---------------------------------------------------------------------------
# plan_log ring buffer (satellite)
# ---------------------------------------------------------------------------


def test_plan_log_is_ring_buffer(monkeypatch):
    monkeypatch.setattr(
        fft_lib, "_PLAN_LOG", collections.deque(maxlen=4)
    )
    fft_lib._plan_cached.cache_clear()
    for n in (2, 4, 8, 16, 32, 64):
        fft_lib.plan(fft_lib.FFTSpec(n=n, kind="fft"), backend="stockham")
    log = fft_lib.plan_log()
    assert len(log) == 4  # capped: oldest entries fell off
    assert [s.n for s, _ in log] == [8, 16, 32, 64]
    fft_lib.clear_plan_log()
    assert fft_lib.plan_log() == ()
    fft_lib._plan_cached.cache_clear()


def test_plan_log_capacity_is_bounded():
    assert fft_lib._PLAN_LOG.maxlen == fft_lib.PLAN_LOG_MAX > 0
