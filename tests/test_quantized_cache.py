"""int8 KV-cache decode: equivalence within quantization tolerance."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as M

CFG = ModelConfig(
    family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
)


def _decode_errs(cfg, S=16, Sp=10):
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    full_logits, _ = M.logits_fn(params, {"tokens": toks}, cfg)
    lp, caches = M.prefill(params, {"tokens": toks[:, :Sp]}, cfg)
    caches = M.prepare_decode_caches(caches, cfg, Sp, S)
    errs = []
    for t in range(Sp, S):
        lg, caches = M.decode_step(
            params, toks[:, t], caches, jnp.asarray(t, jnp.int32), cfg
        )
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    scale = float(jnp.abs(full_logits).max())
    return max(errs) / scale, caches


def test_int8_cache_close_to_exact():
    cfg = dataclasses.replace(CFG, kv_cache_dtype="int8")
    rel, caches = _decode_errs(cfg)
    assert rel < 0.03, rel
    # cache really is int8 + scales
    kv = caches[0]
    assert kv.k.dtype == jnp.int8 and kv.v.dtype == jnp.int8
    assert kv.k_scale is not None and kv.k_scale.dtype == jnp.float32


def test_int8_cache_halves_bytes():
    # realistic head dim so the per-token scale overhead is negligible
    cfg = dataclasses.replace(CFG, head_dim=128)
    bf = M.cache_init(cfg, 2, 64)
    i8 = M.cache_init(dataclasses.replace(cfg, kv_cache_dtype="int8"), 2, 64)
    nbytes = lambda c: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
    assert nbytes(i8) < 0.6 * nbytes(bf)


def test_int8_windowed_cache_decodes():
    cfg = dataclasses.replace(
        CFG, sliding_window=6, kv_cache_dtype="int8"
    )
    rel, _ = _decode_errs(cfg)
    assert rel < 0.03, rel


def test_bf16_path_unchanged():
    rel, _ = _decode_errs(CFG)
    assert rel < 1e-3, rel
