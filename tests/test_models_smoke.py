"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and no NaNs (the assignment's smoke contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig, get_config, list_archs
from repro.configs.reduce import make_reduced
from repro.models import model as M
from repro.train.train_loop import init_train_state, make_train_step

ARCHS = [a for a in list_archs() if get_config(a).family != "fft"]


def _batch_for(cfg, b, s, key):
    ks = jax.random.split(key, 4)
    batch = {
        "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jax.random.normal(ks[0], (b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "vision":
        fl = min(cfg.frontend_len, s)
        batch["vision_embeds"] = jax.random.normal(ks[2], (b, fl, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None, :], (b, 3, s)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = make_reduced(get_config(arch))
    b, s = 2, 32
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(1))

    params, axes = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    # forward: correct shapes, finite values
    logits, aux = M.logits_fn(params, batch, cfg)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one train step: loss finite and params updated
    tc = TrainConfig(total_steps=2, warmup_steps=1, learning_rate=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(new_state.step) == 1
    # at least one parameter changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert changed, f"{arch}: no parameter updated"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = make_reduced(get_config(arch))
    b, s = 2, 16
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(2))
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    logits, caches = M.prefill(params, batch, cfg)
    assert logits.shape == (b, cfg.vocab_size)
    caches = M.prepare_decode_caches(caches, cfg, s, s + 4)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg, caches = M.decode_step(params, tok, caches, jnp.asarray(s, jnp.int32), cfg)
    assert lg.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), f"{arch}: non-finite decode logits"
