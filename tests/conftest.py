"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device by
design (the 512-device mesh belongs to the dry-run only).  Multi-device
tests spawn subprocesses with their own flags."""

import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Keep the autotuner's persistent cache out of the developer's real cache —
# unconditionally, so an exported REPRO_TUNING_CACHE in the developer's
# shell is never read from or written to by the suite.  Subprocesses the
# tests spawn inherit the throwaway path via the environment.
os.environ["REPRO_TUNING_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro-tuning-"), "tuning.json"
)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python ``code`` with N fake CPU devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
