"""Overlap-save convolution engine: oracles, plan-cache discipline, streaming."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft as fft_lib
from repro.core import plan as plan_lib
from repro.core.conv import fft_conv, next_pow2, toeplitz_conv_ref
from repro.core.overlap import (
    OS_FACTOR,
    StreamingConv,
    fft_conv_os,
    frame_signal,
    pick_block,
)


def _new_specs(snapshot):
    """Specs planned since ``snapshot`` (a set of plan_log entries)."""
    return [
        spec for spec, name in fft_lib.plan_log() if (spec, name) not in snapshot
    ]


# ---------------------------------------------------------------------------
# block sizing + framing
# ---------------------------------------------------------------------------


def test_pick_block_defaults():
    assert pick_block(4097) == min(8192 * OS_FACTOR, plan_lib.FUSED_MAX)
    assert pick_block(129) == 256 * OS_FACTOR
    assert pick_block(1) == 8  # degenerate 1-tap filter still plans rfft
    # filters too long for the FUSED_MAX cap keep 50% valid samples instead
    big = plan_lib.FUSED_MAX // 2 + 1
    assert pick_block(big) == 2 * next_pow2(big)


def test_pick_block_override_and_validation():
    assert pick_block(33, block=128) == 128
    with pytest.raises(ValueError):
        pick_block(33, block=100)  # not a power of two
    with pytest.raises(ValueError):
        pick_block(129, block=128)  # no valid samples per block


def test_frame_signal_windows(rng):
    x = np.arange(10, dtype=np.float32)[None]
    f = np.asarray(frame_signal(jnp.asarray(x), block=6, step=4, num_blocks=3))
    assert f.shape == (1, 3, 6)
    # frame 0 starts with the zero history, frame 1 overlaps frame 0 by 2
    np.testing.assert_array_equal(f[0, 0], [0, 0, 0, 1, 2, 3])
    np.testing.assert_array_equal(f[0, 1], [2, 3, 4, 5, 6, 7])
    np.testing.assert_array_equal(f[0, 2], [6, 7, 8, 9, 0, 0])


# ---------------------------------------------------------------------------
# fft_conv_os oracles
# ---------------------------------------------------------------------------


def test_fft_conv_os_vs_toeplitz(rng):
    x = rng.standard_normal((2, 3, 300)).astype(np.float32)
    h = rng.standard_normal((3, 33)).astype(np.float32)
    y = np.asarray(fft_conv_os(jnp.asarray(x), jnp.asarray(h), block=128))
    ref = toeplitz_conv_ref(x, h[None])
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_fft_conv_os_full_mode(rng):
    x = rng.standard_normal((1, 200)).astype(np.float32)
    h = rng.standard_normal((1, 17)).astype(np.float32)
    y = np.asarray(
        fft_conv_os(jnp.asarray(x), jnp.asarray(h), causal=False, block=64)
    )
    ref = np.convolve(x[0], h[0], mode="full")[None]
    assert y.shape == ref.shape
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_fft_conv_os_axis(rng):
    x = rng.standard_normal((130, 2)).astype(np.float32)
    h = rng.standard_normal((9,)).astype(np.float32)
    y = np.asarray(fft_conv_os(jnp.asarray(x), jnp.asarray(h), axis=0, block=32))
    ref = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h), axis=0))
    np.testing.assert_allclose(y, ref, atol=2e-3)


@pytest.mark.parametrize("L", [2**16, 2**18])
def test_fft_conv_os_matches_one_shot(L, rng):
    Lh = 4097
    x = rng.standard_normal((2, L)).astype(np.float32)
    h = rng.standard_normal((Lh,)).astype(np.float32)
    y_one = np.asarray(
        fft_conv(jnp.asarray(x), jnp.asarray(h), overlap_save=False)
    )
    y_os = np.asarray(fft_conv_os(jnp.asarray(x), jnp.asarray(h)))
    scale = np.abs(y_one).max()
    np.testing.assert_allclose(y_os, y_one, atol=1e-3 * scale)


@pytest.mark.parametrize("backend", ["pallas", "xla", "stockham"])
def test_fft_conv_os_backends_agree(backend, rng):
    # pallas runs interpret on CPU — the kernel path through the engine is
    # exercised in the pallas-interpret CI job; small block keeps it cheap.
    x = rng.standard_normal((2, 2**13)).astype(np.float32)
    h = rng.standard_normal((129,)).astype(np.float32)
    y = np.asarray(
        fft_conv_os(jnp.asarray(x), jnp.asarray(h), block=2048, backend=backend)
    )
    ref = np.asarray(
        fft_conv(jnp.asarray(x), jnp.asarray(h), overlap_save=False, backend="xla")
    )
    np.testing.assert_allclose(y, ref, atol=2e-3 * np.abs(ref).max())


def test_fft_conv_os_dtype_restored(rng):
    x = jnp.asarray(rng.standard_normal((2, 256)), jnp.bfloat16)
    h = jnp.asarray(rng.standard_normal((17,)), jnp.bfloat16)
    y = fft_conv_os(x, h, block=64)
    assert y.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# plan-cache discipline: the acceptance criterion made literal
# ---------------------------------------------------------------------------


def test_plan_cache_stays_fused_for_1m_signal(rng):
    L, Lh = 2**20, 4097
    x = rng.standard_normal((1, L)).astype(np.float32)
    h = rng.standard_normal((Lh,)).astype(np.float32)
    snapshot = set(fft_lib.plan_log())
    y = np.asarray(fft_conv_os(jnp.asarray(x), jnp.asarray(h)))
    for spec in _new_specs(snapshot):
        assert max(spec.n, spec.n2 or 0) <= plan_lib.FUSED_MAX, (
            f"overlap-save planned past the fused regime: {spec}"
        )
    # causal outputs only depend on the causal past: the head of the 1M
    # result must equal the (one-shot, fused-regime) conv of the head.
    head = 8192
    ref = np.asarray(
        fft_conv(jnp.asarray(x[..., :head]), jnp.asarray(h), overlap_save=False)
    )
    np.testing.assert_allclose(y[..., :head], ref, atol=1e-3 * np.abs(ref).max())


def test_fft_conv_auto_routes_long_signals(rng):
    L, Lh = 2**17, 4097  # next_pow2(L + Lh - 1) = 2**18 > FUSED_MAX
    x = rng.standard_normal((1, L)).astype(np.float32)
    h = rng.standard_normal((Lh,)).astype(np.float32)
    snapshot = set(fft_lib.plan_log())
    y_auto = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    assert all(
        max(spec.n, spec.n2 or 0) <= plan_lib.FUSED_MAX
        for spec in _new_specs(snapshot)
    )
    y_one = np.asarray(
        fft_conv(jnp.asarray(x), jnp.asarray(h), overlap_save=False)
    )
    np.testing.assert_allclose(y_auto, y_one, atol=1e-3 * np.abs(y_one).max())


def test_fft_conv_short_signals_stay_one_shot(rng):
    # Under the routing threshold nothing changes: the one-shot rfft pair.
    x = rng.standard_normal((2, 1024)).astype(np.float32)
    h = rng.standard_normal((64,)).astype(np.float32)
    snapshot = set(fft_lib.plan_log())
    fft_conv(jnp.asarray(x), jnp.asarray(h))
    kinds = {(s.kind, s.n) for s in _new_specs(snapshot)}
    assert all(n <= plan_lib.FUSED_MAX for _, n in kinds)


# ---------------------------------------------------------------------------
# StreamingConv: chunked == one-shot
# ---------------------------------------------------------------------------


def _stream(sc, x, schedule):
    state = sc.init_state(x.shape[:-1])
    outs, pos = [], 0
    for c in schedule:
        y, state = sc(jnp.asarray(x[..., pos : pos + c]), state)
        outs.append(np.asarray(y))
        pos += c
    assert pos == x.shape[-1]
    return np.concatenate(outs, axis=-1), state


@pytest.mark.parametrize(
    "schedule",
    [
        [640] * 7 + [520],          # ragged final chunk
        [64] * 78 + [8],            # every chunk smaller than Lh
        [1000, 17, 3000, 983],      # mixed, including chunk << Lh
    ],
)
def test_streaming_matches_one_shot(schedule, rng):
    L, Lh = sum(schedule), 129
    x = rng.standard_normal((2, L)).astype(np.float32)
    h = rng.standard_normal((Lh,)).astype(np.float32)
    sc = StreamingConv(jnp.asarray(h))
    y_stream, state = _stream(sc, x, schedule)
    assert state.shape == (2, Lh - 1)
    np.testing.assert_array_equal(np.asarray(state), x[:, -(Lh - 1) :])
    y_one = np.asarray(fft_conv_os(jnp.asarray(x), jnp.asarray(h)))
    scale = max(1.0, np.abs(y_one).max())
    np.testing.assert_allclose(y_stream, y_one, atol=1e-3 * scale)


def test_streaming_per_channel_filters(rng):
    x = rng.standard_normal((2, 3, 500)).astype(np.float32)
    h = rng.standard_normal((3, 33)).astype(np.float32)
    sc = StreamingConv(jnp.asarray(h), block=128)
    y_stream, _ = _stream(sc, x, [200, 300])
    ref = toeplitz_conv_ref(x, h[None])
    np.testing.assert_allclose(y_stream, ref, atol=2e-3)


def test_streaming_one_tap_filter(rng):
    # Lh = 1: zero-width state, pure gain — the degenerate edge.
    x = rng.standard_normal((2, 100)).astype(np.float32)
    sc = StreamingConv(jnp.asarray(np.array([2.0], np.float32)))
    y, state = _stream(sc, x, [60, 40])
    assert state.shape == (2, 0)
    np.testing.assert_allclose(y, 2.0 * x, atol=1e-5)


def test_streaming_rejects_bad_state(rng):
    sc = StreamingConv(jnp.asarray(rng.standard_normal((17,)), jnp.float32))
    with pytest.raises(ValueError):
        sc(jnp.zeros((2, 8)), jnp.zeros((2, 3)))
