"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles.

Sweeps shapes and regimes per the assignment: every kernel is asserted
allclose against ref.py's float64 naive DFT (small N) and jnp.fft.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import twiddle as tw
from repro.kernels import ops, ref
from repro.kernels.dft_matmul import dft_matmul_call
from repro.kernels.fft4step import fft4step_call


def _rand(rng, shape):
    return (
        rng.standard_normal(shape).astype(np.float32),
        rng.standard_normal(shape).astype(np.float32),
    )


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_dft_matmul_vs_naive(n, batch, rng):
    xr, xi = _rand(rng, (batch, n))
    wr, wi = tw.dft_matrix(n)
    yr, yi = dft_matmul_call(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(wr), jnp.asarray(wi),
        batch_tile=batch, interpret=True,
    )
    refv = ref.naive_dft(xr + 1j * xi)
    scale = np.abs(refv).max()
    np.testing.assert_allclose(np.asarray(yr), refv.real, atol=2e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), refv.imag, atol=2e-4 * scale)


@pytest.mark.parametrize("n1,n2", [(64, 32), (64, 64), (128, 64)])
@pytest.mark.parametrize("batch_tile", [1, 2])
def test_fft4step_vs_four_step_ref(n1, n2, batch_tile, rng):
    n = n1 * n2
    b = 2 * batch_tile
    xr, xi = _rand(rng, (b, n))
    w1r, w1i = tw.dft_matrix(n1)
    tr, ti = tw.twiddle_grid(n1, n2)
    w2r, w2i = tw.dft_matrix(n2)
    yr, yi = fft4step_call(
        jnp.asarray(xr), jnp.asarray(xi),
        jnp.asarray(w1r), jnp.asarray(w1i),
        jnp.asarray(tr), jnp.asarray(ti),
        jnp.asarray(w2r), jnp.asarray(w2i),
        batch_tile=batch_tile, interpret=True,
    )
    refv = ref.four_step_ref(xr + 1j * xi, n1, n2)
    refv2 = ref.naive_dft(xr + 1j * xi)
    scale = np.abs(refv).max()
    np.testing.assert_allclose(refv, refv2, atol=1e-9 * scale)  # ref self-check
    np.testing.assert_allclose(np.asarray(yr), refv.real, atol=3e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), refv.imag, atol=3e-4 * scale)


def test_fft4step_pencil_layout(rng):
    n1, n2 = 64, 64
    n = n1 * n2
    xr, xi = _rand(rng, (2, n))
    w1r, w1i = tw.dft_matrix(n1)
    tr, ti = tw.twiddle_grid(n1, n2)
    w2r, w2i = tw.dft_matrix(n2)
    yr, yi = fft4step_call(
        jnp.asarray(xr), jnp.asarray(xi),
        jnp.asarray(w1r), jnp.asarray(w1i),
        jnp.asarray(tr), jnp.asarray(ti),
        jnp.asarray(w2r), jnp.asarray(w2i),
        batch_tile=2, natural_order=False, interpret=True,
    )
    refv = ref.naive_dft(xr + 1j * xi)
    # pencil (k1-major): y.reshape(n1, n2)[k1, k2] == X[k1 + n1*k2]
    y = (np.asarray(yr) + 1j * np.asarray(yi)).reshape(2, n1, n2)
    perm = refv.reshape(2, n2, n1).transpose(0, 2, 1)
    np.testing.assert_allclose(y, perm, atol=3e-4 * np.abs(refv).max())


@pytest.mark.parametrize("inverse", [False, True])
@pytest.mark.parametrize("n", [16, 1024, 4096, 16384])
def test_ops_fft_all_regimes(n, inverse, rng):
    xr, xi = _rand(rng, (3, n))
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), inverse=inverse, interpret=True)
    x = xr + 1j * xi
    refv = np.fft.ifft(x) if inverse else np.fft.fft(x)
    scale = np.abs(refv).max()
    np.testing.assert_allclose(np.asarray(yr), refv.real, atol=4e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), refv.imag, atol=4e-4 * scale)


def test_ops_fft_split_regime_smoke(rng):
    n = 2**17  # two pallas_call passes via the ops-level split
    xr, xi = _rand(rng, (1, n))
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), interpret=True)
    refv = np.fft.fft(xr + 1j * xi)
    rel = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - refv).max() / np.abs(refv).max()
    assert rel < 1e-4, rel


def test_ops_batch_padding(rng):
    # batch not a multiple of the tile must round-trip unchanged
    xr, xi = _rand(rng, (5, 2048))
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), interpret=True)
    assert yr.shape == (5, 2048)
    refv = np.fft.fft(xr + 1j * xi)
    np.testing.assert_allclose(
        np.asarray(yr) + 1j * np.asarray(yi), refv, atol=3e-4 * np.abs(refv).max()
    )


def test_ops_nd_batch(rng):
    xr, xi = _rand(rng, (2, 3, 1024))
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), interpret=True)
    refv = np.fft.fft(xr + 1j * xi)
    np.testing.assert_allclose(
        np.asarray(yr) + 1j * np.asarray(yi), refv, atol=3e-4 * np.abs(refv).max()
    )


def test_dft_matmul_twiddle_epilogue(rng):
    """Post-GEMM per-bin twiddle rides the same HBM round trip."""
    n, b = 256, 4
    xr, xi = _rand(rng, (b, n))
    wr, wi = tw.dft_matrix(n)
    er, ei = tw.rfft_recomb_twiddle(2 * n)  # any unit phasor table works
    er, ei = er[:n], ei[:n]
    yr, yi = dft_matmul_call(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(wr), jnp.asarray(wi),
        batch_tile=b, twiddle=(er, ei), interpret=True,
    )
    refv = ref.naive_dft(xr + 1j * xi) * (er + 1j * ei)[None]
    scale = np.abs(refv).max()
    np.testing.assert_allclose(np.asarray(yr), refv.real, atol=3e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), refv.imag, atol=3e-4 * scale)


def test_fft4step_twiddle_after_epilogue(rng):
    n1 = n2 = 64
    n = n1 * n2
    xr, xi = _rand(rng, (2, n))
    w1r, w1i = tw.dft_matrix(n1)
    tr, ti = tw.twiddle_grid(n1, n2)
    w2r, w2i = tw.dft_matrix(n2)
    er, ei = tw.rfft_recomb_twiddle(2 * n)
    er, ei = er[:n], ei[:n]
    yr, yi = fft4step_call(
        jnp.asarray(xr), jnp.asarray(xi),
        jnp.asarray(w1r), jnp.asarray(w1i),
        jnp.asarray(tr), jnp.asarray(ti),
        jnp.asarray(w2r), jnp.asarray(w2i),
        batch_tile=2, twiddle_after=(er, ei), interpret=True,
    )
    refv = ref.naive_dft(xr + 1j * xi) * (er + 1j * ei)[None]
    scale = np.abs(refv).max()
    np.testing.assert_allclose(np.asarray(yr), refv.real, atol=4e-4 * scale)
    np.testing.assert_allclose(np.asarray(yi), refv.imag, atol=4e-4 * scale)


def test_inverse_scaling_folded(rng):
    """ifft(fft(x)) == x exactly through the kernel path (scaled LUTs)."""
    xr, xi = _rand(rng, (2, 4096))
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), interpret=True)
    zr, zi = ops.ifft(yr, yi, interpret=True)
    np.testing.assert_allclose(np.asarray(zr), xr, atol=2e-4)
    np.testing.assert_allclose(np.asarray(zi), xi, atol=2e-4)
