"""Plan schedule tests — the paper's kernel-call-count table (§2.3.2/§3)."""

import pytest

from repro.core import plan as P


def test_direct_regime():
    for n in (2, 16, 256, 1024):
        p = P.plan_fft(n)
        assert p.kernel_calls == 1
        assert p.leaf_passes[0].kind == "direct"


def test_fused_regime_one_call():
    for n in (2048, 4096, 16384, 65536):
        p = P.plan_fft(n)
        assert p.kernel_calls == 1, n
        assert p.leaf_passes[-1].kind == "fused4"


def test_split_regimes_match_paper_structure():
    # Above the fused limit each program factor is one HBM round trip: the
    # two-factor program covers every N ≤ 2³² in 2 calls (the paper's ≥ 3
    # beyond 32K, beaten by fusing twiddle + transpose into the kernels).
    assert P.plan_fft(2**17).kernel_calls == 2
    assert P.plan_fft(2**24).kernel_calls == 2
    assert P.plan_fft(2**32).kernel_calls == 2  # 65536 x 65536
    # Beyond two factors natural order needs the explicit digit-reversal
    # relayout pass (3 transform passes + 1 reorder); pencil order skips it.
    assert P.plan_fft(2**33).kernel_calls == 4
    assert len(P.compile_passes(2**33, order="pencil")) == 3


def test_balanced_split():
    for n in (4, 64, 1024, 2**20):
        n1, n2 = P.balanced_split(n)
        assert n1 * n2 == n
        assert n1 >= n2
        assert n1 // n2 in (1, 2)
    n1, n2 = P.balanced_split(2**20, cap=256)
    assert n2 <= 256 and n1 * n2 == 2**20


def test_non_pow2_routes_to_bluestein():
    # Non-pow2 lengths compile to Bluestein chirp-conv leaves instead of
    # being rejected; non-positive lengths still raise.
    pl = P.plan_fft(48)
    assert [p.kind for p in pl.passes] == ["bluestein", "bluestein"]
    with pytest.raises(ValueError):
        P.plan_fft(0)
    with pytest.raises(ValueError):
        P.balanced_split(0)


def test_vmem_budget_respected():
    for n in (2048, 65536):
        p = P.plan_fft(n).leaf_passes[-1]
        bt = P.pick_batch_tile(p)
        assert bt >= 1
        assert P.vmem_bytes(p, bt) <= 8 * 1024 * 1024 or bt == 1


def test_describe_smoke():
    s = P.describe(2**18)
    assert "2 HBM round trip" in s
    assert "twiddle" in s  # pass program lines include the fused epilogue
    assert "MB" in s  # ... and the modeled HBM traffic


def test_pass_program_round_trip_counts():
    # ISSUE-2 acceptance bounds: ≤ 3 / 3 / 4 passes for 2¹⁷ / 2¹⁸ / 2²⁰.
    # The fused program does them all in 2 (twiddle + transpose in-kernel).
    for n, bound in ((2**17, 3), (2**18, 3), (2**20, 4)):
        plan = P.plan_fft(n)
        assert len(plan.passes) == 2 <= bound
        assert plan.hbm_round_trips == len(plan.passes)


def test_pass_program_views_and_twiddle():
    n = 2**18
    f0, f1 = P.program_factors(n)
    assert (f0, f1) == (512, 512)
    col, row = P.plan_fft(n).passes
    # column pass: strided pencils, in-place layout, fused twiddle epilogue
    assert col.view_in == (n // f0, f1, f0)
    assert col.view_out == col.view_in
    assert col.twiddle_after == (f0, f1)
    assert col.order == "pencil"
    # row pass: contiguous pencils, natural-order transpose fused into the
    # strided write (its out view is the column view of the output buffer)
    assert row.view_in == (f0, 1, f1)
    assert row.view_out == (f0, f0, f1)
    assert row.twiddle_after is None
    assert row.order == "natural"


def test_pass_program_factor_consistency():
    for n in (2**17, 2**18, 2**20, 2**24):
        fs = P.program_factors(n)
        assert all(f <= P.FUSED_MAX for f in fs)
        prod = 1
        for f in fs:
            prod *= f
        assert prod == n
        # program transform passes and factors line up 1:1
        ts = [p for p in P.plan_fft(n).passes if p.kind != "reorder"]
        assert tuple(p.n for p in ts) == fs


def test_pass_hbm_bytes_model():
    n = 2**18
    plan = P.plan_fft(n)
    sig = n * 2 * 4  # split-complex f32, batch 1
    for p in plan.passes:
        assert P.pass_hbm_bytes(p, batch=1) >= 2 * sig  # read + write
    # the twiddle LUT is charged once, to the pass that fuses it
    col, row = plan.passes
    assert P.pass_hbm_bytes(col, 1) - P.pass_hbm_bytes(row, 1) >= sig
    assert P.program_hbm_bytes(plan.passes, 2) > P.program_hbm_bytes(plan.passes, 1)


def test_pick_pass_chunk_ragged_widths():
    # Non-pow2 widths: the chunk starts from the largest power of two BELOW
    # the width (the executor pads the last partial chunk), including the
    # pow2-floor boundary width 65537 and 3·2^k widths.
    p = P.plan_fft(2**18).passes[0]  # strided column pass, f=512
    c = P.pick_pass_chunk(p, width=65537)
    assert c & (c - 1) == 0 and c <= 65536
    assert P._pass_chunk_bytes(p, c) <= P.VMEM_BUDGET or c == 1
    for k in (4, 8, 12):
        w = 3 << k  # 3·2^k floors to 2^(k+1)
        c = P.pick_pass_chunk(p, width=w)
        assert c & (c - 1) == 0 and c <= 1 << (k + 1)
    # degenerate width: one pencil column still yields a valid chunk
    assert P.pick_pass_chunk(p, width=1) == 1


def test_pick_pass_chunk_chunk1_degenerate():
    # A binding budget collapses to chunk=1 (padded sublanes beat a working
    # set that cannot be placed at all) — with and without width override.
    p = P.plan_fft(2**18).passes[0]
    assert P.pick_pass_chunk(p, budget=1) == 1
    assert P.pick_pass_chunk(p, budget=1, width=65537) == 1
    assert P.pick_pass_chunk(p, budget=1, width=3 << 8) == 1


def test_pick_pass_chunk_fits_budget():
    # The VMEM budget is binding (a chunk below one 128-lane tile beats a
    # working set Mosaic cannot place at all) — incl. huge factors like 2²⁶'s
    # 8192×8192 program, which interpret-mode CI would never surface.
    for n in (2**17, 2**18, 2**20, 2**26):
        for p in P.plan_fft(n).passes:
            c = P.pick_pass_chunk(p)
            assert c >= 1
            axis = p.view_in[1] if p.view_in[1] > 1 else p.view_in[0]
            assert axis % c == 0
            assert P._pass_chunk_bytes(p, c) <= 8 * 1024 * 1024 or c == 1
