"""Plan schedule tests — the paper's kernel-call-count table (§2.3.2/§3)."""

import pytest

from repro.core import plan as P


def test_direct_regime():
    for n in (2, 16, 256, 1024):
        p = P.plan_fft(n)
        assert p.kernel_calls == 1
        assert p.leaf_passes[0].kind == "direct"


def test_fused_regime_one_call():
    for n in (2048, 4096, 16384, 65536):
        p = P.plan_fft(n)
        assert p.kernel_calls == 1, n
        assert p.leaf_passes[-1].kind == "fused4"


def test_split_regimes_match_paper_structure():
    # Above the fused limit each factor-split adds one HBM round trip,
    # mirroring the paper's 2-call and 3-call regimes.
    assert P.plan_fft(2**17).kernel_calls == 2
    assert P.plan_fft(2**24).kernel_calls == 2
    assert P.plan_fft(2**32).kernel_calls == 2  # 65536 x 65536
    assert P.plan_fft(2**33).kernel_calls == 3


def test_balanced_split():
    for n in (4, 64, 1024, 2**20):
        n1, n2 = P.balanced_split(n)
        assert n1 * n2 == n
        assert n1 >= n2
        assert n1 // n2 in (1, 2)
    n1, n2 = P.balanced_split(2**20, cap=256)
    assert n2 <= 256 and n1 * n2 == 2**20


def test_non_pow2_rejected():
    with pytest.raises(ValueError):
        P.plan_fft(48)
    with pytest.raises(ValueError):
        P.balanced_split(0)


def test_vmem_budget_respected():
    for n in (2048, 65536):
        p = P.plan_fft(n).leaf_passes[-1]
        bt = P.pick_batch_tile(p)
        assert bt >= 1
        assert P.vmem_bytes(p, bt) <= 8 * 1024 * 1024 or bt == 1


def test_describe_smoke():
    s = P.describe(2**18)
    assert "2 HBM round trip" in s
