"""Sharding: logical-axis specs, divisibility fallback, multi-device train
parity (subprocess with 8 fake devices)."""

import jax.numpy as jnp
import pytest

from conftest import run_in_subprocess
from repro.configs.base import ModelConfig, ParallelConfig
from repro.sharding.logical import rules_for, spec_for


def test_rules_single_pod():
    par = ParallelConfig()
    r = rules_for(par)
    assert r["batch"] == ("data",)
    assert r["heads"] == ("model",)
    assert r["embed"] is None


def test_rules_multi_pod_fsdp():
    par = ParallelConfig(pod_axis="pod", fsdp=True, sequence_parallel=True)
    r = rules_for(par)
    assert r["batch"] == ("pod", "data")
    assert r["embed"] == ("pod", "data")
    assert r["kv_seq"] == ("data",)


def test_spec_no_duplicate_mesh_axes():
    par = ParallelConfig(fsdp=True)
    # batch uses 'data'; embed would also want 'data' → must drop it.
    spec = spec_for(("batch", "seq", "embed"), par)
    flat = [e for e in spec if e is not None]
    names = []
    for e in flat:
        names += list(e) if isinstance(e, tuple) else [e]
    assert len(names) == len(set(names))


_PARITY = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.mesh import make_mesh, parallel_config_for
from repro.launch import shardings as sh_lib
from repro.sharding.logical import mesh_context
from repro.train.train_loop import init_train_state, make_train_step

cfg = ModelConfig(family='dense', num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=512, loss_chunk=16)
tc = TrainConfig(total_steps=5, warmup_steps=1, learning_rate=1e-3)
dcfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8)

def run(mesh_shape):
    mesh = make_mesh(mesh_shape, ('data', 'model'))
    par = parallel_config_for(mesh)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    state_sh = sh_lib.train_state_shardings(cfg, tc, mesh, par)
    state = jax.device_put(state, state_sh)
    raw = make_train_step(cfg, tc)
    def stepper(s, b):
        with mesh_context(mesh, par):
            return raw(s, b)
    fn = jax.jit(stepper, in_shardings=(state_sh, None), out_shardings=(state_sh, None))
    losses = []
    for i in range(4):
        b = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        state, m = fn(state, b)
        losses.append(float(m['loss']))
    return losses

l1 = run((1, 1))
l8 = run((4, 2))
print('L1', l1)
print('L8', l8)
for a, b in zip(l1, l8):
    assert abs(a - b) < 5e-3, (l1, l8)
print('PARITY_OK')
"""


@pytest.mark.slow
def test_multi_device_training_parity():
    out = run_in_subprocess(_PARITY, devices=8)
    assert "PARITY_OK" in out


_ELASTIC = r"""
import tempfile, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, TrainConfig
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import make_mesh, parallel_config_for
from repro.launch import shardings as sh_lib
from repro.train.train_loop import init_train_state

cfg = ModelConfig(family='dense', num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=512)
tc = TrainConfig()

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    # save from a 4x2 mesh
    mesh_a = make_mesh((4, 2), ('data', 'model'))
    par_a = parallel_config_for(mesh_a)
    st = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    st = jax.device_put(st, sh_lib.train_state_shardings(cfg, tc, mesh_a, par_a))
    mgr.save(1, st)
    # restore onto a 2x4 mesh (elastic re-shard)
    mesh_b = make_mesh((2, 4), ('data', 'model'))
    par_b = parallel_config_for(mesh_b)
    sh_b = sh_lib.train_state_shardings(cfg, tc, mesh_b, par_b)
    like = sh_lib.abstract_train_state(cfg, tc)
    rst, _ = mgr.restore(1, like, shardings=sh_b)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(rst)):
        assert np.allclose(np.asarray(a, dtype=np.float32), np.asarray(b, dtype=np.float32)), 'value mismatch'
print('ELASTIC_OK')
"""


@pytest.mark.slow
def test_elastic_restore_different_mesh():
    out = run_in_subprocess(_ELASTIC, devices=8)
    assert "ELASTIC_OK" in out
