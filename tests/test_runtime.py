"""Fault-tolerance runtime: watchdog, retries, straggler stats, serving."""

import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime.fault_tolerance import StepWatchdog, StragglerStats, with_retries
from repro.serving.engine import Engine, ServeConfig
from repro.serving.sampling import sample


def test_watchdog_fires_on_hang():
    fired = []
    wd = StepWatchdog(0.15, on_timeout=lambda: fired.append(1))
    wd.arm()
    time.sleep(0.5)
    wd.close()
    assert fired


def test_watchdog_disarm_prevents_fire():
    fired = []
    wd = StepWatchdog(0.2, on_timeout=lambda: fired.append(1))
    wd.arm()
    wd.disarm()
    time.sleep(0.5)
    wd.close()
    assert not fired


def test_with_retries_recovers():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient device error")
        return "ok"

    assert with_retries(flaky, retries=3, backoff_s=0.01) == "ok"
    assert len(calls) == 3


def test_with_retries_exhausts():
    def always_fails():
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        with_retries(always_fails, retries=2, backoff_s=0.01)


def test_straggler_stats():
    st = StragglerStats(threshold=2.0)
    for _ in range(10):
        st.record(1.0)
    assert st.record(5.0) is True
    assert st.flagged == 1
    assert 0.9 < st.ewma < 1.6


def test_sampling_greedy_and_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(jax.random.PRNGKey(0), logits)[0]) == 1
    tok = sample(jax.random.PRNGKey(0), logits, temperature=1.0, top_k=2)
    assert int(tok[0]) in (1, 2)


def test_engine_generates_and_stops_at_eos():
    cfg = ModelConfig(
        family="dense", num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
        d_ff=64, vocab_size=64,
    )
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_new=6))
    out = eng.generate(jnp.ones((2, 8), jnp.int32) * 5)
    assert out.shape == (2, 6)
    assert out.dtype == jnp.int32
