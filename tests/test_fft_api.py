"""Plan-and-execute API: plan cache identity, backend registry, use_backend
scoping, axis-aware transforms, and the cross-backend acceptance sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft as F

BACKENDS = F.available_backends()
# 262144 = 2¹⁸: the split regime's linearized pass program, on every backend.
ACCEPTANCE_SIZES = [256, 4096, 131072, 262144]


def _rand_c(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


# ---------------------------------------------------------------------------
# plan cache + handle identity
# ---------------------------------------------------------------------------


def test_plan_cache_identity():
    spec = F.FFTSpec(n=1024)
    assert F.plan(spec) is F.plan(spec)
    # specs are value-keyed, not object-keyed
    assert F.plan(F.FFTSpec(n=1024)) is F.plan(spec)
    # a different backend resolves to a different handle
    assert F.plan(spec, backend="stockham") is not F.plan(spec, backend="xla")
    # int shorthand plans a forward complex FFT
    assert F.plan(1024) is F.plan(spec)


def test_planned_handle_is_hashable():
    a = F.plan(F.FFTSpec(n=512), backend="xla")
    b = F.plan(F.FFTSpec(n=512), backend="xla")
    assert len({a, b}) == 1
    assert hash(a) == hash(b)
    c = F.plan(F.FFTSpec(n=512), backend="stockham")
    assert a != c


def test_planned_carries_schedule_and_luts():
    p = F.plan(F.FFTSpec(n=4096, batch_hint=2), backend="pallas")
    assert p.fft_plan.n == 4096
    assert p.luts, "LUTs should be pre-materialized at plan time"
    # batch_hint caps the kernel tile so a 2-row batch is not padded to 512
    assert all(bt <= 2 for bt in p.batch_tiles.values())
    assert "4096" in p.describe()


def test_spec_validation():
    F.FFTSpec(n=48)  # non-pow2 1-D lengths are valid (Bluestein route)
    with pytest.raises(ValueError):
        F.FFTSpec(n=0)  # n must be >= 1
    with pytest.raises(ValueError):
        F.FFTSpec(n=48, kind="rfft2", n2=64)  # 2-D row axis is still pow2
    with pytest.raises(ValueError):
        F.FFTSpec(n=64, kind="dct")
    with pytest.raises(ValueError):
        F.FFTSpec(n=64, kind="fft2")  # fft2 needs n2
    with pytest.raises(ValueError):
        F.FFTSpec(n=64, n2=32)  # n2 on a 1-D kind
    with pytest.raises(ValueError):
        F.FFTSpec(n=64, kind="fft2", n2=32, axis=0)  # 2-D kinds: last two axes


def test_registration_invalidates_plan_cache(rng):
    F.plan(F.FFTSpec(n=2048))  # warm the cache with a negotiated plan
    name = "late-registered"
    try:
        F.register_backend(
            name,
            lambda xr, xi, *, inverse, planned: F.fft_xla.stockham_fft(
                xr, xi, inverse=inverse
            ),
            F.BackendCapabilities(
                priority=10_000,
                preferred_platforms=frozenset({"cpu", "tpu", "gpu"}),
            ),
        )
        p_after = F.plan(F.FFTSpec(n=2048))
        assert p_after.backend.name == name, "new high-priority backend should win"
        x = _rand_c(rng, (2, 2048))
        y = np.asarray(p_after(jnp.asarray(x)))
        np.testing.assert_allclose(y, np.fft.fft(x), atol=2e-3 * np.abs(y).max())
    finally:
        # don't leak a session-global negotiation winner into other tests
        F._REGISTRY.pop(name, None)
        F._plan_cached.cache_clear()


# ---------------------------------------------------------------------------
# registry + capability negotiation
# ---------------------------------------------------------------------------


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown FFT backend"):
        F.plan(F.FFTSpec(n=64), backend="nope")
    with pytest.raises(ValueError, match="unknown FFT backend"):
        with F.use_backend("nope"):
            pass  # pragma: no cover


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        F.register_backend("xla", lambda *a, **k: None)


@pytest.fixture
def scratch_backend():
    """Yields a registration helper and cleans the global registry up after."""
    names = []

    def register(name, fn, caps=None):
        names.append(name)
        return F.register_backend(name, fn, caps)

    try:
        yield register
    finally:
        for name in names:
            F._REGISTRY.pop(name, None)
        F._plan_cached.cache_clear()


def test_register_custom_backend(rng, scratch_backend):
    calls = []

    def counting(xr, xi, *, inverse, planned):
        calls.append(planned.spec.n)
        return F.fft_xla.stockham_fft(xr, xi, inverse=inverse)

    scratch_backend("counting-test", counting)
    x = _rand_c(rng, (2, 128))
    y = np.asarray(F.fft(jnp.asarray(x), backend="counting-test"))
    np.testing.assert_allclose(y, np.fft.fft(x), atol=2e-3 * np.abs(y).max())
    assert calls == [128]


def test_capability_rejection(scratch_backend):
    def tiny(xr, xi, *, inverse, planned):
        return F.fft_xla.stockham_fft(xr, xi, inverse=inverse)

    scratch_backend("tiny-test", tiny, F.BackendCapabilities(max_n=64))
    assert F.plan(F.FFTSpec(n=64), backend="tiny-test")
    with pytest.raises(ValueError, match="does not support"):
        F.plan(F.FFTSpec(n=128), backend="tiny-test")


# ---------------------------------------------------------------------------
# use_backend scoping
# ---------------------------------------------------------------------------


def test_use_backend_scopes_and_nests():
    base = F.default_backend()
    with F.use_backend("stockham"):
        assert F.default_backend() == "stockham"
        with F.use_backend("xla"):
            assert F.default_backend() == "xla"
        assert F.default_backend() == "stockham"
    assert F.default_backend() == base


def test_use_backend_restores_on_exception():
    base = F.default_backend()
    with pytest.raises(RuntimeError):
        with F.use_backend("stockham"):
            assert F.default_backend() == "stockham"
            raise RuntimeError("boom")
    assert F.default_backend() == base


def test_use_backend_drives_plan_selection(rng):
    with F.use_backend("stockham"):
        p = F.plan(F.FFTSpec(n=256))
    assert p.backend.name == "stockham"


def test_set_default_backend_deprecated():
    import repro.core.fft as fft_mod

    saved = fft_mod._GLOBAL_DEFAULT
    try:
        with pytest.warns(DeprecationWarning):
            fft_mod.set_default_backend("xla")
        assert F.default_backend() == "xla"
    finally:
        fft_mod._GLOBAL_DEFAULT = saved


# ---------------------------------------------------------------------------
# axis-aware transforms
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("axis", [0, 1, -2])
def test_fft_non_last_axis_matches_jnp(axis, rng):
    x = _rand_c(rng, (64, 32, 3))
    y = np.asarray(F.fft(jnp.asarray(x), axis=axis))
    ref = np.asarray(jnp.fft.fft(jnp.asarray(x), axis=axis))
    np.testing.assert_allclose(y, ref, atol=1e-3 * np.abs(ref).max())


def test_rfft_irfft_non_last_axis(rng):
    x = rng.standard_normal((2, 256, 3)).astype(np.float32)
    Xr, Xi = F.rfft(jnp.asarray(x), axis=1)
    ref = np.fft.rfft(x, axis=1)
    assert Xr.shape == ref.shape
    np.testing.assert_allclose(np.asarray(Xr), ref.real, atol=3e-3 * np.abs(ref).max())
    np.testing.assert_allclose(np.asarray(Xi), ref.imag, atol=3e-3 * np.abs(ref).max())
    back = np.asarray(F.irfft((Xr, Xi), 256, axis=1))
    np.testing.assert_allclose(back, x, atol=2e-4)


def test_ifft_axis_roundtrip(rng):
    x = _rand_c(rng, (4, 128, 2))
    y = F.ifft(F.fft(jnp.asarray(x), axis=1), axis=1)
    np.testing.assert_allclose(np.asarray(y), x, atol=2e-4)


# ---------------------------------------------------------------------------
# acceptance sweep: every registered backend, 1e-3, incl. a non-last axis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", ACCEPTANCE_SIZES)
def test_planned_matches_jnp_all_backends(backend, n, rng):
    batch = 1 if n > 2**14 else 3
    x = _rand_c(rng, (batch, n))
    planned = F.plan(F.FFTSpec(n=n, kind="fft"), backend=backend)
    y = np.asarray(planned(jnp.asarray(x)))
    ref = np.asarray(jnp.fft.fft(jnp.asarray(x)))
    assert np.abs(y - ref).max() <= 1e-3 * np.abs(ref).max(), (backend, n)


@pytest.mark.parametrize("backend", BACKENDS)
def test_planned_matches_jnp_non_last_axis(backend, rng):
    x = _rand_c(rng, (2, 4096, 2))
    planned = F.plan(F.FFTSpec(n=4096, kind="fft", axis=1), backend=backend)
    y = np.asarray(planned(jnp.asarray(x)))
    ref = np.asarray(jnp.fft.fft(jnp.asarray(x), axis=1))
    assert np.abs(y - ref).max() <= 1e-3 * np.abs(ref).max(), backend
