"""Multi-axis (2-D) pass programs: fft2/rfft2 as ONE compiled schedule.

The image acceptance criterion (paper §3's remote-sensing workload): a
planned ``fft2`` lowers to exactly rows+cols kernel calls with zero
standalone HBM transposes between them — the `_fft2_planes` swapaxes
sandwich is gone.  Asserted over the jaxpr like the 1-D split regime, plus
cross-backend numerical acceptance, the rfft2/irfft2 Hermitian-epilogue
kinds, the joint-program halves the distributed driver consumes, and the
2-D fft_conv2d matched-filter path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.core import fft as F
from repro.core import plan as P
from repro.core.conv import fft_conv2d, toeplitz_conv_ref

BACKENDS = ["stockham", "xla", "pallas"]


def _rand_c(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


# ---------------------------------------------------------------------------
# plan structure: one joint program, rows then in-place columns
# ---------------------------------------------------------------------------


def test_plan_fft2_is_one_joint_program():
    plan = P.plan_fft2(2048, 64)
    rows = [p for p in plan.passes if p.axis == -1]
    cols = [p for p in plan.passes if p.axis == -2]
    assert plan.n2 == 64
    assert [p.axis for p in plan.passes] == [-1] * len(rows) + [-2] * len(cols)
    assert len(cols) == 1 and cols[0].n == 64
    assert plan.hbm_round_trips == len(rows) + 1
    # split-regime rows: the 1-D program rides along unchanged
    plan = P.plan_fft2(2**17, 8)
    assert [p.axis for p in plan.passes] == [-1, -1, -2]
    assert tuple(p.n for p in plan.passes if p.axis == -1) == P.program_factors(2**17)


def test_plan_fft2_strip_mined_columns_past_fused():
    # n2 = 2¹⁷ > FUSED_MAX: ONE joint program — row pass(es) then the
    # strip-mined column factors of the n2 axis, re-tagged axis=-2 with the
    # same pencil views as the 1-D split program (the tentpole acceptance).
    plan = P.plan_fft2(512, 2**17)
    cols = [p for p in plan.passes if p.axis == -2]
    assert plan.n2 == 2**17
    assert tuple(p.n for p in cols) == P.program_factors(2**17)
    f0, f1 = P.program_factors(2**17)
    assert cols[0].view_in == (2**17 // f0, f1, f0)
    assert cols[0].twiddle_after == (f0, f1)
    assert cols[1].view_in == (f0, 1, f1)
    assert cols[1].view_out == (f0, f0, f1)  # fused natural digit write
    assert plan.hbm_round_trips == len(plan.passes)
    # column factors show up as plan leaves (LUT warm-up needs them)
    assert {f0, f1} <= {p.n for p in plan.leaf_passes}


def test_plan_fft2_gated_only_beyond_fused_squared():
    # Strip-mined columns cover n2 ≤ FUSED_MAX²; beyond that the column
    # program would need a digit-reversal relayout down axis -2.
    with pytest.raises(NotImplementedError):
        P.plan_fft2(256, 2**33)


def test_strip_mined_joint_program_beats_fallback_bytes():
    # Acceptance: modeled HBM bytes of the joint strip-mined program are
    # strictly below the per-axis composition it replaced (which paid a
    # swapaxes sandwich around its multi-pass column plan).
    rep = rl.fft2_fallback_report(512, 2**17)
    assert rep["joint_passes"] == 3  # 1 row pass + 2 strip-mined col passes
    assert rep["joint_hbm_bytes"] < rep["fallback_hbm_bytes"]
    assert rep["fallback_transpose_bytes"] > 0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_tall_image_plans_joint_and_matches_numpy(backend, rng):
    # Tall images now plan as ONE joint strip-mined program; non-native-2d
    # backends still execute it through per-axis composition, the pallas
    # backend through execute_program2d — both must match numpy.
    planned = F.plan(F.FFTSpec(n=64, kind="fft2", n2=2**17), backend=backend)
    assert planned.fft_plan is not None and planned.fft_plan.n2 == 2**17
    x = _rand_c(rng, (1, 2**17, 64))
    y = np.asarray(planned(jnp.asarray(x)))
    ref = np.fft.fft2(x)
    assert np.abs(y - ref).max() <= 1e-4 * np.abs(ref).max(), backend
    # the joint-program halves compose to the same transform
    yr, yi = planned.apply_cols(*planned.apply_rows(jnp.asarray(x.real), jnp.asarray(x.imag)))
    err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
    assert err <= 1e-4 * np.abs(ref).max(), backend


def test_describe_is_multi_axis_with_mb():
    planned = F.plan(F.FFTSpec(n=2048, n2=512, kind="fft2"), backend="pallas")
    s = planned.describe()
    assert "N=512x2048" in s
    assert "axis -2 in-place columns" in s
    assert "MB" in s
    assert "2 HBM round trip" in s


def test_pass_hbm_bytes_charge_whole_image():
    plan = P.plan_fft2(2048, 64)
    img = 64 * 2048 * 2 * 4  # split-complex f32 image bytes
    for p in plan.passes:
        other = P.pass_other(p, plan)
        assert P.pass_hbm_bytes(p, 1, other) >= 2 * img  # read + write
    total = P.program_hbm_bytes(plan.passes, 1, shape2d=(64, 2048))
    assert total >= 2 * len(plan.passes) * img


def test_fft_pass_report_2d():
    rep = rl.fft_pass_report(2048, batch=2, n2=64)
    assert rep["n2"] == 64 and rep["hbm_round_trips"] == len(rep["passes"]) == 2
    assert [e["axis"] for e in rep["passes"]] == [-1, -2]
    assert rep["modeled_hbm_bytes"] == sum(e["hbm_bytes"] for e in rep["passes"])
    assert rep["memory_s"] > 0


# ---------------------------------------------------------------------------
# schedule purity: rows+cols pallas_calls only, no HBM glue between them
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n2,n", [(512, 512), (4, 2**17), (2**17, 64)])
def test_fft2_schedule_is_pure_pass_program(n2, n):
    # (2**17, 64) is the strip-mined acceptance case: a taller-than-fused
    # image still lowers to pallas_calls + reshapes only — the column
    # digit transpose and inter-factor twiddle live inside the kernels.
    planned = F.plan(F.FFTSpec(n=n, n2=n2, kind="fft2"), backend="pallas")
    x = jnp.zeros((1, n2, n), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda a, b: planned.apply_planes(a, b))(x, x).jaxpr
    prims = [e.primitive.name for e in jaxpr.eqns]
    assert prims.count("pallas_call") == len(planned.passes), (n2, n, prims)
    # Zero standalone HBM transpose / twiddle / relayout ops between the
    # kernel calls — the row→column handoff is a free row-major reshape.
    forbidden = {"transpose", "mul", "add", "sub", "gather", "dynamic_slice"}
    assert not forbidden & set(prims), prims
    assert set(prims) <= {"pallas_call", "reshape", "device_put"}, prims


# ---------------------------------------------------------------------------
# numerical acceptance across backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n2,n", [(16, 64), (64, 128), (32, 2048), (128, 32)])
def test_fft2_matches_numpy(backend, n2, n, rng):
    x = _rand_c(rng, (2, n2, n))
    y = np.asarray(F.fft2(jnp.asarray(x), backend=backend))
    ref = np.fft.fft2(x)
    assert np.abs(y - ref).max() <= 1e-3 * np.abs(ref).max(), (backend, n2, n)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fft2_split_regime_rows(backend, rng):
    x = _rand_c(rng, (1, 4, 2**17))
    y = np.asarray(F.fft2(jnp.asarray(x), backend=backend))
    ref = np.fft.fft2(x)
    rel = np.abs(y - ref).max() / np.abs(ref).max()
    assert rel < 5e-5, (backend, rel)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fft2_ifft2_roundtrip(backend, rng):
    x = _rand_c(rng, (2, 32, 256))
    y = F.ifft2(F.fft2(jnp.asarray(x), backend=backend), backend=backend)
    np.testing.assert_allclose(np.asarray(y), x, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n2,n", [(16, 64), (64, 256)])
def test_rfft2_matches_numpy_and_roundtrips(backend, n2, n, rng):
    x = rng.standard_normal((2, n2, n)).astype(np.float32)
    Xr, Xi = F.rfft2(jnp.asarray(x), backend=backend)
    ref = np.fft.rfft2(x)
    assert Xr.shape == ref.shape
    scale = np.abs(ref).max()
    np.testing.assert_allclose(np.asarray(Xr), ref.real, atol=3e-3 * scale)
    np.testing.assert_allclose(np.asarray(Xi), ref.imag, atol=3e-3 * scale)
    back = np.asarray(F.irfft2((Xr, Xi), n, n2, backend=backend))
    np.testing.assert_allclose(back, x, atol=2e-3)


def test_rfft2_plan_carries_epilogue_and_trips():
    planned = F.plan(F.FFTSpec(n=256, n2=64, kind="rfft2"), backend="pallas")
    assert planned.epilogue is not None and planned.epilogue.kind == "rfft_recomb"
    inner, cols = planned.children
    # packed rows + recomb epilogue + column pass, in execution order
    assert planned.hbm_round_trips == inner.hbm_round_trips + 1 + cols.hbm_round_trips
    kinds = [p.kind for p in planned.passes]
    assert kinds.index("rfft_recomb") == len(inner.passes)


# ---------------------------------------------------------------------------
# joint-program halves (what the distributed pencil driver consumes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_rows_cols_compose_to_fft2(backend, rng):
    planned = F.plan(F.FFTSpec(n=256, n2=128, kind="fft2"), backend=backend)
    x = _rand_c(rng, (2, 128, 256))
    xr, xi = jnp.asarray(x.real), jnp.asarray(x.imag)
    yr, yi = planned.apply_cols(*planned.apply_rows(xr, xi))
    ref = np.fft.fft2(x)
    err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
    assert err <= 1e-3 * np.abs(ref).max(), backend


def test_ragged_width_chunk_bounds_padding():
    # rfft2's m+1-wide half-spectrum: a pow2-floored chunk of ~width would
    # pad a whole extra chunk (2x the pass); the executor's chunk keeps the
    # padding under half a chunk (floored at one 128-lane tile).
    from repro.kernels import ops

    p = P.Pass(kind="direct", n=512, view_in=(1, 1, 512), view_out=(1, 1, 512), axis=-2)
    for w in (513, 1025, 2049):
        chunk = ops.image_chunk(p, w)
        assert (-w) % chunk < max(chunk // 2, 128), (w, chunk)
        assert (-w) % chunk < w // 4  # padding waste is bounded, never ~2x
    for w in (128, 512, 2048):  # pow2 widths stay exact
        assert (-w) % ops.image_chunk(p, w) == 0


def test_ragged_width_and_chunk1_execution(rng):
    # Ragged 3·2^k widths and the chunk=1 degenerate execute correctly on
    # both program shapes: a 2-D column pass over a width-24 image, and a
    # 1-D split program with every pass forced to chunk=1.
    from repro.kernels import ops

    p = P.Pass(
        kind="direct", n=64, view_in=(1, 1, 64), view_out=(1, 1, 64),
        order="natural", axis=-2,
    )
    x = _rand_c(rng, (2, 64, 24))
    ref = np.fft.fft(x, axis=-2)
    for chunks in (None, {0: 1}):
        yr, yi = ops.execute_program2d(
            jnp.asarray(x.real), jnp.asarray(x.imag), (p,),
            interpret=True, chunks=chunks,
        )
        err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
        assert err <= 1e-4 * np.abs(ref).max(), chunks
    passes1d = P.compile_passes(1024, 256)  # (32, 32) split program
    x1 = _rand_c(rng, (2, 1024))
    ref1 = np.fft.fft(x1)
    yr, yi = ops.execute_program(
        jnp.asarray(x1.real), jnp.asarray(x1.imag), passes1d,
        interpret=True, chunks={i: 1 for i in range(len(passes1d))},
    )
    err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref1).max()
    assert err <= 1e-4 * np.abs(ref1).max()


def test_apply_cols_accepts_narrow_slab(rng):
    # The column half runs at whatever width the a2a left behind (q = n/D).
    planned = F.plan(F.FFTSpec(n=256, n2=128, kind="fft2"), backend="pallas")
    x = _rand_c(rng, (2, 128, 16))
    yr, yi = planned.apply_cols(jnp.asarray(x.real), jnp.asarray(x.imag))
    ref = np.fft.fft(x, axis=-2)
    err = np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
    assert err <= 1e-3 * np.abs(ref).max()


# ---------------------------------------------------------------------------
# fft_conv2d: the SAR matched-filter path (rfft2/irfft2 plan pair)
# ---------------------------------------------------------------------------


def _direct_conv2d(x, h):
    H, W = x.shape[-2:]
    Hh, Wh = h.shape[-2:]
    out = np.zeros(x.shape[:-2] + (H + Hh - 1, W + Wh - 1), np.float64)
    for a in range(Hh):
        for b in range(Wh):
            out[..., a : a + H, b : b + W] += h[..., a : a + 1, b : b + 1] * x
    return out


def test_fft_conv2d_matches_direct(rng):
    x = rng.standard_normal((2, 24, 50)).astype(np.float32)
    h = rng.standard_normal((3, 7)).astype(np.float32)
    ref = _direct_conv2d(x, h)
    y_full = np.asarray(fft_conv2d(jnp.asarray(x), jnp.asarray(h), mode="full"))
    np.testing.assert_allclose(y_full, ref, atol=2e-3)
    y_same = np.asarray(fft_conv2d(jnp.asarray(x), jnp.asarray(h)))
    np.testing.assert_allclose(y_same, ref[..., :24, :50], atol=2e-3)


def test_fft_conv2d_row_matched_filter(rng):
    # A (1, Lh) filter is per-row range compression: equals 1-D row convs.
    x = rng.standard_normal((16, 128)).astype(np.float32)
    h = rng.standard_normal((1, 32)).astype(np.float32)
    y = np.asarray(fft_conv2d(jnp.asarray(x), jnp.asarray(h)))
    ref = np.stack([np.convolve(row, h[0], mode="full")[:128] for row in x])
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_toeplitz_ref_exercises_every_filter(rng):
    # Regression: the oracle used to convolve every row with h[0].
    x = rng.standard_normal((4, 32))
    hs = rng.standard_normal((4, 8))
    ref = toeplitz_conv_ref(x, hs)
    manual = np.stack(
        [np.convolve(x[i], hs[i], mode="full")[:32] for i in range(4)]
    )
    np.testing.assert_allclose(ref, manual)
    # a wrong (h[0]-only) oracle would disagree on rows 1..3
    wrong = np.stack([np.convolve(x[i], hs[0], mode="full")[:32] for i in range(4)])
    assert not np.allclose(ref[1:], wrong[1:])
