"""End-to-end system behaviour: train → checkpoint → kill → resume → serve,
plus config-registry and dry-run plumbing sanity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LM_SHAPES, TrainConfig, get_config, list_archs, shapes_for
from repro.configs.reduce import make_reduced
from repro.configs.specs import decode_state_specs, input_specs


def test_registry_covers_assignment():
    archs = list_archs()
    for required in (
        "gemma3-12b", "h2o-danube-1.8b", "yi-6b", "phi4-mini-3.8b",
        "arctic-480b", "deepseek-moe-16b", "musicgen-large", "xlstm-125m",
        "zamba2-2.7b", "qwen2-vl-72b", "fftbench",
    ):
        assert required in archs


def test_assignment_dimensions_exact():
    g = get_config("gemma3-12b")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads) == (48, 3840, 16, 8)
    assert (g.d_ff, g.vocab_size) == (15360, 262144)
    a = get_config("arctic-480b")
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads) == (35, 7168, 56, 8)
    assert (a.num_experts, a.top_k, a.moe_dense_residual) == (128, 2, True)
    d = get_config("deepseek-moe-16b")
    assert (d.num_experts, d.top_k, d.num_shared_experts) == (64, 6, 2)
    q = get_config("qwen2-vl-72b")
    assert (q.num_layers, q.d_model, q.vocab_size) == (80, 8192, 152064)
    z = get_config("zamba2-2.7b")
    assert z.ssm_state == 64 and z.d_model == 2560
    x = get_config("xlstm-125m")
    assert x.d_model == 768 and x.d_ff == 0


def test_long500k_gating_matches_design():
    runs_long = {a for a in list_archs()
                 if a != "fftbench" and any(s.name == "long_500k" for s in shapes_for(a))}
    assert runs_long == {"gemma3-12b", "h2o-danube-1.8b", "xlstm-125m", "zamba2-2.7b"}


def test_input_specs_shapes():
    cfg = get_config("yi-6b")
    sp = input_specs(cfg, LM_SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["targets"].dtype == jnp.int32
    cfg_a = get_config("musicgen-large")
    sp = input_specs(cfg_a, LM_SHAPES["prefill_32k"])
    assert sp["frame_embeds"].shape == (32, 32768, 2048)
    cfg_v = get_config("qwen2-vl-72b")
    sp = input_specs(cfg_v, LM_SHAPES["train_4k"])
    assert sp["mrope_positions"].shape == (256, 3, 4096)
    assert sp["vision_embeds"].shape[1] == 1024


def test_decode_specs_build_without_allocation():
    cfg = make_reduced(get_config("zamba2-2.7b"))
    tok, caches, t = decode_state_specs(cfg, LM_SHAPES["decode_32k"])
    assert tok.shape == (128,)
    # every leaf is an abstract ShapeDtypeStruct, nothing allocated
    for leaf in jax.tree.leaves(caches):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_train_kill_resume_end_to_end(tmp_path):
    """The crash-only contract: losses after resume match an uninterrupted run."""
    from repro.launch.train import main as train_main

    args = [
        "--arch", "xlstm-125m", "--reduced", "--batch", "2", "--seq", "32",
        "--ckpt-every", "5", "--ckpt-dir", str(tmp_path),
    ]
    full = train_main(args + ["--steps", "10"])
    # fresh dir: crash after step 5 (same 10-step schedule), then resume
    import shutil

    shutil.rmtree(tmp_path)
    os.makedirs(tmp_path)
    train_main(args + ["--steps", "10", "--stop-at", "5"])
    resumed = train_main(args + ["--steps", "10"])
    np.testing.assert_allclose(full[5:], resumed, atol=2e-3)
