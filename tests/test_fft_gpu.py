"""The Pallas-on-Triton GPU backend: shared-memory-budgeted claimed leaves,
per-leaf xla fallback, negotiation precedence, crossover tuning, seed cache.

Runs on CPU hosts in Pallas interpret mode (automatic — ``should_interpret``
defaults on when ``jax.default_backend() == "cpu"``); a real GPU exercises
the Triton lowering of the identical plans with zero code changes.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_subprocess
from repro.analysis import roofline as rl
from repro.core import fft as fft_lib
from repro.core import limits
from repro.core import plan as plan_lib
from repro.core import tuning
from repro.kernels import fft_gpu


@pytest.fixture()
def fresh_plans():
    fft_lib._plan_cached.cache_clear()
    yield
    fft_lib._plan_cached.cache_clear()


def _fft_ref(x, inverse=False):
    return np.fft.ifft(x) if inverse else np.fft.fft(x)


# ---------------------------------------------------------------------------
# numerics: the acceptance sweep under interpret
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 4096, 131072])
@pytest.mark.parametrize("kind", ["fft", "ifft"])
def test_pallas_gpu_matches_xla(n, kind, rng):
    spec = fft_lib.FFTSpec(n=n, kind=kind)
    p_gpu = fft_lib.plan(spec, backend="pallas_gpu", tune="off")
    p_xla = fft_lib.plan(spec, backend="xla", tune="off")
    x = rng.standard_normal((3, n)).astype(np.float32)
    xi = rng.standard_normal((3, n)).astype(np.float32)
    yr, yi = p_gpu.apply_planes(jnp.asarray(x), jnp.asarray(xi))
    rr, ri = p_xla.apply_planes(jnp.asarray(x), jnp.asarray(xi))
    ref = np.asarray(rr) + 1j * np.asarray(ri)
    got = np.asarray(yr) + 1j * np.asarray(yi)
    rel = np.abs(got - ref).max() / max(np.abs(ref).max(), 1e-30)
    assert rel < 1e-3, (n, kind, rel)
    # and against numpy, so both backends can't be wrong together
    npref = _fft_ref(x + 1j * xi, inverse=(kind == "ifft"))
    rel_np = np.abs(got - npref).max() / max(np.abs(npref).max(), 1e-30)
    assert rel_np < 1e-3, (n, kind, rel_np)


# ---------------------------------------------------------------------------
# per-leaf claims: unclaimed passes fall back to xla INSIDE the same plan
# ---------------------------------------------------------------------------


def test_mixed_plan_claims_per_leaf():
    # 131072 = 512×256 outside the fused regime: pass 0 is a strided-column
    # transform (disclaimed — Triton leaf wants unit-stride rows), pass 1
    # the natural-order row leaf (claimed).
    p = fft_lib.plan(fft_lib.FFTSpec(n=131072), backend="pallas_gpu", tune="off")
    assert p.pass_claims == ("xla", "pallas_gpu")
    # fused-regime sizes are single-pass and fully claimed
    for n in (256, 4096):
        q = fft_lib.plan(fft_lib.FFTSpec(n=n), backend="pallas_gpu", tune="off")
        assert q.pass_claims == ("pallas_gpu",) * len(q.passes)
    # plans without a claim surface report their own name everywhere
    x = fft_lib.plan(fft_lib.FFTSpec(n=4096), backend="xla", tune="off")
    assert set(x.pass_claims) == {"xla"}


def test_gpu_claims_predicate():
    passes = plan_lib.plan_fft(131072).passes
    assert [fft_gpu.gpu_claims(p) for p in passes] == [False, True]
    assert all(fft_gpu.gpu_claims(p) for p in plan_lib.plan_fft(4096).passes)
    # column passes (axis=-2) are never claimed
    col = next(
        (p for p in plan_lib.plan_fft2(64, 131072).passes if p.axis == -2), None
    )
    assert col is not None and not fft_gpu.gpu_claims(col)


# ---------------------------------------------------------------------------
# jaxpr purity: a claimed plan is pallas_call + shape glue, nothing else
# ---------------------------------------------------------------------------

_GLUE = {
    "reshape",
    "pad",
    "slice",
    "squeeze",
    "device_put",
    "convert_element_type",
    "broadcast_in_dim",
    "pjit",
}


def _collect_prims(jaxpr, acc):
    """All primitive names, descending into pjit bodies but NOT into
    pallas_call kernels (the kernel may use any math it wants)."""
    for e in jaxpr.eqns:
        acc.append(e.primitive.name)
        if e.primitive.name == "pallas_call":
            continue
        for v in e.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                _collect_prims(inner, acc)
    return acc


@pytest.mark.parametrize("n", [256, 4096])
def test_claimed_leaf_jaxpr_is_pallas_call_plus_reshapes(n):
    p = fft_lib.plan(fft_lib.FFTSpec(n=n), backend="pallas_gpu", tune="off")
    xr = jnp.zeros((4, n), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda a, b: p.apply_planes(a, b))(xr, xr)
    prims = _collect_prims(jaxpr.jaxpr, [])
    n_calls = prims.count("pallas_call")
    assert n_calls == len(p.passes), (n_calls, len(p.passes))
    stray = [q for q in prims if q != "pallas_call" and q not in _GLUE]
    assert not stray, f"claimed leaf leaked XLA math outside the kernel: {stray}"


# ---------------------------------------------------------------------------
# the shared-memory budget model
# ---------------------------------------------------------------------------


def test_memory_budget_device_resolution():
    assert limits.memory_budget("NVIDIA A100-SXM4-40GB") == 164 * 1024
    assert limits.memory_budget("NVIDIA H100 80GB HBM3") == 228 * 1024
    assert limits.memory_budget("Tesla T4") == 64 * 1024
    assert limits.memory_budget("Tesla V100-SXM2-16GB") == 96 * 1024
    # unknown GPU-ish silicon floors at the paper's 48 KiB budget
    assert limits.memory_budget("NVIDIA GeForce RTX 5090") == limits.GPU_SMEM_DEFAULT
    # non-GPU kinds keep the TPU VMEM budget
    assert limits.memory_budget("TPU v4") == limits.VMEM_BUDGET
    assert limits.memory_budget("cpu") == limits.VMEM_BUDGET
    # None resolves the local device (cpu in this suite)
    assert limits.memory_budget() == limits.VMEM_BUDGET


@pytest.mark.parametrize("budget_kib", [48, 96, 164, 228])
def test_gpu_tiles_respect_any_budget(budget_kib):
    budget = budget_kib * 1024
    for n in (256, 4096, 65536, 131072):
        for p in plan_lib.plan_fft(n).passes:
            if not fft_gpu.gpu_claims(p):
                continue
            bt = plan_lib.pick_batch_tile_gpu(p, budget)
            assert bt >= 1
            assert plan_lib.gpu_smem_bytes(p, bt) <= budget or bt == 1, (
                n, p.kind, bt,
            )


def test_gpu_budget_shrinks_tiles():
    (p,) = plan_lib.plan_fft(4096).passes
    big = plan_lib.pick_batch_tile_gpu(p, 8 * 2**20)
    small = plan_lib.pick_batch_tile_gpu(p, 48 * 1024)
    assert small <= big and small >= 1


# ---------------------------------------------------------------------------
# roofline: shared-memory bytes + global round trips in describe()/report
# ---------------------------------------------------------------------------


def test_gpu_program_report_round_trips():
    rep = rl.gpu_program_report(
        plan_lib.plan_fft(4096).passes, fft_gpu.gpu_claims, batch=2
    )
    assert rep["claims"] == ("pallas_gpu",)
    assert rep["global_round_trips"] == 1  # fused single pass: read + write
    assert rep["smem_bytes_max"] > 0
    assert rep["smem_budget"] == limits.memory_budget()
    mixed = rl.gpu_program_report(
        plan_lib.plan_fft(131072).passes, fft_gpu.gpu_claims, batch=2
    )
    assert mixed["claims"] == ("xla", "pallas_gpu")
    # the disclaimed strided-column pass pays materialized transposes
    assert mixed["global_round_trips"] > 2
    assert mixed["modeled_global_bytes"] > rep["modeled_global_bytes"]


def test_describe_reports_gpu_account():
    d = fft_lib.plan(
        fft_lib.FFTSpec(n=131072), backend="pallas_gpu", tune="off"
    ).describe()
    assert "gpu:" in d and "global round trips" in d
    assert "smem" in d and "claims [xla, pallas_gpu]" in d
    # claim-less backends keep their describe() unchanged
    assert "gpu:" not in fft_lib.plan(
        fft_lib.FFTSpec(n=131072), backend="xla", tune="off"
    ).describe()


def test_xla_gpu_fft_bytes_monotone():
    assert rl.xla_gpu_fft_bytes(8192) > rl.xla_gpu_fft_bytes(4096) > 0
    assert rl.xla_gpu_fft_bytes(4096, batch=8) > rl.xla_gpu_fft_bytes(4096)


# ---------------------------------------------------------------------------
# negotiation precedence (satellite: platform-preferred registration order)
# ---------------------------------------------------------------------------


def test_gpu_negotiation_prefers_later_registered_backend():
    spec = fft_lib.FFTSpec(n=4096)
    # both xla and pallas_gpu prefer "gpu"; the explicitly registered
    # pallas_gpu came later, so the tie breaks toward it
    assert fft_lib._negotiate(spec, "gpu").name == "pallas_gpu"
    # cpu negotiation is untouched: xla is preferred, pallas_gpu merely runs
    assert fft_lib._negotiate(spec, "cpu").name == "xla"


def test_registered_preferred_backend_beats_default(fresh_plans):
    spec = fft_lib.FFTSpec(n=1024)
    calls = []

    def fn(xr, xi, *, inverse, planned):
        calls.append(planned.spec.n)
        return fft_lib._xla_backend(xr, xi, inverse=inverse, planned=planned)

    fft_lib.register_backend(
        "scratch_cpu",
        fn,
        fft_lib.BackendCapabilities(preferred_platforms=frozenset({"cpu"})),
    )
    try:
        # same score as the xla default on cpu — later registration wins
        assert fft_lib._negotiate(spec, "cpu").name == "scratch_cpu"
        p = fft_lib.plan(spec, tune="off")
        x = jnp.zeros((2, 1024), jnp.float32)
        p.apply_planes(x, x)
        assert calls, "negotiation never routed to the registered backend"
    finally:
        fft_lib._REGISTRY.pop("scratch_cpu", None)
        fft_lib._plan_cached.cache_clear()


# ---------------------------------------------------------------------------
# crossover tuning + seed cache
# ---------------------------------------------------------------------------


def test_backend_pick_modes():
    spec = fft_lib.FFTSpec(n=4096, kind="fft", batch_hint=2)
    assert tuning.backend_pick(spec, "gpu", "off") is None
    pick = tuning.backend_pick(spec, "gpu", "model")
    assert pick in ("pallas_gpu", "xla")
    assert tuning.backend_pick(spec, "gpu", "model") == pick  # cached
    # 2-D and real-input specs keep negotiation's answer
    assert tuning.backend_pick(
        fft_lib.FFTSpec(n=64, kind="fft2", n2=4096), "gpu", "model"
    ) is None
    assert tuning.measure_log() == ()  # model mode never timed anything


def test_seed_cache_layers_beneath_user_cache():
    seed = tuning.seed_cache()
    assert seed, "packaged tuning_seed.json missing or empty"
    key = "cpu|pallas_gpu|plan|fft|n=8192|batch=2"
    assert key in seed and seed[key]["mode"] == "measure"
    # the user cache shadows the seed on put()
    tuning.cache.put(key, {"config": {"sentinel": 1}, "mode": "measure"})
    try:
        assert tuning.cache.get(key)["config"] == {"sentinel": 1}
    finally:
        tuning.cache.clear()
    # after clearing the user layer, the seed answers again
    assert tuning.cache.get(key)["mode"] == "measure"


_SEED_BODY = r"""
from repro.core import fft as F
from repro.core import tuning

spec = F.FFTSpec(n=8192, kind="fft", batch_hint=2)
for backend in ("pallas", "pallas_gpu"):
    p = F.plan(spec, backend=backend, tune="measure")
    assert p.tuned is not None, backend
assert tuning.measure_log() == (), tuning.measure_log()
print("SEED_ZERO_MEASURE_OK")
"""


def test_seeded_spec_measures_nothing_in_fresh_process():
    # The acceptance criterion, end to end: a FRESH process (cold interning
    # cache, empty user tuning cache — conftest points REPRO_TUNING_CACHE
    # at a tempdir) plans a seeded spec under tune="measure" with zero
    # device measurements, because the packaged seed already has the
    # measured winner.
    out = run_in_subprocess(_SEED_BODY, devices=1)
    assert "SEED_ZERO_MEASURE_OK" in out
