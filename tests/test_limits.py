"""core.limits is the grep-asserted single source of every regime constant."""

import os
import re

import repro.core.limits as limits
from repro.core import overlap, plan
from repro.core.conv import next_pow2

SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src", "repro"
)

#: Assignment sites of these names may exist ONLY in core/limits.py.
CONSTANTS = ("DIRECT_MAX", "FUSED_MAX", "OS_FACTOR", "VMEM_BUDGET")


def _py_files():
    for root, _dirs, files in os.walk(SRC):
        if "__pycache__" in root:
            continue
        for f in files:
            if f.endswith(".py"):
                yield os.path.join(root, f)


def test_constants_assigned_only_in_limits():
    pattern = re.compile(
        rf"^\s*({'|'.join(CONSTANTS)})\s*(?::[^=]+)?=[^=]", re.MULTILINE
    )
    offenders = []
    for path in _py_files():
        if path.endswith(os.path.join("core", "limits.py")):
            continue
        with open(path) as f:
            text = f.read()
        for m in pattern.finditer(text):
            offenders.append((os.path.relpath(path, SRC), m.group(1)))
    assert not offenders, (
        f"regime constants re-assigned outside core/limits.py: {offenders}"
    )


def test_next_pow2_defined_only_in_limits():
    offenders = [
        os.path.relpath(p, SRC)
        for p in _py_files()
        if not p.endswith(os.path.join("core", "limits.py"))
        and re.search(r"^\s*def next_pow2\b", open(p).read(), re.MULTILINE)
    ]
    assert not offenders, offenders


def test_reexports_are_the_same_objects():
    # The historical import sites keep working and agree with the source.
    assert plan.FUSED_MAX is limits.FUSED_MAX
    assert plan.DIRECT_MAX is limits.DIRECT_MAX
    assert plan.VMEM_BUDGET is limits.VMEM_BUDGET
    assert overlap.OS_FACTOR is limits.OS_FACTOR
    assert next_pow2 is limits.next_pow2
    assert limits.next_pow2(1025) == 2048 and limits.next_pow2(1) == 1
