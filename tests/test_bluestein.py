"""Arbitrary-length FFTs: the Bluestein chirp-conv leaf.

The tentpole's acceptance gates, made literal:

* numerics — planned non-pow2 fft/ifft/rfft/irfft match ``numpy.fft`` at
  1e-3 across primes, 3·2^k, and the n=1 degenerate case;
* purity — a Bluestein leaf executes as claimed pallas_calls + shape glue
  only (jaxpr-asserted) on both the TPU and ``pallas_gpu`` interpret paths;
* interning — the chirp spectrum is computed once per interned plan: zero
  new plans on warm reuse (``plan_log()``-asserted), and the spectrum LUT
  is cache-identical across lookups.

Plus the split-regime composition, tuning knob, validation-message, and
hypothesis property sweeps that ride along.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.analysis import roofline as rl
from repro.core import fft as F
from repro.core import limits
from repro.core import plan as P
from repro.core import twiddle as tw
from repro.kernels import ops

PRIMES = [3, 7, 97, 251, 2029]
THREE_POW2 = [6, 12, 96, 1536]
SIZES = PRIMES + THREE_POW2 + [1]


def _rand_c(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


# ---------------------------------------------------------------------------
# numerics gate: primes, 3·2^k, n=1 vs numpy at 1e-3
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("backend", ["pallas", "pallas_gpu", "xla"])
def test_fft_ifft_match_numpy(n, backend, rng):
    x = _rand_c(rng, (3, n))
    tol = 1e-3 * max(np.abs(np.fft.fft(x)).max(), 1.0)
    y = np.asarray(F.plan(F.FFTSpec(n=n), backend=backend)(jnp.asarray(x)))
    np.testing.assert_allclose(y, np.fft.fft(x), atol=tol)
    z = np.asarray(
        F.plan(F.FFTSpec(n=n, kind="ifft"), backend=backend)(jnp.asarray(x))
    )
    np.testing.assert_allclose(z, np.fft.ifft(x), atol=1e-3)


@pytest.mark.parametrize("n", [n for n in SIZES if n >= 2])
def test_rfft_irfft_match_numpy(n, rng):
    x = rng.standard_normal((3, n)).astype(np.float32)
    ref = np.fft.rfft(x)
    tol = 1e-3 * max(np.abs(ref).max(), 1.0)
    Xr, Xi = F.rfft(jnp.asarray(x))
    assert Xr.shape[-1] == n // 2 + 1
    np.testing.assert_allclose(np.asarray(Xr) + 1j * np.asarray(Xi), ref, atol=tol)
    back = np.asarray(F.irfft((Xr, Xi), n))
    np.testing.assert_allclose(back, x, atol=1e-3)


def test_fft2_non_pow2_rows(rng):
    x = _rand_c(rng, (2, 16, 97))
    p = F.plan(F.FFTSpec(n=97, kind="fft2", n2=16))
    y = np.asarray(p(jnp.asarray(x)))
    ref = np.fft.fft2(x)
    np.testing.assert_allclose(y, ref, atol=1e-3 * np.abs(ref).max())


# ---------------------------------------------------------------------------
# jaxpr purity: claimed pallas_calls + shape glue only, both backends
# ---------------------------------------------------------------------------

_GLUE = {
    "reshape",
    "pad",
    "slice",
    "squeeze",
    "device_put",
    "convert_element_type",
    "broadcast_in_dim",
    "pjit",
}


def _collect_prims(jaxpr, acc):
    for e in jaxpr.eqns:
        acc.append(e.primitive.name)
        if e.primitive.name == "pallas_call":
            continue
        for v in e.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                _collect_prims(inner, acc)
    return acc


@pytest.mark.parametrize("n", [97, 2029])
@pytest.mark.parametrize("backend", ["pallas", "pallas_gpu"])
def test_bluestein_leaf_is_pallas_calls_plus_glue(n, backend):
    p = F.plan(F.FFTSpec(n=n), backend=backend, tune="off")
    assert all(k.kind == "bluestein" for k in p.passes)
    assert all(c == backend for c in p.pass_claims)
    # tile-aligned batch: no pad/unpad glue beyond the leaf's own framing
    bt = p.batch_tiles[n]
    xr = jnp.zeros((bt, n), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda a, b: p.apply_planes(a, b))(xr, xr)
    prims = _collect_prims(jaxpr.jaxpr, [])
    assert prims.count("pallas_call") == len(p.passes), prims
    stray = [q for q in prims if q != "pallas_call" and q not in _GLUE]
    assert not stray, f"Bluestein leaf leaked XLA math outside the kernel: {stray}"


# ---------------------------------------------------------------------------
# interning: one plan per spec, one chirp spectrum per (n, pad, dir)
# ---------------------------------------------------------------------------


def test_warm_reuse_plans_nothing(rng):
    spec = F.FFTSpec(n=251)
    p = F.plan(spec)  # cold: intern the plan + chirp LUTs
    x = _rand_c(rng, (2, 251))
    p(jnp.asarray(x))
    F.clear_plan_log()
    for _ in range(3):
        q = F.plan(spec)
        assert q is p
        q(jnp.asarray(x))
    assert len(F.plan_log()) == 0, F.plan_log()


def test_chirp_spectrum_cached_identity():
    a = tw.bluestein_spectrum(97, 256)
    b = tw.bluestein_spectrum(97, 256)
    assert a is b  # lru-cached: computed once, interned like twiddle LUTs
    assert tw.bluestein_chirp(97) is tw.bluestein_chirp(97)
    assert tw.bluestein_spectrum(97, 512) is not a  # pad is part of the key


# ---------------------------------------------------------------------------
# program shapes: fused 2-pass leaf, split composition, limits helpers
# ---------------------------------------------------------------------------


def test_fused_bluestein_is_two_passes():
    prog = P.compile_bluestein(2029)
    assert [(p.kind, p.stage) for p in prog] == [
        ("bluestein", "fwd"),
        ("bluestein", "inv"),
    ]
    assert prog[0].n1 == limits.bluestein_pad(2029) == 4096


def test_split_bluestein_composes_with_pass_programs(rng):
    # Force the inner pow2 conv past fused_max: the chirp stages become
    # standalone passes around the inner split-regime programs.
    plan = P.plan_fft(300, fused_max=256)
    kinds = [p.kind for p in plan.passes]
    assert kinds.count("bluestein") >= 3  # pre / mul / post at least
    assert any(k != "bluestein" for k in kinds)  # inner pow2 program inlined
    x = _rand_c(rng, (2, 300))
    yr, yi = ops.execute_plan(
        jnp.asarray(x.real), jnp.asarray(x.imag), plan, interpret=True
    )
    ref = np.fft.fft(x)
    np.testing.assert_allclose(
        np.asarray(yr) + 1j * np.asarray(yi), ref, atol=1e-3 * np.abs(ref).max()
    )


def test_limits_helpers():
    assert limits.next_fast_len(48) == 64
    assert limits.bluestein_pad(97) == 256  # next_pow2(2*97 - 1)
    assert limits.bluestein_pad(2029) == 4096
    assert limits.BLUESTEIN_MIN == 2


def test_tuning_pad_knob(rng):
    # The chirp pad length is a searchable knob: 2x the minimal pad is a
    # legal plan and still correct.
    pad = 2 * limits.bluestein_pad(97)
    plan = P.plan_fft(97, pad=pad)
    assert plan.passes[0].n1 == pad
    x = _rand_c(rng, (2, 97))
    yr, yi = ops.execute_plan(
        jnp.asarray(x.real), jnp.asarray(x.imag), plan, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(yr) + 1j * np.asarray(yi), np.fft.fft(x), atol=1e-2
    )
    with pytest.raises(ValueError):
        P.plan_fft(128, pad=512)  # pad is a Bluestein-only knob


# ---------------------------------------------------------------------------
# validation messages + roofline report
# ---------------------------------------------------------------------------


def test_validation_errors_name_the_route():
    with pytest.raises(ValueError, match="Bluestein"):
        F.FFTSpec(n=48, kind="rfft2", n2=64)
    with pytest.raises(ValueError, match="fft"):
        F.FFTSpec(n=64, kind="dct")
    with pytest.raises(ValueError):
        F.FFTSpec(n=0)


def test_bluestein_report():
    rep = rl.bluestein_report(2029)
    assert rep["pad"] == 4096
    assert 2.0 <= rep["pad_ratio"] <= 2.1
    assert rep["flops_overhead"] > 1.0
    assert rep["hbm_round_trips"] == 2
    with pytest.raises(ValueError):
        rl.bluestein_report(1024)  # pow2 lengths don't pay the chirp tax


def test_describe_surfaces_the_tax():
    d = F.plan(F.FFTSpec(n=2029)).describe()
    assert "bluestein" in d and "pad 4096" in d


# ---------------------------------------------------------------------------
# hypothesis property sweep: random n ∈ [2, 4096]
# ---------------------------------------------------------------------------


@given(n=st.integers(min_value=2, max_value=4096))
@settings(max_examples=20, deadline=None)
def test_property_random_n_matches_numpy(n):
    rng = np.random.default_rng(n)
    x = _rand_c(rng, (2, n))
    spec = F.FFTSpec(n=n)
    p = F.plan(spec)
    assert F.plan(spec) is p  # plan-cache interning across repeated specs
    y = np.asarray(p(jnp.asarray(x)))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(y, ref, atol=1e-3 * max(np.abs(ref).max(), 1.0))
