"""Distributed pencil FFT — runs in a subprocess with 8 fake devices so the
rest of the suite keeps the default single-device environment."""

import pytest

from conftest import run_in_subprocess

_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D

mesh = jax.make_mesh((8,), ('x',))
np.random.seed(0)

# ---- 1-D forward, natural order ------------------------------------------
for n in (1024, 8192):
    x = (np.random.randn(2, n) + 1j*np.random.randn(2, n)).astype(np.complex64)
    xr, xi = jnp.asarray(x.real), jnp.asarray(x.imag)
    ref = np.fft.fft(x)
    yr, yi = D.pfft_sharded(xr, xi, mesh, 'x')
    rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / np.abs(ref).max()
    assert rel < 5e-5, ('natural', n, rel)

    # ---- pencil layout + inverse-from-pencil (the 4-a2a conv path) -------
    pr, pi = D.pfft_sharded(xr, xi, mesh, 'x', natural_order=False)
    zr, zi = D.pifft_sharded(pr, pi, mesh, 'x', from_pencil=True)
    err = np.abs((np.asarray(zr)+1j*np.asarray(zi)) - x).max()
    assert err < 5e-5, ('pencil roundtrip', n, err)

    # pencil layout semantics: [k1, k2] holds X[k1 + n1*k2]
    n1, n2 = D.pencil_factors(n, 8)
    pen = (np.asarray(pr)+1j*np.asarray(pi)).reshape(2, n1, n2)
    perm = ref.reshape(2, n2, n1).transpose(0, 2, 1)
    rel = np.abs(pen - perm).max() / np.abs(ref).max()
    assert rel < 5e-5, ('pencil layout', n, rel)

    # ---- natural-order inverse -------------------------------------------
    zr, zi = D.pifft_sharded(yr, yi, mesh, 'x')
    err = np.abs((np.asarray(zr)+1j*np.asarray(zi)) - x).max()
    assert err < 5e-5, ('natural roundtrip', n, err)

# ---- inverse via pfft(inverse=True) ---------------------------------------
x = (np.random.randn(1, 2048) + 1j*np.random.randn(1, 2048)).astype(np.complex64)
ref = np.fft.ifft(x)
yr, yi = D.pfft_sharded(jnp.asarray(x.real), jnp.asarray(x.imag), mesh, 'x', inverse=True)
rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / (np.abs(ref).max())
assert rel < 5e-5, ('pfft inverse', rel)

# ---- 2-D (SAR layout): rows sharded --------------------------------------
from jax.sharding import NamedSharding, PartitionSpec as P
n1, n2 = 128, 256
img = (np.random.randn(2, n1, n2) + 1j*np.random.randn(2, n1, n2)).astype(np.complex64)
spec = P(None, 'x', None)
fn = D.shard_map_compat(
    lambda xr, xi: D.pfft2d(xr, xi, n1=n1, n2=n2, axis_name='x', num_shards=8),
    mesh, in_specs=(spec, spec), out_specs=(spec, spec))
yr, yi = fn(jnp.asarray(img.real), jnp.asarray(img.imag))
ref2 = np.fft.fft2(img)
rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref2).max() / np.abs(ref2).max()
assert rel < 5e-5, ('fft2d', rel)

print('DISTRIBUTED_FFT_OK')
"""


@pytest.mark.slow
def test_distributed_fft_8dev():
    out = run_in_subprocess(_BODY, devices=8)
    assert "DISTRIBUTED_FFT_OK" in out


_GRAD_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D

mesh = jax.make_mesh((8,), ('x',))
n = 1024
np.random.seed(1)
x = np.random.randn(2, n).astype(np.float32)

def loss(xr):
    yr, yi = D.pfft_sharded(xr, jnp.zeros_like(xr), mesh, 'x')
    return jnp.sum(yr**2 + yi**2)

g = jax.grad(loss)(jnp.asarray(x))
# Parseval: d/dx sum|FFT(x)|^2 = 2*n*x
np.testing.assert_allclose(np.asarray(g), 2*n*x, rtol=1e-3)
print('DIST_GRAD_OK')
"""


@pytest.mark.slow
def test_distributed_fft_differentiable():
    out = run_in_subprocess(_GRAD_BODY, devices=8)
    assert "DIST_GRAD_OK" in out


_CONV_OS_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D
from repro.core.conv import fft_conv

mesh = jax.make_mesh((8,), ('x',))
np.random.seed(3)
x = np.random.randn(2, 50000).astype(np.float32)
h = np.random.randn(257,).astype(np.float32)

y = np.asarray(D.pconv_os_sharded(jnp.asarray(x), jnp.asarray(h), mesh, 'x',
                                  block=1024))
ref = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h), overlap_save=False))
rel = np.abs(y - ref).max() / np.abs(ref).max()
assert rel < 1e-4, ('pconv_os', rel)

# blocks are embarrassingly parallel: ZERO collectives in the program
jx = str(jax.make_jaxpr(
    lambda a, b: D.pconv_os_sharded(a, b, mesh, 'x', block=1024)
)(jnp.asarray(x), jnp.asarray(h)))
for coll in ('all_to_all', 'all_gather', 'psum', 'ppermute'):
    assert coll not in jx, coll
print('PCONV_OS_OK')
"""


@pytest.mark.slow
def test_distributed_overlap_save_conv_8dev():
    out = run_in_subprocess(_CONV_OS_BODY, devices=8)
    assert "PCONV_OS_OK" in out


_PACKED_BODY = r"""
import os, tempfile
# Fresh cache path: proves the pencil decisions themselves never write a
# cache, independent of what other suites left in the session-wide file.
os.environ['REPRO_TUNING_CACHE'] = os.path.join(
    tempfile.mkdtemp(), 'tuning.json')
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import distributed as D
from repro.core import tuning

mesh = jax.make_mesh((8,), ('x',))
n = 8192
x = jnp.zeros((n,), jnp.float32)

def n_a2a(fn):
    sm = D.shard_map_compat(fn, mesh, in_specs=(P('x'), P('x')),
                            out_specs=(P('x'), P('x')))
    return str(jax.make_jaxpr(sm)(x, x)).count('all_to_all')

def fwd(natural, **kw):
    return lambda xr, xi: D.pfft(xr, xi, n=n, axis_name='x', num_shards=8,
                                 natural_order=natural, **kw)

def inv(from_pencil, **kw):
    return lambda xr, xi: D.pifft(xr, xi, n=n, axis_name='x', num_shards=8,
                                  from_pencil=from_pencil, **kw)

# Packed split-complex: ONE stacked a2a per transpose.  The default path's
# count follows the tuned chunk count K: 2K + 1 natural, 2K pencil.
K_nat = D.plan_pencil(n, 8).a2a_chunks
K_pen = D.plan_pencil(n, 8, natural_order=False).a2a_chunks
assert n_a2a(fwd(True)) == 2 * K_nat + 1, (n_a2a(fwd(True)), K_nat)
assert n_a2a(fwd(False)) == 2 * K_pen, (n_a2a(fwd(False)), K_pen)
assert n_a2a(inv(False)) == 2 * K_nat + 1
assert n_a2a(inv(True)) == 2 * K_pen

# Forcing K pins the count exactly: K=1 is the flat packed pipeline (3
# collectives, was 6 per-plane calls), K=2 double-buffers the middle (5).
assert n_a2a(fwd(True, chunks=1)) == 3
assert n_a2a(fwd(True, chunks=2)) == 5
assert n_a2a(fwd(False, chunks=1)) == 2
assert n_a2a(inv(False, chunks=2)) == 5

# Legacy per-plane baseline kept for A/B: two a2a per step.
assert n_a2a(fwd(True, pack=False)) == 6
assert n_a2a(fwd(False, pack=False)) == 4
assert n_a2a(inv(False, pack=False)) == 6

# pfft2d: one packed a2a per transpose (2), per-plane legacy 4.
img = jnp.zeros((8, 128, 256), jnp.float32)
def n_a2a_2d(pack):
    sm = D.shard_map_compat(
        lambda xr, xi: D.pfft2d(xr, xi, n1=128, n2=256, axis_name='x',
                                num_shards=8, pack=pack),
        mesh, in_specs=(P(None, 'x'), P(None, 'x')),
        out_specs=(P(None, 'x'), P(None, 'x')))
    return str(jax.make_jaxpr(sm)(img, img)).count('all_to_all')
assert n_a2a_2d(True) == 2, n_a2a_2d(True)
assert n_a2a_2d(False) == 4, n_a2a_2d(False)

# Chunked overlap stays correct, not just countable.
np.random.seed(5)
xv = (np.random.randn(2, n) + 1j*np.random.randn(2, n)).astype(np.complex64)
ref = np.fft.fft(xv)
yr, yi = D.pfft_sharded(jnp.asarray(xv.real), jnp.asarray(xv.imag), mesh, 'x',
                        chunks=2)
rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / np.abs(ref).max()
assert rel < 5e-5, ('chunked numerics', rel)

# All tuned decisions above were modeled, never measured: no timings, and
# nothing leaked into the persistent cache.
assert tuning.measure_log() == (), tuning.measure_log()
assert not os.path.exists(tuning.cache_path()), tuning.cache_path()
print('PACKED_A2A_OK')
"""


@pytest.mark.slow
def test_packed_collective_counts_8dev():
    out = run_in_subprocess(_PACKED_BODY, devices=8)
    assert "PACKED_A2A_OK" in out


_TUNE_DET_BODY = r"""
import json, os, tempfile
os.environ['REPRO_TUNING_CACHE'] = os.path.join(
    tempfile.mkdtemp(), 'tuning.json')
from repro.core import tuning

picks = {}
for n in (4096, 8192, 65536):
    for d in (8, 16):
        for nat in (True, False):
            cfg = tuning.pencil_config(n, d, natural_order=nat)
            picks[f'{n}/{d}/{nat}'] = cfg
            # tune="measure" must clamp to the same modeled pick: an SPMD
            # host is never allowed to time its way to a private config.
            assert tuning.pencil_config(n, d, tune='measure',
                                        natural_order=nat) == cfg
assert tuning.measure_log() == ()
assert not os.path.exists(tuning.cache_path())
print('PICKS=' + json.dumps(picks, sort_keys=True))
"""


@pytest.mark.slow
def test_pencil_tuning_deterministic_across_processes():
    """Two fresh processes must derive the identical modeled pencil config
    with no cache file mediating — the SPMD-safety contract."""
    outs = [run_in_subprocess(_TUNE_DET_BODY, devices=8) for _ in range(2)]
    lines = [
        next(ln for ln in o.splitlines() if ln.startswith("PICKS="))
        for o in outs
    ]
    assert lines[0] == lines[1]


_NONSQUARE_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D

d = {devices}
mesh = jax.make_mesh((d,), ('x',))
np.random.seed(7)
for n in (2048, 32768):
    n1, n2 = D.pencil_factors(n, d)
    assert n1 != n2 and n1 % d == 0 and n2 % d == 0, (n, n1, n2)
    x = (np.random.randn(2, n) + 1j*np.random.randn(2, n)).astype(np.complex64)
    ref = np.fft.fft(x)
    yr, yi = D.pfft_sharded(jnp.asarray(x.real), jnp.asarray(x.imag), mesh, 'x')
    rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / np.abs(ref).max()
    assert rel < 5e-5, ('nonsquare', n, d, rel)
    zr, zi = D.pifft_sharded(yr, yi, mesh, 'x')
    err = np.abs((np.asarray(zr)+1j*np.asarray(zi)) - x).max()
    assert err < 5e-5, ('nonsquare roundtrip', n, d, err)

# explicit factors override flows through the plan layer
n = 8192
x = (np.random.randn(1, n) + 1j*np.random.randn(1, n)).astype(np.complex64)
ref = np.fft.fft(x)
yr, yi = D.pfft_sharded(jnp.asarray(x.real), jnp.asarray(x.imag), mesh, 'x',
                        factors=(512, 16))
rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / np.abs(ref).max()
assert rel < 5e-5, ('factors override', rel)
print('NONSQUARE_OK')
"""


@pytest.mark.slow
@pytest.mark.parametrize("devices", [8, 16])
def test_nonsquare_factors(devices):
    out = run_in_subprocess(_NONSQUARE_BODY.format(devices=devices),
                            devices=devices)
    assert "NONSQUARE_OK" in out
