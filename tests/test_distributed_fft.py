"""Distributed pencil FFT — runs in a subprocess with 8 fake devices so the
rest of the suite keeps the default single-device environment."""

import pytest

from conftest import run_in_subprocess

_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D

mesh = jax.make_mesh((8,), ('x',))
np.random.seed(0)

# ---- 1-D forward, natural order ------------------------------------------
for n in (1024, 8192):
    x = (np.random.randn(2, n) + 1j*np.random.randn(2, n)).astype(np.complex64)
    xr, xi = jnp.asarray(x.real), jnp.asarray(x.imag)
    ref = np.fft.fft(x)
    yr, yi = D.pfft_sharded(xr, xi, mesh, 'x')
    rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / np.abs(ref).max()
    assert rel < 5e-5, ('natural', n, rel)

    # ---- pencil layout + inverse-from-pencil (the 4-a2a conv path) -------
    pr, pi = D.pfft_sharded(xr, xi, mesh, 'x', natural_order=False)
    zr, zi = D.pifft_sharded(pr, pi, mesh, 'x', from_pencil=True)
    err = np.abs((np.asarray(zr)+1j*np.asarray(zi)) - x).max()
    assert err < 5e-5, ('pencil roundtrip', n, err)

    # pencil layout semantics: [k1, k2] holds X[k1 + n1*k2]
    n1, n2 = D.pencil_factors(n, 8)
    pen = (np.asarray(pr)+1j*np.asarray(pi)).reshape(2, n1, n2)
    perm = ref.reshape(2, n2, n1).transpose(0, 2, 1)
    rel = np.abs(pen - perm).max() / np.abs(ref).max()
    assert rel < 5e-5, ('pencil layout', n, rel)

    # ---- natural-order inverse -------------------------------------------
    zr, zi = D.pifft_sharded(yr, yi, mesh, 'x')
    err = np.abs((np.asarray(zr)+1j*np.asarray(zi)) - x).max()
    assert err < 5e-5, ('natural roundtrip', n, err)

# ---- inverse via pfft(inverse=True) ---------------------------------------
x = (np.random.randn(1, 2048) + 1j*np.random.randn(1, 2048)).astype(np.complex64)
ref = np.fft.ifft(x)
yr, yi = D.pfft_sharded(jnp.asarray(x.real), jnp.asarray(x.imag), mesh, 'x', inverse=True)
rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref).max() / (np.abs(ref).max())
assert rel < 5e-5, ('pfft inverse', rel)

# ---- 2-D (SAR layout): rows sharded --------------------------------------
from jax.sharding import NamedSharding, PartitionSpec as P
n1, n2 = 128, 256
img = (np.random.randn(2, n1, n2) + 1j*np.random.randn(2, n1, n2)).astype(np.complex64)
spec = P(None, 'x', None)
fn = D.shard_map_compat(
    lambda xr, xi: D.pfft2d(xr, xi, n1=n1, n2=n2, axis_name='x', num_shards=8),
    mesh, in_specs=(spec, spec), out_specs=(spec, spec))
yr, yi = fn(jnp.asarray(img.real), jnp.asarray(img.imag))
ref2 = np.fft.fft2(img)
rel = np.abs((np.asarray(yr)+1j*np.asarray(yi)) - ref2).max() / np.abs(ref2).max()
assert rel < 5e-5, ('fft2d', rel)

print('DISTRIBUTED_FFT_OK')
"""


@pytest.mark.slow
def test_distributed_fft_8dev():
    out = run_in_subprocess(_BODY, devices=8)
    assert "DISTRIBUTED_FFT_OK" in out


_GRAD_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D

mesh = jax.make_mesh((8,), ('x',))
n = 1024
np.random.seed(1)
x = np.random.randn(2, n).astype(np.float32)

def loss(xr):
    yr, yi = D.pfft_sharded(xr, jnp.zeros_like(xr), mesh, 'x')
    return jnp.sum(yr**2 + yi**2)

g = jax.grad(loss)(jnp.asarray(x))
# Parseval: d/dx sum|FFT(x)|^2 = 2*n*x
np.testing.assert_allclose(np.asarray(g), 2*n*x, rtol=1e-3)
print('DIST_GRAD_OK')
"""


@pytest.mark.slow
def test_distributed_fft_differentiable():
    out = run_in_subprocess(_GRAD_BODY, devices=8)
    assert "DIST_GRAD_OK" in out


_CONV_OS_BODY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as D
from repro.core.conv import fft_conv

mesh = jax.make_mesh((8,), ('x',))
np.random.seed(3)
x = np.random.randn(2, 50000).astype(np.float32)
h = np.random.randn(257,).astype(np.float32)

y = np.asarray(D.pconv_os_sharded(jnp.asarray(x), jnp.asarray(h), mesh, 'x',
                                  block=1024))
ref = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h), overlap_save=False))
rel = np.abs(y - ref).max() / np.abs(ref).max()
assert rel < 1e-4, ('pconv_os', rel)

# blocks are embarrassingly parallel: ZERO collectives in the program
jx = str(jax.make_jaxpr(
    lambda a, b: D.pconv_os_sharded(a, b, mesh, 'x', block=1024)
)(jnp.asarray(x), jnp.asarray(h)))
for coll in ('all_to_all', 'all_gather', 'psum', 'ppermute'):
    assert coll not in jx, coll
print('PCONV_OS_OK')
"""


@pytest.mark.slow
def test_distributed_overlap_save_conv_8dev():
    out = run_in_subprocess(_CONV_OS_BODY, devices=8)
    assert "PCONV_OS_OK" in out
