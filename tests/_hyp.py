"""Optional-hypothesis shim: property tests skip cleanly when the dev extra
is not installed, while the deterministic tests in the same files still run.

Usage (instead of importing hypothesis directly):

    from _hyp import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for hypothesis.strategies: placeholders only — the test
        body never runs because ``given`` skips it."""

        @staticmethod
        def sampled_from(values):
            return None

        @staticmethod
        def integers(min_value=None, max_value=None):
            return None

    st = _Strategies()

    def settings(**kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed (dev extra)")(
                fn
            )

        return deco
