"""Layer-level unit tests: rope, norms, MoE invariants, SWA masks, SSD."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.layers import rope as rope_lib
from repro.models.layers.moe import moe_apply, moe_init, _capacity
from repro.models.layers.norms import rms_norm, rms_norm_init
from repro.utils.params import unzip


# ---------------------------------------------------------------- rope
def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = rope_lib.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

    def dot_at(m, n):
        pm = jnp.asarray([[m]], jnp.int32)
        pn = jnp.asarray([[n]], jnp.int32)
        qr = rope_lib.apply_rope(q, pm, 100.0)
        kr = rope_lib.apply_rope(k, pn, 100.0)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-4


def test_mrope_text_equals_standard_rope():
    """With t == h == w == position, M-RoPE must equal standard RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6)).astype(jnp.int32)
    mpos = jnp.broadcast_to(pos[:, None, :], (2, 3, 6))
    a = rope_lib.apply_rope(x, pos, 1000.0)
    b = rope_lib.apply_mrope(x, mpos, 1000.0, (4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------- norms
def test_rms_norm_scale_invariance():
    p = rms_norm_init(32)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    y1 = rms_norm(p, x)
    y2 = rms_norm(p, 7.3 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ---------------------------------------------------------------- MoE
def _moe_cfg(**kw):
    d = dict(
        family="moe", d_model=32, d_ff=16, num_experts=8, top_k=2,
        capacity_factor=1.5, vocab_size=64,
    )
    d.update(kw)
    return ModelConfig(**d)


def test_moe_output_shape_and_aux():
    cfg = _moe_cfg()
    params, _ = unzip(moe_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe_apply(params, x, cfg=cfg)
    assert y.shape == x.shape
    assert float(aux) > 0.0  # load-balance loss is positive by construction
    assert bool(jnp.isfinite(y).all())


def test_moe_capacity_is_aligned():
    cfg = _moe_cfg()
    c = _capacity(4096, cfg)
    assert c % 8 == 0
    assert c >= 4096 * cfg.top_k / cfg.num_experts


def test_moe_zero_capacity_drop_graceful():
    """With a tiny capacity factor most tokens drop but nothing breaks."""
    cfg = _moe_cfg(capacity_factor=0.01)
    params, _ = unzip(moe_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    y, _ = moe_apply(params, x, cfg=cfg)
    assert bool(jnp.isfinite(y).all())


def test_moe_is_differentiable():
    cfg = _moe_cfg()
    params, _ = unzip(moe_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))

    def loss(p):
        y, aux = moe_apply(p, x, cfg=cfg)
        return jnp.sum(y**2) + aux

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


# ------------------------------------------------------- sliding window
def test_swa_matches_naive_masked_attention():
    from repro.models.layers.attention import attn_forward, attn_init

    cfg = ModelConfig(
        d_model=32, num_heads=2, num_kv_heads=2, vocab_size=64,
        sliding_window=4, attn_chunk=4, attn_chunk_threshold=8,
    )
    params, _ = unzip(attn_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16)).astype(jnp.int32)
    # chunked+banded path (S=16 > threshold 8)
    y_band = attn_forward(params, x, cfg=cfg, positions=pos, window=4)
    # full path (raise threshold)
    import dataclasses

    cfg_full = dataclasses.replace(cfg, attn_chunk_threshold=64)
    y_full = attn_forward(params, x, cfg=cfg_full, positions=pos, window=4)
    np.testing.assert_allclose(np.asarray(y_band), np.asarray(y_full), atol=2e-3)


# ---------------------------------------------------------------- SSD
def test_mamba2_chunked_invariant_to_chunk_size():
    import dataclasses

    from repro.models.layers import ssm

    base = ModelConfig(d_model=32, ssm_state=8, ssm_heads=4, ssm_expand=2, vocab_size=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    outs = []
    for q in (2, 4, 8, 16):
        cfg = dataclasses.replace(base, chunk_size=q)
        params, _ = unzip(ssm.mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32))
        outs.append(np.asarray(ssm.mamba2_forward(params, x, cfg=cfg)))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=2e-4)
