"""Training substrate: convergence, compression, optimizers, schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLM, host_batch_slice, make_batch
from repro.train.compression import compress_grads, init_error_state, quantize_int8
from repro.train.optimizer import clip_by_global_norm, global_norm, make_optimizer
from repro.train.schedule import make_schedule
from repro.train.train_loop import init_train_state, make_train_step

CFG = ModelConfig(
    family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, loss_chunk=16,
)


def _run(tc, steps=25, cfg=CFG):
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    losses = []
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_adamw():
    losses = _run(TrainConfig(total_steps=25, warmup_steps=5, learning_rate=1e-3))
    assert losses[-1] < losses[0] - 0.1, losses[::6]


def test_loss_decreases_adafactor():
    losses = _run(
        TrainConfig(optimizer="adafactor", total_steps=25, warmup_steps=5, learning_rate=1e-2)
    )
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equivalence():
    """Grad accumulation over microbatches ≈ one big batch step."""
    tc1 = TrainConfig(total_steps=5, warmup_steps=1, learning_rate=1e-3, microbatches=1)
    tc4 = TrainConfig(total_steps=5, warmup_steps=1, learning_rate=1e-3, microbatches=4)
    s1 = init_train_state(jax.random.PRNGKey(0), CFG, tc1)
    s4 = init_train_state(jax.random.PRNGKey(0), CFG, tc4)
    batch = {k: jnp.asarray(v) for k, v in make_batch(
        DataConfig(vocab_size=512, seq_len=64, global_batch=8), 0).items()}
    s1n, m1 = jax.jit(make_train_step(CFG, tc1))(s1, batch)
    s4n, m4 = jax.jit(make_train_step(CFG, tc4))(s4, batch)
    # parameters after one step should be close (mean-of-grads identical up
    # to reduction order & loss-chunk normalisation differences)
    l1 = jax.tree.leaves(s1n.params)
    l4 = jax.tree.leaves(s4n.params)
    worst = max(float(jnp.abs(a - b).max()) for a, b in zip(l1, l4))
    assert worst < 5e-3, worst


def test_grad_compression_error_feedback():
    grads = {"w": jnp.asarray(np.linspace(-1.0, 1.0, 101, dtype=np.float32))}
    err = init_error_state(grads)
    deq, err2 = compress_grads(grads, err)
    # dequantised close to the true grads
    assert float(jnp.abs(deq["w"] - grads["w"]).max()) < 1e-2
    # residual carries what was lost
    np.testing.assert_allclose(
        np.asarray(deq["w"] + err2["w"]), np.asarray(grads["w"]), atol=1e-6
    )


def test_quantize_int8_range():
    q, s = quantize_int8(jnp.asarray([-3.0, 0.0, 3.0]))
    assert q.dtype == jnp.int8
    assert int(q[0]) == -127 and int(q[2]) == 127


def test_compressed_training_still_converges():
    losses = _run(
        TrainConfig(total_steps=25, warmup_steps=5, learning_rate=1e-3, grad_compression=True)
    )
    assert losses[-1] < losses[0] - 0.1


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-4
    assert float(norm) > 100.0


def test_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr = make_schedule(tc)
    assert abs(float(lr(0)) - 1e-4) < 1e-9  # step 0 trains at peak/warmup
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(55)) < 1e-3
    assert float(lr(100)) < 1e-5


def test_sgd_runs():
    losses = _run(TrainConfig(optimizer="sgd", total_steps=10, warmup_steps=2, learning_rate=1e-2), steps=10)
    assert np.isfinite(losses).all()


# ---------------------------------------------------------------- data
def test_data_determinism():
    d = DataConfig(vocab_size=128, seq_len=32, global_batch=4)
    b1 = make_batch(d, 7)
    b2 = make_batch(d, 7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(d, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_iterator_state_restore():
    d = DataConfig(vocab_size=128, seq_len=32, global_batch=4)
    it = SyntheticLM(d)
    for _ in range(3):
        next(it)
    st = it.state()
    a = next(it)
    it2 = SyntheticLM.restore(d, st)
    b = next(it2)
    assert np.array_equal(a["tokens"], b["tokens"])


def test_host_batch_slice():
    d = DataConfig(vocab_size=128, seq_len=32, global_batch=8)
    b = make_batch(d, 0)
    s0 = host_batch_slice(b, 0, 4)
    s3 = host_batch_slice(b, 3, 4)
    assert s0["tokens"].shape == (2, 32)
    assert np.array_equal(s3["tokens"], b["tokens"][6:8])


def test_tokens_in_vocab_range():
    d = DataConfig(vocab_size=128, seq_len=64, global_batch=4)
    b = make_batch(d, 0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
