"""Serving subsystem: phases, EOS discipline, sampling, plan discipline.

Covers the three-phase engine (prefill / insert / generate), the
ServeSession slot pool, nucleus sampling, and the serving-specific
invariants: finished slots freeze (caches and emissions), a request
inserted into a RUNNING batch decodes exactly like a solo run (the
spectral stream re-phasing path), stream mode equals the ring-buffer
oracle, and a warm generate loop creates zero new FFT plans."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import fft as fft_lib
from repro.models import model as M
from repro.serving.engine import Engine, ServeConfig
from repro.serving.sampling import sample
from repro.serving.spectral_serve import ServeSession, sweep_once

CFG = ModelConfig(
    family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, block_pattern=("spectral", "attn"),
    spectral_filter_len=8, compute_dtype="float32",
)


@pytest.fixture(scope="module")
def params():
    p, _ = M.init_unzipped(jax.random.PRNGKey(0), CFG)
    return p


@pytest.fixture(scope="module")
def prompts():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 10), 4, CFG.vocab_size)


def _greedy(params, max_new=8, **cfg_overrides):
    cfg = dataclasses.replace(CFG, **cfg_overrides) if cfg_overrides else CFG
    return Engine(cfg, params, ServeConfig(max_new=max_new))


# -- sampling ---------------------------------------------------------------


def test_top_p_restricts_support_and_matches_distribution():
    """top_p=0.7 over p=[.5,.3,.15,.05] keeps exactly {0,1}; renormalized
    P(0) = .5/.8 = .625.  Seeded frequency check over 4000 draws."""
    logits = jnp.log(jnp.asarray([0.5, 0.3, 0.15, 0.05]))[None, :]
    keys = jax.random.split(jax.random.PRNGKey(7), 4000)
    draws = jax.vmap(
        lambda k: sample(k, logits, temperature=1.0, top_p=0.7)[0]
    )(keys)
    counts = np.bincount(np.asarray(draws), minlength=4)
    assert counts[2] == 0 and counts[3] == 0, "tokens outside the nucleus sampled"
    freq0 = counts[0] / counts.sum()
    assert abs(freq0 - 0.625) < 0.05, freq0


def test_top_p_keeps_argmax():
    logits = jnp.log(jnp.asarray([0.9, 0.05, 0.03, 0.02]))[None, :]
    keys = jax.random.split(jax.random.PRNGKey(3), 64)
    draws = jax.vmap(
        lambda k: sample(k, logits, temperature=1.0, top_p=1e-6)[0]
    )(keys)
    assert (np.asarray(draws) == 0).all(), "tiny top_p must degenerate to argmax"


def test_top_k_and_top_p_compose():
    """k filters first, p renormalizes over the survivors."""
    logits = jnp.log(jnp.asarray([0.4, 0.3, 0.2, 0.1]))[None, :]
    keys = jax.random.split(jax.random.PRNGKey(5), 512)
    draws = jax.vmap(
        lambda k: sample(k, logits, temperature=1.0, top_k=3, top_p=0.5)[0]
    )(keys)
    # k=3 drops token 3; within {.4,.3,.2}/.9 the nucleus at .5 keeps {0,1}
    assert set(np.asarray(draws).tolist()) <= {0, 1}


# -- EOS discipline ---------------------------------------------------------


def test_eos_freezes_slot_and_pads_output(params, prompts):
    """Once a slot emits EOS, every later emission is EOS and the slot's
    cache rows stop changing (including the very first sampled token)."""
    free = Engine(CFG, params, ServeConfig(max_new=10, eos_id=-1))
    ref = np.asarray(free.generate(prompts))  # eos_id=-1: nothing matches
    eos = int(ref[0, 3])  # force row 0 to finish after 4 tokens
    eng = Engine(CFG, params, ServeConfig(max_new=10, eos_id=eos))
    out = np.asarray(eng.generate(prompts))
    assert out[0, 3] == eos
    assert (out[0, 4:] == eos).all(), "emissions after EOS must be EOS"
    # tokens before the stop are unaffected by the EOS rule
    assert (out[0, :4] == ref[0, :4]).all()

    # cache rows of a done slot are bit-frozen across further decode steps
    key = jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    pres = eng.prefill(prompts, max_len=30, key=sub)
    from repro.serving.engine import DecodeState

    state = DecodeState(
        caches=pres.caches, tokens=pres.token, lengths=pres.length,
        done=pres.token == eos, key=key,
    )
    state, _ = eng.decode(state, 5)  # row 0 finishes at step 3
    frozen, _ = eng.decode(state, 3)
    done = np.asarray(frozen.done)
    assert done[0], "row 0 should be done"
    for old, new in zip(jax.tree.leaves(state.caches), jax.tree.leaves(frozen.caches)):
        if old.ndim >= 2 and old.shape[1] == 2:  # batch-axis leaves
            np.testing.assert_array_equal(
                np.asarray(old[:, 0]), np.asarray(new[:, 0])
            )
    assert int(frozen.lengths[0]) == int(state.lengths[0])


def test_first_token_eos(params, prompts):
    """A prompt whose FIRST sampled token is EOS yields all-EOS output —
    the first token is subject to the same masking as the rest."""
    free = Engine(CFG, params, ServeConfig(max_new=6, eos_id=-1))
    first = int(np.asarray(free.generate(prompts, max_new=1))[0, 0])
    eng = Engine(CFG, params, ServeConfig(max_new=6, eos_id=first))
    out = np.asarray(eng.generate(prompts))
    assert (out[0] == first).all()


# -- phases -----------------------------------------------------------------


def test_session_matches_whole_batch_generate(params, prompts):
    eng = _greedy(params)
    ref = np.asarray(eng.generate(prompts))
    sess = ServeSession(eng, slots=2, max_len=18)
    s0 = sess.submit(prompts[0])
    s1 = sess.submit(prompts[1])
    sess.run(7)
    assert sess.output(s0) == ref[0].tolist()
    assert sess.output(s1) == ref[1].tolist()


def test_insert_joins_running_batch(params, prompts):
    """A request admitted AFTER the batch has been decoding (spectral
    stream re-phasing) produces exactly the tokens it would produce solo."""
    eng = _greedy(params)
    ref = np.asarray(eng.generate(prompts))
    sess = ServeSession(eng, slots=2, max_len=18)
    s0 = sess.submit(prompts[0])
    sess.run(3)  # slot 0 runs alone; global stream phase advances
    s1 = sess.submit(prompts[1])  # joins mid-stream at nonzero phase
    sess.run(7)
    assert sess.output(s0)[:8] == ref[0].tolist()
    assert sess.output(s1)[:8] == ref[1].tolist()


def test_insert_requires_stream_mode(params, prompts):
    eng = _greedy(params, spectral_decode_mode="ring")
    key = jax.random.PRNGKey(0)
    pres = eng.prefill(prompts[:1], max_len=18, key=key)
    state = eng.init_state(2, 18)
    with pytest.raises(ValueError, match="stream"):
        eng.insert(state, pres, 0)


def test_stream_equals_ring_oracle(params, prompts):
    a = np.asarray(_greedy(params).generate(prompts))
    b = np.asarray(_greedy(params, spectral_decode_mode="ring").generate(prompts))
    np.testing.assert_array_equal(a, b)


def test_generate_contract(params, prompts):
    """Back-compat: (B, S) int32 in → (B, max_new) int32 out."""
    out = _greedy(params, max_new=5).generate(prompts)
    assert out.shape == (2, 5) and out.dtype == jnp.int32


def test_zero_new_plans_when_warm(params):
    """After one warm sweep, a full prefill+insert+generate pass creates
    zero new FFT plans — every spectral flush reuses the cached plan."""
    eng = _greedy(params)
    sweep_once(eng, batch=2, prompt_len=10, max_new=6, warmup=0)
    fft_lib.clear_plan_log()
    r = sweep_once(eng, batch=2, prompt_len=10, max_new=6, warmup=0)
    assert len(fft_lib.plan_log()) == 0, fft_lib.plan_log()
    assert r["decode_tok_per_s"] is not None
