"""Chaos suite: every registered fault site either recovers with correct
numerics or raises a typed :class:`~repro.core.faults.ReproError`.

Covers the taxonomy contract (multiple inheritance keeps pre-taxonomy
``except ValueError`` call sites working), deterministic injection
(``inject_fault`` / ``REPRO_FAULTS``), per-leaf degradation to the traced
XLA fallback with quarantine reuse across re-plans, tuning-cache corruption
rebuild from the packaged seed with zero measurements, the pencil
collective site, serving retry/deadline/backpressure, and the no-fault
invariant: the planned-FFT jaxpr is byte-identical with the fault
machinery bypassed entirely."""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed, faults, tuning
from repro.core import fft as fft_lib


@pytest.fixture(autouse=True)
def _clean_fault_state():
    """Injection, quarantine and the degradation ledger are process-global;
    every chaos test starts and ends clean."""
    faults.clear_faults()
    faults.clear_quarantine()
    faults.clear_degradations()
    yield
    faults.clear_faults()
    faults.clear_quarantine()
    faults.clear_degradations()


def _ref_fft(x):
    return np.fft.fft(np.asarray(x))


# ---------------------------------------------------------------------------
# taxonomy
# ---------------------------------------------------------------------------


def test_taxonomy_builtin_compat():
    """Typed errors keep satisfying the builtin excepts the pre-taxonomy
    code raised."""
    assert issubclass(faults.PlanError, ValueError)
    assert issubclass(faults.KernelError, RuntimeError)
    assert issubclass(faults.TuningCacheError, RuntimeError)
    assert issubclass(faults.CollectiveError, RuntimeError)
    assert issubclass(faults.ServeError, ValueError)
    assert issubclass(faults.ServeError, RuntimeError)
    assert issubclass(faults.NumericsError, ArithmeticError)
    for cls in (
        faults.PlanError,
        faults.KernelError,
        faults.TuningCacheError,
        faults.CollectiveError,
        faults.ServeError,
        faults.NumericsError,
    ):
        assert issubclass(cls, faults.ReproError)


def test_error_carries_context():
    err = faults.KernelError(
        "boom", site="kernel.launch", backend="pallas", pass_kind="fused4", n=256
    )
    assert err.site == "kernel.launch"
    assert err.backend == "pallas"
    assert err.pass_kind == "fused4"
    assert err.context == {"n": 256}
    msg = str(err)
    assert "kernel.launch" in msg and "pallas" in msg and "fused4" in msg


def test_unknown_site_rejected():
    with pytest.raises(faults.PlanError, match="unknown fault site"):
        with faults.inject_fault("bogus.site"):
            pass


def test_env_arming(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "serve.generate:2")
    faults.arm_env_faults(force=True)
    for _ in range(2):
        with pytest.raises(faults.ServeError):
            faults.maybe_fail("serve.generate")
    faults.maybe_fail("serve.generate")  # exhausted: no-op
    assert faults.fault_counters()["serve.generate"] == 2


def test_env_arming_rejects_unknown_site(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "no.such.site")
    with pytest.raises(faults.PlanError, match="unknown fault site"):
        faults.arm_env_faults(force=True)


# ---------------------------------------------------------------------------
# kernel.launch: retry → quarantine → degradation to the XLA fallback
# ---------------------------------------------------------------------------


def test_one_shot_kernel_fault_recovers_cleanly():
    """times=1 is absorbed by the in-place retry: no quarantine, no ledger
    entry, exact happy-path numerics."""
    spec = fft_lib.FFTSpec(n=256, batch_hint=2)
    planned = fft_lib.plan(spec, backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 256), jnp.float32) + 0j
    with faults.inject_fault("kernel.launch", times=1):
        y = planned(x)
    np.testing.assert_allclose(np.asarray(y), _ref_fft(x), rtol=1e-3, atol=1e-3)
    assert planned.degradations == ()
    assert faults.quarantined() == ()


def test_persistent_kernel_fault_degrades_to_xla():
    """A leaf that fails twice is quarantined and demoted to the traced XLA
    fallback; the degraded plan still matches the reference at 1e-3 and
    advertises the demotion."""
    spec = fft_lib.FFTSpec(n=512, batch_hint=3)
    planned = fft_lib.plan(spec, backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 512), jnp.float32) + 0j
    with faults.inject_fault("kernel.launch", times=64):
        y = planned(x)
    np.testing.assert_allclose(np.asarray(y), _ref_fft(x), rtol=1e-3, atol=1e-3)
    degs = planned.degradations
    assert degs, "persistent kernel fault must be recorded on the plan"
    assert all(d["backend"] == "pallas" for d in degs)
    assert any(q[0] == "pallas" for q in faults.quarantined())
    assert "DEGRADED" in planned.describe()
    # the process-global ledger (what ServeSession.health surfaces) agrees
    assert any(d["backend"] == "pallas" for d in faults.degradation_log())


def test_warm_replan_reuses_quarantine_without_reattempting():
    """Once (backend, kind) is quarantined, a NEW plan goes straight to the
    fallback: the kernel is never attempted again, so the armed-fault
    counter does not move."""
    spec = fft_lib.FFTSpec(n=512, batch_hint=5)
    planned = fft_lib.plan(spec, backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 512), jnp.float32) + 0j
    with faults.inject_fault("kernel.launch", times=64):
        planned(x)
    assert faults.quarantined()
    fired = faults.fault_counters()["kernel.launch"]

    spec2 = fft_lib.FFTSpec(n=512, batch_hint=7)
    planned2 = fft_lib.plan(spec2, backend="pallas")
    x2 = jax.random.normal(jax.random.PRNGKey(3), (7, 512), jnp.float32) + 0j
    with faults.inject_fault("kernel.launch", times=64):
        y2 = planned2(x2)
    np.testing.assert_allclose(np.asarray(y2), _ref_fft(x2), rtol=1e-3, atol=1e-3)
    assert faults.fault_counters()["kernel.launch"] == fired
    assert any(d["reason"] == "quarantined" for d in planned2.degradations)


def test_contract_gates_are_never_demoted():
    """NotImplementedError is a planner contract, not a kernel failure:
    run_leaf re-raises it instead of falling back."""

    def attempt():
        raise NotImplementedError("contract")

    with pytest.raises(NotImplementedError):
        faults.run_leaf("pallas", "direct", attempt, lambda: (0, 0))
    assert faults.quarantined() == ()


# ---------------------------------------------------------------------------
# no-fault invariant: the machinery leaves no trace in the jaxpr
# ---------------------------------------------------------------------------


def test_happy_path_jaxpr_identical(monkeypatch):
    """With nothing armed, a planned call's jaxpr is byte-identical to one
    built with run_leaf/maybe_fail bypassed entirely — degradation wiring
    costs nothing at trace time."""
    spec = fft_lib.FFTSpec(n=1024, batch_hint=2)
    planned = fft_lib.plan(spec, backend="pallas")
    x = jnp.zeros((2, 1024), jnp.complex64)
    before_measure = tuning.measure_log()
    guarded = str(jax.make_jaxpr(planned)(x))

    monkeypatch.setattr(
        faults, "run_leaf", lambda b, k, attempt, fallback, **kw: attempt()
    )
    monkeypatch.setattr(faults, "maybe_fail", lambda site, **ctx: None)
    bare = str(jax.make_jaxpr(planned)(x))
    assert guarded == bare
    assert tuning.measure_log() == before_measure


# ---------------------------------------------------------------------------
# tuning cache: corruption, foreign schema, injected read/write faults
# ---------------------------------------------------------------------------


@pytest.fixture
def scratch_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)
    tuning.clear_measure_log()
    yield path
    tuning.cache._loaded_path = None  # drop the memoized view of the tmp path
    tuning.cache._mem = {}
    tuning.clear_measure_log()


def test_corrupt_cache_quarantined_and_rebuilt_from_seed(scratch_cache):
    with open(scratch_cache, "w") as f:
        f.write('{"this is": not json')
    with pytest.warns(RuntimeWarning, match="rebuilding from the packaged seed"):
        entries = tuning.TuningCache()._load()
    assert entries == {}
    assert os.path.exists(scratch_cache + ".corrupt")
    assert not os.path.exists(scratch_cache)
    # the packaged seed still serves through get()
    assert tuning.TuningCache().get("cpu|pallas|plan|fft|n=8192|batch=2")


@pytest.mark.skipif(
    jax.default_backend() != "cpu", reason="seed entries are keyed for cpu"
)
def test_corrupt_cache_plans_seeded_spec_with_zero_measurements(scratch_cache):
    with open(scratch_cache, "w") as f:
        f.write("truncated garbag")
    with pytest.warns(RuntimeWarning):
        fft_lib.plan(
            fft_lib.FFTSpec(n=8192, batch_hint=2), backend="pallas", tune="measure"
        )
    assert tuning.measure_log() == ()


def test_foreign_schema_quarantined(scratch_cache):
    with open(scratch_cache, "w") as f:
        json.dump({"version": 99, "entries": {}}, f)
    with pytest.warns(RuntimeWarning, match="foreign schema"):
        assert tuning.TuningCache()._load() == {}
    assert os.path.exists(scratch_cache + ".corrupt")


def test_legacy_flat_schema_still_readable(scratch_cache):
    with open(scratch_cache, "w") as f:
        json.dump({"a|b|c|d": {"config": {"x": 1}, "mode": "model"}}, f)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no quarantine warning for legacy files
        assert tuning.TuningCache().get("a|b|c|d") == {
            "config": {"x": 1},
            "mode": "model",
        }


def test_cache_write_round_trips_versioned(scratch_cache):
    c = tuning.TuningCache()
    c.put("k|k|k|k", {"config": {"block": 4}, "mode": "measure"})
    with open(scratch_cache) as f:
        doc = json.load(f)
    assert doc["version"] == tuning.CACHE_SCHEMA_VERSION
    assert doc["entries"]["k|k|k|k"]["mode"] == "measure"
    assert tuning.TuningCache().get("k|k|k|k")["config"]["block"] == 4


def test_injected_cache_read_fault_serves_seed(scratch_cache):
    with open(scratch_cache, "w") as f:
        json.dump({"version": 1, "entries": {"u|u|u|u": {"config": 1}}}, f)
    with faults.inject_fault("tuning.cache_read"):
        c = tuning.TuningCache()
        assert c.get("u|u|u|u") is None  # user file unreadable this once
        assert c.get("cpu|pallas|plan|fft|n=8192|batch=2")  # seed still serves
    assert os.path.exists(scratch_cache)  # the healthy file is NOT quarantined
    assert tuning.TuningCache().get("u|u|u|u") == {"config": 1}


def test_injected_cache_write_fault_degrades_to_memory(scratch_cache):
    c = tuning.TuningCache()
    with faults.inject_fault("tuning.cache_write"):
        c.put("w|w|w|w", {"config": 2, "mode": "model"})
    assert c.get("w|w|w|w") == {"config": 2, "mode": "model"}  # memory kept it
    assert not os.path.exists(scratch_cache)  # nothing half-written


# ---------------------------------------------------------------------------
# pencil collective site
# ---------------------------------------------------------------------------


def test_collective_fault_raises_typed_before_the_wire():
    with faults.inject_fault("pencil.all_to_all"):
        with pytest.raises(faults.CollectiveError) as ei:
            distributed._a2a(jnp.zeros((2, 2)), "x", 0, 0)
    assert ei.value.injected
    assert ei.value.site == "pencil.all_to_all"


# ---------------------------------------------------------------------------
# serving: retry, deadline reaping, backpressure, health
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_engine():
    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.serving.engine import Engine, ServeConfig

    cfg = ModelConfig(
        family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, block_pattern=("spectral", "attn"),
        spectral_filter_len=8, compute_dtype="float32",
    )
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    return Engine(cfg, params, ServeConfig(max_new=8))


@pytest.fixture
def serve_prompts(serve_engine):
    return jax.random.randint(
        jax.random.PRNGKey(1), (3, 10), 4, serve_engine.cfg.vocab_size
    )


def test_transient_prefill_fault_is_retried(serve_engine, serve_prompts):
    from repro.serving.spectral_serve import ServeSession

    sess = ServeSession(serve_engine, slots=2, max_len=32)
    with faults.inject_fault("serve.prefill", times=1):
        slot = sess.submit(serve_prompts[0])
    assert slot == 0
    assert sess.counts["retries"] == 1
    assert len(sess.output(slot)) == 1  # first token sampled despite the fault


def test_persistent_prefill_fault_raises_typed(serve_engine, serve_prompts):
    from repro.serving.spectral_serve import ServeSession

    sess = ServeSession(serve_engine, slots=1, max_len=32, prefill_retries=1)
    with faults.inject_fault("serve.prefill", times=8):
        with pytest.raises(faults.ServeError) as ei:
            sess.submit(serve_prompts[0])
    assert ei.value.injected


def test_insert_and_generate_faults_raise_typed(serve_engine, serve_prompts):
    from repro.serving.spectral_serve import ServeSession

    sess = ServeSession(serve_engine, slots=1, max_len=32)
    with faults.inject_fault("serve.insert"):
        with pytest.raises(faults.ServeError):
            sess.submit(serve_prompts[0])
    sess2 = ServeSession(serve_engine, slots=1, max_len=32)
    sess2.submit(serve_prompts[0])
    with faults.inject_fault("serve.generate"):
        with pytest.raises(faults.ServeError):
            sess2.run(2)


def test_queue_backpressure_and_ticket_drain(serve_engine, serve_prompts):
    from repro.serving.spectral_serve import ServeSession

    sess = ServeSession(serve_engine, slots=1, max_len=32, queue_cap=1)
    slot = sess.submit(serve_prompts[0])
    ticket = sess.submit(serve_prompts[1])
    assert slot == 0 and ticket < 0
    with pytest.raises(faults.ServeError, match="queue"):
        sess.submit(serve_prompts[2])  # beyond the cap: typed rejection
    assert sess.counts["rejected"] == 1
    with pytest.raises(faults.ServeError, match="queued"):
        sess.output(ticket)
    # expire the occupying request so run() reaps it and drains the queue
    sess._deadline[0] = -1.0
    sess.run(2)
    assert sess.counts["expired"] == 1
    assert len(sess.output(ticket)) >= 1


def test_deadline_reaps_expired_slot(serve_engine, serve_prompts):
    from repro.serving.spectral_serve import ServeSession

    sess = ServeSession(serve_engine, slots=1, max_len=32, default_deadline_s=0.0)
    sess.submit(serve_prompts[0])
    sess.run(2)
    assert sess.counts["expired"] == 1
    assert sess.free_slots() == [0]


def test_health_snapshot(serve_engine, serve_prompts):
    from repro.serving.spectral_serve import ServeSession

    sess = ServeSession(serve_engine, slots=2, max_len=32, queue_cap=4)
    sess.submit(serve_prompts[0])
    h = sess.health()
    assert h["slots"] == 2 and h["live"] + h["free"] == 2
    assert h["queue_depth"] == 0 and h["queue_cap"] == 4
    for key in ("counts", "quarantined", "degradations", "fault_counters"):
        assert key in h


# ---------------------------------------------------------------------------
# numerics guards
# ---------------------------------------------------------------------------


def test_check_nan_guard():
    planned = fft_lib.plan(fft_lib.FFTSpec(n=64, batch_hint=1))
    good = jnp.ones((1, 64), jnp.complex64)
    planned(good, check="nan")  # clean input passes
    bad = good.at[0, 3].set(jnp.nan)
    with pytest.raises(faults.NumericsError):
        planned(bad, check="nan")


def test_check_parseval_guard():
    planned = fft_lib.plan(fft_lib.FFTSpec(n=128, batch_hint=2))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 128), jnp.float32) + 0j
    planned(x, check="parseval")  # a correct transform conserves energy
    with pytest.raises(faults.PlanError, match="check"):
        planned(x, check="bogus")
