"""Pencil plan layer — in-process tests (no fake-device subprocess needed).

Covers the tuned-schedule resolution (`plan_pencil` / `tuning.pencil_config`
/ `roofline.pencil_report`) and the d=1 degenerate mesh, which runs on the
default single-device environment.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.core import distributed as D
from repro.core import tuning


# ---------------------------------------------------------------------------
# PencilPlan / plan_pencil
# ---------------------------------------------------------------------------


def test_plan_pencil_resolves_and_caches():
    pl = D.plan_pencil(8192, 8)
    assert pl.n1 * pl.n2 == 8192
    assert pl.n1 % 8 == 0 and pl.n2 % 8 == 0
    assert pl.p == pl.n1 // 8 and pl.q == pl.n2 // 8
    # interned: same args → same handle
    assert D.plan_pencil(8192, 8) is pl
    assert D.plan_pencil(8192, 8, inverse=True) is not pl


def test_describe_prints_schedule():
    s = D.plan_pencil(8192, 8).describe()
    assert f"factors {D.plan_pencil(8192, 8).n1}x{D.plan_pencil(8192, 8).n2}" in s
    assert "a2a x3 natural" in s and "MB/step" in s
    assert "leaf n1:" in s and "leaf n2:" in s
    s1 = D.plan_pencil(4096, 1).describe()
    assert "0 collectives" in s1 and "local:" in s1


def test_a2a_count_math():
    assert D.plan_pencil(8192, 8, chunks=1).a2a_count(True) == 3
    assert D.plan_pencil(8192, 8, chunks=1).a2a_count(False) == 2
    assert D.plan_pencil(8192, 8, chunks=2).a2a_count(True) == 5
    assert D.plan_pencil(8192, 8, pack=False).a2a_count(True) == 6
    assert D.plan_pencil(8192, 8, pack=False).a2a_count(False) == 4
    assert D.plan_pencil(4096, 1).a2a_count(True) == 0
    assert D.plan_pencil(4096, 1).a2a_count(False) == 0


def test_chunk_count_clamps_to_divide_columns():
    pl = D.plan_pencil(8192, 8)  # q = n2 / 8
    big = D.plan_pencil(8192, 8, chunks=4 * pl.q)
    assert big.a2a_chunks == pl.q  # clamped to the column count
    odd = D.plan_pencil(8192, 8, chunks=3)
    assert odd.q % odd.a2a_chunks == 0
    # split-plane path never chunks
    assert D.plan_pencil(8192, 8, pack=False, chunks=4).a2a_chunks == 1


def test_plan_pencil_rejects_bad_factors():
    with pytest.raises(ValueError):
        D.plan_pencil(8192, 8, factors=(64, 64))  # product != n
    with pytest.raises(ValueError):
        D.plan_pencil(8192, 8, factors=(2048, 4))  # 4 % 8 != 0


# ---------------------------------------------------------------------------
# Deterministic modeled tuning (the SPMD contract)
# ---------------------------------------------------------------------------


def test_pencil_config_modeled_only_no_cache_no_measure(
    monkeypatch, tmp_path
):
    # Fresh cache path: other suites may legitimately populate the
    # session-wide cache file; pencil decisions themselves never write one.
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tuning.json"))
    before = len(tuning.measure_log())
    cfg = tuning.pencil_config(65536, 8)
    assert cfg["n1"] * cfg["n2"] == 65536
    assert cfg["n1"] % 8 == 0 and cfg["n2"] % 8 == 0
    # "measure" clamps to the modeled pick — identical, still zero timings
    assert tuning.pencil_config(65536, 8, tune="measure") == cfg
    assert tuning.pencil_config(65536, 8) == cfg  # repeatable
    assert len(tuning.measure_log()) == before
    assert not os.path.exists(tuning.cache_path())


def test_pencil_config_off_is_balanced_serial():
    cfg = tuning.pencil_config(8192, 8, tune="off")
    assert (cfg["n1"], cfg["n2"]) == D.pencil_factors(8192, 8)
    assert cfg["pack"] and cfg["a2a_chunks"] == 1


def test_for_pencil_space_candidates_valid():
    space = tuning.TuningSpace.for_pencil(65536, 16)
    assert space.measure_fn is None  # never measurable — SPMD safety
    assert len(space.candidates) > 1
    for cfg, cost, vmem in space.candidates:
        assert cfg["n1"] * cfg["n2"] == 65536
        assert cfg["n1"] % 16 == 0 and cfg["n2"] % 16 == 0
        assert cost > 0 and vmem > 0
        if cfg["a2a_chunks"] > 1:
            assert cfg["pack"]  # chunk overlap rides the packed path only


# ---------------------------------------------------------------------------
# Roofline comm model
# ---------------------------------------------------------------------------


def test_pencil_report_keys_and_overlap():
    rep = rl.pencil_report(65536, 8)
    for k in (
        "n1",
        "n2",
        "comm_bytes_per_step",
        "local_hbm_bytes",
        "modeled_s",
        "serial_s",
        "overlap_win",
    ):
        assert k in rep, k
    assert rep["comm_bytes_per_step"] > 0
    assert rep["modeled_s"] <= rep["serial_s"] * (1 + 1e-9)
    # packing strictly beats split-plane in the model (launch charges)
    unpacked = rl.pencil_report(65536, 8, pack=False)
    assert rep["modeled_s"] < unpacked["modeled_s"]


def test_pencil_report_single_device_has_no_comm():
    rep = rl.pencil_report(65536, 1)
    assert rep["comm_bytes_per_step"] == 0
    assert rep["modeled_s"] > 0


# ---------------------------------------------------------------------------
# d=1 degenerate mesh: collapses to the local plan, zero collectives
# ---------------------------------------------------------------------------


def _mesh1():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))


def test_single_shard_collapses_to_local_plan():
    n = 4096
    rng = np.random.default_rng(11)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(
        np.complex64
    )
    ref = np.fft.fft(x)
    mesh = _mesh1()
    yr, yi = D.pfft_sharded(jnp.asarray(x.real), jnp.asarray(x.imag), mesh, "x")
    rel = (
        np.abs((np.asarray(yr) + 1j * np.asarray(yi)) - ref).max()
        / np.abs(ref).max()
    )
    assert rel < 5e-5, rel
    zr, zi = D.pifft_sharded(yr, yi, mesh, "x")
    assert np.abs((np.asarray(zr) + 1j * np.asarray(zi)) - x).max() < 5e-5


def test_single_shard_zero_collectives_jaxpr():
    n = 4096
    mesh = _mesh1()
    from jax.sharding import PartitionSpec as P

    for natural in (True, False):
        fn = D.shard_map_compat(
            lambda xr, xi: D.pfft(
                xr,
                xi,
                n=n,
                axis_name="x",
                num_shards=1,
                natural_order=natural,
            ),
            mesh,
            in_specs=(P("x"), P("x")),
            out_specs=(P("x"), P("x")),
        )
        jx = str(jax.make_jaxpr(fn)(jnp.zeros(n), jnp.zeros(n)))
        for coll in ("all_to_all", "all_gather", "psum", "ppermute"):
            assert coll not in jx, (natural, coll)


def test_single_shard_pencil_layout_semantics():
    # d=1, natural_order=False must keep the k1-major layout contract.
    n = 4096
    rng = np.random.default_rng(12)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(
        np.complex64
    )
    ref = np.fft.fft(x)
    mesh = _mesh1()
    pr, pi = D.pfft_sharded(
        jnp.asarray(x.real), jnp.asarray(x.imag), mesh, "x", natural_order=False
    )
    n1, n2 = D.pencil_factors(n, 1)
    pen = (np.asarray(pr) + 1j * np.asarray(pi)).reshape(n1, n2)
    perm = ref.reshape(n2, n1).T
    rel = np.abs(pen - perm).max() / np.abs(ref).max()
    assert rel < 5e-5, rel
    # and the mirrored inverse consumes it
    zr, zi = D.pifft_sharded(pr, pi, mesh, "x", from_pencil=True)
    assert np.abs((np.asarray(zr) + 1j * np.asarray(zi)) - x).max() < 5e-5


# ---------------------------------------------------------------------------
# StreamingConv under SPMD
# ---------------------------------------------------------------------------


def test_streaming_conv_spmd_block_is_modeled():
    from repro.core.overlap import StreamingConv, pick_block

    h = jnp.asarray(np.random.default_rng(13).standard_normal(257), jnp.float32)
    before = len(tuning.measure_log())
    sc = StreamingConv(h, chunk_hint=4096, spmd=True)
    expect = tuning.modeled_block(4096, 257, 1, None, chunk=4096)
    assert sc.block == expect
    assert len(tuning.measure_log()) == before  # no timings taken
    # and it still convolves correctly at that block
    x = np.random.default_rng(14).standard_normal(10000).astype(np.float32)
    state = sc.init_state()
    y1, state = sc(jnp.asarray(x[:4096]), state)
    y2, state = sc(jnp.asarray(x[4096:]), state)
    y = np.concatenate([np.asarray(y1), np.asarray(y2)])
    ref = np.convolve(x, np.asarray(h))[: x.shape[-1]]
    np.testing.assert_allclose(y, ref, atol=5e-3)
