"""Checkpoint manager: atomicity, async, keep-N, elastic restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(seed):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state(3)
    mgr.save(3, s, extra={"data_step": 3})
    r, extra = mgr.restore(3, s)
    assert extra["data_step"] == 3
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for i in range(3):
        mgr.save(i, _state(i), blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [0, 1, 2]


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for i in range(5):
        mgr.save(i, _state(i))
    assert mgr.all_steps() == [3, 4]


def test_latest_and_autoresume(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is None
    mgr.save(10, _state(10))
    mgr.save(20, _state(20))
    assert mgr.latest_step() == 20


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    # simulate a crash mid-write: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    assert mgr.latest_step() == 1


def test_structure_mismatch_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    with pytest.raises(AssertionError, match="architecture mismatch"):
        mgr.restore(1, {"only_one_leaf": jnp.zeros(3)})


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,), jnp.float32)})
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    r, _ = mgr.restore(1, like)
    assert r["w"].dtype == jnp.bfloat16
