"""Core FFT correctness + property-based invariants (hypothesis optional)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import fft as F

BACKENDS = ["stockham", "xla", "pallas"]
SIZES = [2, 8, 64, 256, 1024, 4096]


def _rand_c(rng, shape):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", SIZES)
def test_fft_matches_numpy(backend, n, rng):
    x = _rand_c(rng, (3, n))
    y = np.asarray(F.fft(jnp.asarray(x), backend=backend))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-3 * np.abs(ref).max())


@pytest.mark.parametrize("backend", BACKENDS)
def test_fft_large_split_regime(backend, rng):
    n = 2**17  # forces the 2-round-trip plan
    x = _rand_c(rng, (1, n))
    y = np.asarray(F.fft(jnp.asarray(x), backend=backend))
    ref = np.fft.fft(x)
    rel = np.abs(y - ref).max() / np.abs(ref).max()
    assert rel < 5e-5, rel


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [16, 1024, 4096])
def test_roundtrip(backend, n, rng):
    x = _rand_c(rng, (2, n))
    y = F.ifft(F.fft(jnp.asarray(x), backend=backend), backend=backend)
    np.testing.assert_allclose(np.asarray(y), x, atol=2e-4)


@pytest.mark.parametrize("n", [16, 256, 4096])
def test_rfft_matches_numpy(n, rng):
    x = rng.standard_normal((2, n)).astype(np.float32)
    Xr, Xi = F.rfft(jnp.asarray(x))
    ref = np.fft.rfft(x)
    np.testing.assert_allclose(np.asarray(Xr), ref.real, atol=3e-3 * np.abs(ref).max())
    np.testing.assert_allclose(np.asarray(Xi), ref.imag, atol=3e-3 * np.abs(ref).max())
    back = np.asarray(F.irfft((Xr, Xi), n))
    np.testing.assert_allclose(back, x, atol=2e-4)


def test_fft2_matches_numpy(rng):
    x = _rand_c(rng, (2, 64, 128))
    y = np.asarray(F.fft2(jnp.asarray(x)))
    ref = np.fft.fft2(x)
    np.testing.assert_allclose(y, ref, atol=2e-3 * np.abs(ref).max())


def test_planes_api(rng):
    x = _rand_c(rng, (2, 256))
    yr, yi = F.fft((jnp.asarray(x.real), jnp.asarray(x.imag)))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(np.asarray(yr), ref.real, atol=2e-3 * np.abs(ref).max())
    np.testing.assert_allclose(np.asarray(yi), ref.imag, atol=2e-3 * np.abs(ref).max())


# --------------------------------------------------------------------------
# property-based invariants
# --------------------------------------------------------------------------

_sizes = st.sampled_from([8, 64, 256, 1024])
_seed = st.integers(0, 2**31 - 1)
_backend = st.sampled_from(BACKENDS)


@settings(max_examples=20, deadline=None)
@given(n=_sizes, seed=_seed, backend=_backend)
def test_linearity(n, seed, backend):
    rng = np.random.default_rng(seed)
    a, b = rng.standard_normal(2).astype(np.float32)
    x = _rand_c(rng, (n,))
    y = _rand_c(rng, (n,))
    lhs = F.fft(jnp.asarray(a * x + b * y), backend=backend)
    rhs = a * F.fft(jnp.asarray(x), backend=backend) + b * F.fft(
        jnp.asarray(y), backend=backend
    )
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(n=_sizes, seed=_seed, backend=_backend)
def test_parseval(n, seed, backend):
    rng = np.random.default_rng(seed)
    x = _rand_c(rng, (n,))
    X = np.asarray(F.fft(jnp.asarray(x), backend=backend))
    lhs = np.sum(np.abs(x) ** 2)
    rhs = np.sum(np.abs(X) ** 2) / n
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n=_sizes, seed=_seed, shift=st.integers(0, 63), backend=_backend)
def test_time_shift_theorem(n, seed, shift, backend):
    rng = np.random.default_rng(seed)
    shift = shift % n
    x = _rand_c(rng, (n,))
    X = np.asarray(F.fft(jnp.asarray(x), backend=backend))
    Xs = np.asarray(F.fft(jnp.asarray(np.roll(x, shift)), backend=backend))
    k = np.arange(n)
    phase = np.exp(-2j * np.pi * k * shift / n)
    np.testing.assert_allclose(Xs, X * phase, atol=2e-2 * (np.abs(X).max() + 1))


@settings(max_examples=10, deadline=None)
@given(n=_sizes, pos=st.integers(0, 1023), backend=_backend)
def test_impulse_is_phasor(n, pos, backend):
    pos = pos % n
    x = np.zeros(n, np.complex64)
    x[pos] = 1.0
    X = np.asarray(F.fft(jnp.asarray(x), backend=backend))
    k = np.arange(n)
    ref = np.exp(-2j * np.pi * k * pos / n)
    np.testing.assert_allclose(X, ref, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=_sizes, seed=_seed)
def test_backends_agree(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_rand_c(rng, (n,)))
    ys = [np.asarray(F.fft(x, backend=b)) for b in BACKENDS]
    np.testing.assert_allclose(ys[0], ys[1], atol=1e-2)
    np.testing.assert_allclose(ys[0], ys[2], atol=1e-2)


# ---------------------------------------------------------------------------
# twiddle overflow regression: huge-n traced tables with x64 DISABLED
# ---------------------------------------------------------------------------


def test_traced_twiddle_int32_safe_beyond_2_31():
    """n > 2³¹ twiddles must be right under the default (x64-off) config.

    The old implementation built jnp.int64 iotas which silently downcast to
    int32 without x64, so the (k1·m2) % n reduction overflowed — producing
    wrong twiddles exactly in the huge-N regime the traced tables exist for.
    A column window keeps the table small while the products span ~2³³.
    """
    from repro.core import twiddle as tw

    assert not jax.config.jax_enable_x64  # the regression's precondition
    n1, n2 = 1 << 15, 1 << 18  # n = 2**33 > 2**31
    n = n1 * n2
    q = 64
    start = n2 - q  # top of the range: k1·m2 up to ~n, the overflow zone
    tr, ti = tw.traced_twiddle(n1, n2, col_start=start, col_count=q)
    k1 = np.arange(n1, dtype=np.int64)[:, None]
    m2 = (start + np.arange(q, dtype=np.int64))[None, :]
    ang = (2.0 * np.pi / n) * ((k1 * m2) % n).astype(np.float64)
    np.testing.assert_allclose(np.asarray(tr), np.cos(ang), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ti), -np.sin(ang), atol=2e-5)


def test_traced_twiddle_at_exactly_2_31():
    # The boundary case: n == 2**31 must take the int32-safe path (an int32
    # `% n` operand would fail to parse at trace time).
    from repro.core import twiddle as tw

    n1, n2 = 1 << 15, 1 << 16  # n = 2**31
    q, start = 32, (1 << 16) - 32
    tr, ti = tw.traced_twiddle(n1, n2, col_start=start, col_count=q)
    k1 = np.arange(n1, dtype=np.int64)[:, None]
    m2 = (start + np.arange(q, dtype=np.int64))[None, :]
    ang = (2.0 * np.pi / (n1 * n2)) * ((k1 * m2) % (n1 * n2)).astype(np.float64)
    np.testing.assert_allclose(np.asarray(tr), np.cos(ang), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ti), -np.sin(ang), atol=2e-5)


def test_traced_twiddle_small_n_matches_host_grid():
    from repro.core import twiddle as tw

    for n1, n2 in [(8, 16), (64, 64)]:
        tr, ti = tw.traced_twiddle(n1, n2)
        hr, hi = tw.twiddle_grid(n1, n2)
        np.testing.assert_allclose(np.asarray(tr), hr, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ti), hi, atol=1e-6)
        # the column window agrees with the full grid
        wr, wi = tw.traced_twiddle(n1, n2, col_start=4, col_count=8)
        np.testing.assert_allclose(np.asarray(wr), hr[:, 4:12], atol=1e-6)
        np.testing.assert_allclose(np.asarray(wi), hi[:, 4:12], atol=1e-6)


def test_mulfrac_pow2_exact_across_regimes():
    from repro.core import twiddle as tw

    rng = np.random.default_rng(7)
    for e in (20, 31, 32, 33, 40, 48):
        n = 1 << e
        k1 = rng.integers(0, min(n, 2**31), size=(32, 1))
        m2 = rng.integers(0, min(n, 2**31), size=(1, 32))
        exact = ((k1 * m2) % n) / n
        got = np.asarray(
            tw.mulfrac_pow2(
                jnp.asarray(k1, jnp.int32), jnp.asarray(m2, jnp.int32), n
            )
        ) % 1.0
        err = np.abs(got - exact)
        err = np.minimum(err, 1.0 - err)  # wrap at the 0/1 seam
        assert err.max() < 1e-6, (e, err.max())
