"""The mini HLO cost analyzer: loop-aware flops/bytes/collectives."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze
from repro.analysis.roofline import V5E, roofline_terms


def test_scan_flops_loop_corrected():
    W = jnp.zeros((8, 256, 256))
    x0 = jnp.zeros((4, 256))

    def scanned(x0, W):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x0, W)[0]

    def unrolled(x0, W):
        x = x0
        for i in range(8):
            x = jnp.tanh(x @ W[i])
        return x

    cs = analyze(jax.jit(scanned).lower(x0, W).compile().as_text())
    cu = analyze(jax.jit(unrolled).lower(x0, W).compile().as_text())
    true_dot = 8 * 2 * 4 * 256 * 256
    assert abs(cs.dot_flops - true_dot) / true_dot < 1e-6
    assert abs(cu.dot_flops - true_dot) / true_dot < 1e-6
    # XLA's own counter under-reports the scan by ~8x — that's why we parse.
    xla = jax.jit(scanned).lower(x0, W).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):  # older JAX: one dict per device
        xla = xla[0]
    assert xla["flops"] < true_dot / 4


def test_dot_flops_with_batch_dims():
    a = jnp.zeros((4, 32, 64))
    b = jnp.zeros((4, 64, 16))
    c = analyze(jax.jit(jnp.matmul).lower(a, b).compile().as_text())
    true = 2 * 4 * 32 * 64 * 16
    assert abs(c.dot_flops - true) / true < 1e-6


def test_scan_bytes_do_not_explode():
    """In-place ys accumulation must not count the full buffer per step."""
    xs = jnp.zeros((64, 128))

    def f(xs):
        def body(c, x):
            return c, x * 2.0
        return jax.lax.scan(body, 0.0, xs)[1]

    c = analyze(jax.jit(f).lower(xs).compile().as_text())
    total = 64 * 128 * 4
    # traffic should be O(read + write) of the data, not O(steps * buffer)
    assert c.bytes < 20 * total, c.bytes


def test_roofline_terms_bound_selection():
    t = roofline_terms(197e12, 0.0, 0.0)  # 1s of pure compute
    assert t["bound"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, 819e9, 0.0)
    assert t["bound"] == "memory"
    t = roofline_terms(0.0, 0.0, 50e9)
    assert t["bound"] == "collective"
    assert abs(t["collective_s"] - 1.0) < 1e-9


def test_elementwise_counted():
    x = jnp.zeros((1024,))
    c = analyze(jax.jit(lambda x: jnp.tanh(x) + 1.0).lower(x).compile().as_text())
    assert c.flops >= 1024  # at least one flop per element
