"""Decode-vs-forward logit equivalence across block families.

The strongest correctness property of the serving path: prefilling a prefix
and decoding token-by-token must reproduce the full-sequence forward logits
exactly (same dtype path, same kernels)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as M

CASES = {
    "dense_gqa": ModelConfig(
        family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
    ),
    "dense_softcap_tied": ModelConfig(
        family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, attn_logit_softcap=20.0,
        final_logit_softcap=30.0, tie_embeddings=True,
    ),
    "swa_local_global": ModelConfig(
        family="dense", num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, sliding_window=6, local_global_ratio=2,
    ),
    "moe_shared_dense": ModelConfig(
        family="moe", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=256, num_experts=8, top_k=2,
        num_shared_experts=1, moe_dense_residual=True,
    ),
    "zamba_hybrid": ModelConfig(
        family="hybrid", d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_state=8, ssm_heads=4, chunk_size=2,
        block_pattern=("mamba2", "mamba2", "shared_attn") * 2,
    ),
    "xlstm": ModelConfig(
        family="ssm", d_model=64, num_heads=4, num_kv_heads=4, d_ff=0,
        vocab_size=256, ssm_heads=2, chunk_size=2,
        block_pattern=("mlstm", "slstm") * 2,
    ),
    "spectral": ModelConfig(
        family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, block_pattern=("spectral", "attn"),
        spectral_filter_len=8,
    ),
    "chunked_attn": ModelConfig(
        family="dense", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, attn_chunk=8, attn_chunk_threshold=8,
    ),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    S, Sp = 16, 10
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    full_logits, _ = M.logits_fn(params, {"tokens": toks, "targets": toks}, cfg)
    lp, caches = M.prefill(params, {"tokens": toks[:, :Sp]}, cfg)
    caches = M.prepare_decode_caches(caches, cfg, Sp, S)
    errs = [float(jnp.abs(lp - full_logits[:, Sp - 1]).max())]
    for t in range(Sp, S):
        lg, caches = M.decode_step(
            params, toks[:, t], caches, jnp.asarray(t, jnp.int32), cfg
        )
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 1e-3, f"{name}: decode diverges from forward ({max(errs)})"


def test_scan_equals_unrolled_stack():
    base = dict(
        family="dense", num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, compute_dtype="float32",
    )
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 256)
    cfg_s = ModelConfig(**base, scan_layers=True)
    cfg_u = ModelConfig(**base, scan_layers=False)
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg_s)
    ls, _ = M.logits_fn(params, {"tokens": toks}, cfg_s)
    lu, _ = M.logits_fn(params, {"tokens": toks}, cfg_u)
    assert float(jnp.abs(ls - lu).max()) < 1e-4


@pytest.mark.parametrize("sp", [3, 8, 11, 16])
def test_spectral_stream_prefill_lengths(sp):
    """Streamed spectral decode after prefills that straddle the chunk /
    filter boundaries: Sp < Lf (zero-padded history), Sp == chunk (flush
    boundary), ragged tail, and multiple whole chunks.  The spectral case
    has Lf = 8 and stream chunk C = 8, so 3 / 8 / 11 / 16 hit each regime;
    8 decode steps always cross at least one in-flight flush.  float32 so
    the comparison measures the streaming math, not bf16 rounding."""
    import dataclasses

    cfg = dataclasses.replace(CASES["spectral"], compute_dtype="float32")
    S = sp + 8
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    full_logits, _ = M.logits_fn(params, {"tokens": toks, "targets": toks}, cfg)
    lp, caches = M.prefill(params, {"tokens": toks[:, :sp]}, cfg)
    caches = M.prepare_decode_caches(caches, cfg, sp, S)
    errs = [float(jnp.abs(lp - full_logits[:, sp - 1]).max())]
    for t in range(sp, S):
        lg, caches = M.decode_step(
            params, toks[:, t], caches, jnp.asarray(t, jnp.int32), cfg
        )
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 1e-3, f"Sp={sp}: stream decode diverges ({max(errs)})"


def test_spectral_stream_past_fused_regime():
    """A prompt longer than FUSED_MAX: prefill must route the mixer conv
    through overlap-save (no plan bigger than the fused ceiling) and the
    carried stream state must still continue the sequence to 1e-3."""
    from repro.core import fft as fft_lib
    from repro.core.limits import FUSED_MAX
    from repro.models.layers import spectral as spec_lib
    from repro.utils.params import unzip

    cfg = ModelConfig(
        family="dense", num_layers=2, d_model=2, num_heads=1, num_kv_heads=1,
        d_ff=4, vocab_size=16, block_pattern=("spectral", "attn"),
        spectral_filter_len=32, compute_dtype="float32",
    )
    c, _ = spec_lib.stream_grain(cfg)
    s, t_steps = FUSED_MAX + 64, c + 2
    params, _ = unzip(spec_lib.spectral_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = 0.1 * jax.random.normal(
        jax.random.PRNGKey(1), (1, s + t_steps, cfg.d_model), jnp.float32
    )
    ref = spec_lib.spectral_forward(params, x, cfg=cfg)
    fft_lib.clear_plan_log()
    _, cache = spec_lib.spectral_forward(params, x[:, :s], cfg=cfg, return_cache=True)
    assert all(spec.n <= FUSED_MAX for spec, _ in fft_lib.plan_log()), (
        "prefill past FUSED_MAX planned a fused-regime-sized FFT"
    )
    step = jax.jit(
        lambda xt, cc: spec_lib.spectral_stream_decode(params, xt, cc, cfg=cfg)
    )
    errs = []
    for i in range(t_steps):
        y, cache = step(x[:, s + i : s + i + 1], cache)
        errs.append(float(jnp.abs(y - ref[:, s + i : s + i + 1]).max()))
    assert max(errs) < 1e-3, f"stream decode past fused regime: {max(errs)}"


def test_spectral_mixer_flag_trains_and_decodes():
    """The paper-integration ablation: use_spectral_mixer alternates FFT
    long-conv mixing with attention and must stay decode-exact."""
    cfg = ModelConfig(
        family="dense", num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, use_spectral_mixer=True, spectral_filter_len=8,
    )
    assert cfg.pattern() == ("spectral", "attn") * 2
    S, Sp = 12, 8
    params, _ = M.init_unzipped(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, 256)
    full_logits, _ = M.logits_fn(params, {"tokens": toks}, cfg)
    lp, caches = M.prefill(params, {"tokens": toks[:, :Sp]}, cfg)
    caches = M.prepare_decode_caches(caches, cfg, Sp, S)
    errs = [float(jnp.abs(lp - full_logits[:, Sp - 1]).max())]
    for t in range(Sp, S):
        lg, caches = M.decode_step(
            params, toks[:, t], caches, jnp.asarray(t, jnp.int32), cfg
        )
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 1e-3, max(errs)
