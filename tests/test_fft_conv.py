"""FFT convolution vs direct convolution (hypothesis sweep optional)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.conv import (
    fft_conv,
    fft_conv2d,
    fft_conv_packed,
    next_pow2,
    toeplitz_conv_ref,
)


def _direct_causal(x, h):
    L = x.shape[-1]
    out = np.zeros_like(x)
    for j in range(h.shape[-1]):
        if j < L:
            out[..., j:] += h[..., j : j + 1] * x[..., : L - j]
    return out


def test_next_pow2():
    assert next_pow2(1) == 1
    assert next_pow2(5) == 8
    assert next_pow2(1024) == 1024
    assert next_pow2(1025) == 2048


def test_fft_conv_matches_direct(rng):
    x = rng.standard_normal((2, 4, 128)).astype(np.float32)
    h = rng.standard_normal((4, 32)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    ref = _direct_causal(x, h[None])
    np.testing.assert_allclose(y, ref, atol=1e-3)


def test_fft_conv_per_channel_filters_vs_toeplitz(rng):
    # Distinct per-channel filters: the Toeplitz oracle now broadcasts them
    # properly, so this actually exercises the multi-filter path.
    x = rng.standard_normal((3, 4, 96)).astype(np.float32)
    h = rng.standard_normal((4, 24)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    ref = toeplitz_conv_ref(x, h[None])
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_fft_conv_full_mode(rng):
    x = rng.standard_normal((1, 64)).astype(np.float32)
    h = rng.standard_normal((1, 16)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h), causal=False))
    ref = np.convolve(x[0], h[0], mode="full")[None]
    np.testing.assert_allclose(y, ref, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    L=st.sampled_from([16, 100, 256, 500]),
    Lh=st.sampled_from([1, 4, 33, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fft_conv_property(L, Lh, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, L)).astype(np.float32)
    h = rng.standard_normal((1, Lh)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    ref = _direct_causal(x, h)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(y, ref, atol=2e-3 * scale)


@pytest.mark.parametrize("rows", [3, 5])
def test_fft_conv_packed_odd_rows(rows, rng):
    # Odd row counts used to hard-assert; now a zero row is packed along
    # with the last real one and stripped from the output.
    x = rng.standard_normal((2, rows, 100)).astype(np.float32)
    h = rng.standard_normal((16,)).astype(np.float32)
    y = np.asarray(fft_conv_packed(jnp.asarray(x), jnp.asarray(h)))
    assert y.shape == x.shape
    ref = toeplitz_conv_ref(x, h)
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_fft_conv_packed_full_mode_odd_rows(rng):
    x = rng.standard_normal((3, 60)).astype(np.float32)
    h = rng.standard_normal((9,)).astype(np.float32)
    y = np.asarray(fft_conv_packed(jnp.asarray(x), jnp.asarray(h), causal=False))
    assert y.shape == (3, 68)
    ref = np.stack([np.convolve(r, h, mode="full") for r in x])
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_fft_conv_bf16_in_f32_accurate_out(rng):
    # bf16 inputs are computed in float32 (not fed raw to the kernels) and
    # the output dtype is restored; only the final rounding is bf16.
    x32 = rng.standard_normal((2, 3, 128)).astype(np.float32)
    h32 = rng.standard_normal((3, 32)).astype(np.float32)
    x = jnp.asarray(x32, jnp.bfloat16)
    h = jnp.asarray(h32, jnp.bfloat16)
    y = fft_conv(x, h)
    assert y.dtype == jnp.bfloat16
    ref = toeplitz_conv_ref(np.asarray(x, np.float32), np.asarray(h, np.float32)[None])
    scale = np.abs(ref).max()
    # one bf16 rounding of an f32-accurate result: ~2^-8 relative
    np.testing.assert_allclose(np.asarray(y, np.float32), ref, atol=0.02 * scale)


def test_fft_conv_packed_and_2d_restore_dtype(rng):
    xb = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.bfloat16)
    hb = jnp.asarray(rng.standard_normal((16,)), jnp.bfloat16)
    assert fft_conv_packed(xb, hb).dtype == jnp.bfloat16
    img = jnp.asarray(rng.standard_normal((16, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((3, 5)), jnp.bfloat16)
    assert fft_conv2d(img, k).dtype == jnp.bfloat16
    # float32 callers are untouched
    assert fft_conv2d(jnp.asarray(rng.standard_normal((16, 32)), jnp.float32),
                      jnp.asarray(rng.standard_normal((3, 5)), jnp.float32)
                      ).dtype == jnp.float32


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv_commutes_with_filter_scaling(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, 128)).astype(np.float32)
    h = rng.standard_normal((1, 16)).astype(np.float32)
    y1 = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(2.0 * h)))
    y2 = 2.0 * np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    np.testing.assert_allclose(y1, y2, atol=1e-3)
