"""FFT convolution vs direct convolution (hypothesis sweep optional)."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.conv import fft_conv, next_pow2, toeplitz_conv_ref


def _direct_causal(x, h):
    L = x.shape[-1]
    out = np.zeros_like(x)
    for j in range(h.shape[-1]):
        if j < L:
            out[..., j:] += h[..., j : j + 1] * x[..., : L - j]
    return out


def test_next_pow2():
    assert next_pow2(1) == 1
    assert next_pow2(5) == 8
    assert next_pow2(1024) == 1024
    assert next_pow2(1025) == 2048


def test_fft_conv_matches_direct(rng):
    x = rng.standard_normal((2, 4, 128)).astype(np.float32)
    h = rng.standard_normal((4, 32)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    ref = _direct_causal(x, h[None])
    np.testing.assert_allclose(y, ref, atol=1e-3)


def test_fft_conv_per_channel_filters_vs_toeplitz(rng):
    # Distinct per-channel filters: the Toeplitz oracle now broadcasts them
    # properly, so this actually exercises the multi-filter path.
    x = rng.standard_normal((3, 4, 96)).astype(np.float32)
    h = rng.standard_normal((4, 24)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    ref = toeplitz_conv_ref(x, h[None])
    np.testing.assert_allclose(y, ref, atol=2e-3)


def test_fft_conv_full_mode(rng):
    x = rng.standard_normal((1, 64)).astype(np.float32)
    h = rng.standard_normal((1, 16)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h), causal=False))
    ref = np.convolve(x[0], h[0], mode="full")[None]
    np.testing.assert_allclose(y, ref, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    L=st.sampled_from([16, 100, 256, 500]),
    Lh=st.sampled_from([1, 4, 33, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fft_conv_property(L, Lh, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, L)).astype(np.float32)
    h = rng.standard_normal((1, Lh)).astype(np.float32)
    y = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    ref = _direct_causal(x, h)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(y, ref, atol=2e-3 * scale)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv_commutes_with_filter_scaling(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, 128)).astype(np.float32)
    h = rng.standard_normal((1, 16)).astype(np.float32)
    y1 = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(2.0 * h)))
    y2 = 2.0 * np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(h)))
    np.testing.assert_allclose(y1, y2, atol=1e-3)
